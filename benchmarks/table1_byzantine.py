"""Paper Table I / Figs. 5-8: test accuracy under each Byzantine attack at
10% malicious clients, across all aggregation methods (b fixed at 0.01 as
in the paper's Byzantine section)."""

from __future__ import annotations

import time

from .common import emit, run_fl

ATTACKS = ("gaussian", "sign_flip", "zero_gradient", "sample_duplicate")
METHODS = (
    ("probit_plus", {}),
    ("probit_plus_dp", {"aggregator": "probit_plus", "dp_epsilon": 0.1}),
    ("rsa", {"aggregator": "rsa"}),
    ("signsgd_mv", {"aggregator": "signsgd_mv"}),
    ("fed_gm", {"aggregator": "fed_gm"}),
    ("fedavg", {"aggregator": "fedavg"}),
)


def main(rounds: int | None = None, byz_frac: float = 0.1) -> dict:
    out: dict = {}
    for attack in ATTACKS:
        out[attack] = {}
        for name, kw in METHODS:
            kw = dict(kw)
            kw.setdefault("aggregator", "probit_plus")
            t0 = time.time()
            sim = run_fl(
                10, rounds, byz_frac=byz_frac, attack=attack,
                b_mode="fixed", **kw,
            )
            acc = sim.history[-1]["acc"]
            out[attack][name] = acc
            emit(
                f"table1_{attack}_{name}",
                (time.time() - t0) / sim.cfg.rounds * 1e6,
                f"acc={acc:.4f}",
            )
    return out


if __name__ == "__main__":
    main()
