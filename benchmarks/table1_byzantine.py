"""Paper Table I / Figs. 5-8: test accuracy under each Byzantine attack at
10% malicious clients, across all aggregation methods (b fixed at 0.01 as
in the paper's Byzantine section) — plus a beyond-paper buffered-async
PRoBit+ column (clients arrive with mean latency 1 round, staleness
discount ``1/sqrt(1+age)``), which shows how much of the synchronous
robustness survives realistic arrivals.

The grid runs through the campaign planner as one ``CampaignSpec``: the
4 attacks x 7 methods become 28 cells; cells differing only in the attack
share a vmapped program (the attack axis is a traced ``lax.switch`` id),
so the plan lowers to one program per *method* instead of one per cell
(the Byzantine cohort keeps these cells out of heterogeneous-M fusion —
``n_byz`` is a static slice bound — but they still ride the AOT compile
cache and overlapped dispatch)::

    spec = table1_spec(rounds=60, byz_frac=0.1)
    plan = repro.sim.plan_campaign(spec)        # 28 cells -> 7 programs
    result = repro.sim.run_campaign(spec, common.campaign_task, plan=plan)
    result.final("acc")            # {cell_name: (mean, ci), ...}

``main`` additionally replays the same cell set through the sequential
``FLSimulation`` loop, asserts per-cell accuracies agree to 1e-6 at the
fixed seed, and emits the wall-clock comparison (set ``parity=False`` or
``PROBIT_BENCH_NO_PARITY=1`` to skip the sequential replay)."""

from __future__ import annotations

import os
import time

from .common import ROUNDS, campaign_task, emit, run_fl  # sets sys.path first

from repro.sim import CampaignSpec, CellSpec, plan_campaign, run_campaign  # noqa: E402

ATTACKS = ("gaussian", "sign_flip", "zero_gradient", "sample_duplicate")
METHODS = (
    ("probit_plus", {}),
    ("probit_plus_dp", {"aggregator": "probit_plus", "dp_epsilon": 0.1}),
    (
        "probit_plus_async",
        {
            "aggregator": "probit_plus",
            "async_buffer": 10,
            "async_latency": 1.0,
            "staleness_decay": 0.5,
        },
    ),
    ("rsa", {"aggregator": "rsa"}),
    ("signsgd_mv", {"aggregator": "signsgd_mv"}),
    ("fed_gm", {"aggregator": "fed_gm"}),
    ("fedavg", {"aggregator": "fedavg"}),
)


def table1_spec(rounds: int | None = None, byz_frac: float = 0.1) -> CampaignSpec:
    """The Table-I grid as a campaign declaration (28 cells, 1 seed)."""
    cells = []
    for attack in ATTACKS:
        for name, kw in METHODS:
            overrides = dict(kw)
            overrides.setdefault("aggregator", "probit_plus")
            overrides["attack"] = attack
            cells.append(CellSpec(f"{attack}_{name}", overrides))
    return CampaignSpec(
        base=dict(
            n_clients=10,
            rounds=rounds or ROUNDS,
            local_epochs=2,
            byz_frac=byz_frac,
            b_mode="fixed",
        ),
        cells=tuple(cells),
        seeds=(0,),
    )


def main(rounds: int | None = None, byz_frac: float = 0.1, parity: bool | None = None) -> dict:
    if parity is None:
        parity = not os.environ.get("PROBIT_BENCH_NO_PARITY")
    spec = table1_spec(rounds, byz_frac)
    n_rounds = spec.base["rounds"]

    t0 = time.perf_counter()
    plan = plan_campaign(spec)
    result = run_campaign(spec, campaign_task, plan=plan)
    t_grid = time.perf_counter() - t0
    emit(
        "table1_plan",
        t_grid / (len(spec.cells) * n_rounds) * 1e6,
        f"programs={plan.n_programs};cells={len(spec.cells)};"
        f"cells_per_sec={result.cells_per_sec:.2f}",
    )

    out: dict = {attack: {} for attack in ATTACKS}
    for name, us, derived in result.emit_rows("table1"):
        emit(name, us, derived)
    for attack in ATTACKS:
        for name, _ in METHODS:
            out[attack][name] = float(
                result.cell(f"{attack}_{name}").metrics["acc"][0, -1]
            )

    if parity:
        # Acceptance check: the vmapped grid must reproduce the sequential
        # loop per cell (1e-6) and beat it wall-clock on the same cell set.
        t0 = time.perf_counter()
        max_diff = 0.0
        for attack in ATTACKS:
            for name, kw in METHODS:
                kw = dict(kw)
                kw.setdefault("aggregator", "probit_plus")
                sim = run_fl(
                    10, n_rounds, byz_frac=byz_frac, attack=attack,
                    b_mode="fixed", **kw,
                )
                max_diff = max(
                    max_diff, abs(sim.history[-1]["acc"] - out[attack][name])
                )
        t_seq = time.perf_counter() - t0
        emit(
            "table1_parity",
            t_grid / (len(spec.cells) * n_rounds) * 1e6,
            f"max_acc_diff={max_diff:.2e};grid_s={t_grid:.1f};seq_s={t_seq:.1f};"
            f"speedup={t_seq / t_grid:.2f}x",
        )
        assert max_diff <= 1e-6, f"campaign/sequential divergence: {max_diff}"
        out["_parity"] = {
            "max_acc_diff": max_diff,
            "grid_s": t_grid,
            "seq_s": t_seq,
            "speedup": t_seq / t_grid,
        }
    return out


if __name__ == "__main__":
    main()
