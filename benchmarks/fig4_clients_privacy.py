"""Paper Fig. 4: (left) accuracy vs number of uploading clients M —
validates the O(1/M) error decay reaching FedAvg; (right) accuracy vs
privacy loss eps at fixed M."""

from __future__ import annotations

import time

from .common import emit, run_fl


def main(rounds: int | None = None) -> dict:
    out: dict = {"clients": {}, "privacy": {}}
    for m in (5, 10, 20, 40):
        t0 = time.time()
        pb = run_fl(m, rounds, aggregator="probit_plus")
        fa = run_fl(m, rounds, aggregator="fedavg")
        gap = fa.history[-1]["acc"] - pb.history[-1]["acc"]
        out["clients"][m] = {
            "probit": pb.history[-1]["acc"],
            "fedavg": fa.history[-1]["acc"],
            "gap": gap,
        }
        emit(
            f"fig4_clients_M{m}",
            (time.time() - t0) / (2 * pb.cfg.rounds) * 1e6,
            f"probit={pb.history[-1]['acc']:.4f};fedavg={fa.history[-1]['acc']:.4f};gap={gap:.4f}",
        )
    for eps in (1.0, 0.1, 0.01):
        t0 = time.time()
        sim = run_fl(20, rounds, aggregator="probit_plus", dp_epsilon=eps)
        out["privacy"][eps] = sim.history[-1]["acc"]
        emit(
            f"fig4_privacy_eps{eps}",
            (time.time() - t0) / sim.cfg.rounds * 1e6,
            f"acc={sim.history[-1]['acc']:.4f}",
        )
    return out


if __name__ == "__main__":
    main()
