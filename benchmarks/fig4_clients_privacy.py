"""Paper Fig. 4: (left) accuracy vs number of uploading clients M —
validates the O(1/M) error decay reaching FedAvg; (right) accuracy vs
privacy loss eps at fixed M.

One ``CampaignSpec`` covers both panels: an (M x aggregator) sweep plus a
privacy-eps sweep. Since the planner (``repro.sim.plan``), the M-sweep is
**fused**: every ``n_clients`` value of one aggregator pads to the sweep
max and runs as ONE compiled program (M is traced via the active-client
mask), so the grid compiles one program per aggregator plus one per eps
(eps changes the compiled DP branch) instead of one per cell::

    plan = plan_campaign(fig4_spec(rounds))
    plan.describe()   # 11 cells -> 5 programs (2 fused M-sweeps)
    result = run_campaign(fig4_spec(rounds), common.campaign_task)
    result.cell("M=20_probit").metrics["theta_mse"]  # O(1/M) per round
"""

from __future__ import annotations

from .common import ROUNDS, campaign_task, emit  # sets sys.path first

from repro.sim import CampaignSpec, CellSpec, plan_campaign, run_campaign  # noqa: E402

CLIENTS = (5, 10, 20, 40)
EPSILONS = (1.0, 0.1, 0.01)


def fig4_spec(rounds: int | None = None) -> CampaignSpec:
    cells = []
    for m in CLIENTS:
        cells.append(CellSpec(f"M={m}_probit", {"n_clients": m}))
        cells.append(
            CellSpec(f"M={m}_fedavg", {"n_clients": m, "aggregator": "fedavg"})
        )
    for eps in EPSILONS:
        cells.append(CellSpec(f"eps={eps}", {"n_clients": 20, "dp_epsilon": eps}))
    return CampaignSpec(
        base=dict(rounds=rounds or ROUNDS, local_epochs=2, aggregator="probit_plus"),
        cells=tuple(cells),
        seeds=(0,),
    )


def main(rounds: int | None = None) -> dict:
    spec = fig4_spec(rounds)
    plan = plan_campaign(spec)
    # Acceptance: the whole probit M-sweep is one fused compiled program
    # (same for the fedavg sweep) — the planner's reason to exist.
    m_sweep = {f"M={m}_probit" for m in CLIENTS}
    fused_groups = [
        {spec.cells[i].name for i in g.cell_idx}
        for g in plan.groups
        if g.fused
    ]
    assert any(m_sweep <= names for names in fused_groups), plan.describe()
    result = run_campaign(spec, campaign_task, plan=plan)
    rows = {name: (us, derived) for name, us, derived in result.emit_rows("fig4")}
    out: dict = {"clients": {}, "privacy": {}}
    for m in CLIENTS:
        pb = float(result.cell(f"M={m}_probit").metrics["acc"][0, -1])
        fa = float(result.cell(f"M={m}_fedavg").metrics["acc"][0, -1])
        gap = fa - pb
        out["clients"][m] = {"probit": pb, "fedavg": fa, "gap": gap}
        emit(
            f"fig4_clients_M{m}",
            rows[f"fig4_M={m}_probit"][0],
            f"probit={pb:.4f};fedavg={fa:.4f};gap={gap:.4f}",
        )
    for eps in EPSILONS:
        acc = float(result.cell(f"eps={eps}").metrics["acc"][0, -1])
        out["privacy"][eps] = acc
        emit(f"fig4_privacy_eps{eps}", rows[f"fig4_eps={eps}"][0], f"acc={acc:.4f}")
    return out


if __name__ == "__main__":
    main()
