"""Campaign throughput vs device count: cells/sec with the batch axis
sharded over N virtual CPU devices.

The ROADMAP's "device-sharded campaigns at scale" item, measured: one
fused campaign grid (every cell in a single compiled program — the
planner's fused heterogeneous-M path) is executed with
``run_campaign(..., shard=True)`` under ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` for a sweep of N. Each device
count runs in a **subprocess** because the flag must be set before jax
initializes its platform; the child re-enters this module with
``--inner`` and prints one JSON line the parent collects.

Per device count the child warms the AOT compile cache, then times
``REPS`` executions and reports the best cells/sec (steady-state
throughput; compile excluded by the warm-up). The parent emits one row
per device count, writes ``reports/fig_campaign_throughput.json``, and
reports ``monotone_1_to_max`` — throughput at the max device count must
be >= throughput at 1 device (the 1 -> 4 endpoint comparison; interior
counts are reported but not gated, since on an N-core host the
intermediate points can jitter within noise). This is the acceptance
line for the sharded execution path, asserted by the nightly test in
``tests/test_plan.py``.

  PYTHONPATH=src python -m benchmarks.fig_campaign_throughput
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4)
REPS = 3
# Smaller default than the figure benchmarks: the sweep runs the same
# grid once per device count (plus warm-up).
ROUNDS = int(os.environ.get("PROBIT_BENCH_ROUNDS", "60")) // 3 or 1
SEEDS = (0, 1, 2, 3)


def throughput_spec(rounds: int | None = None):
    """A fused grid: (M x lr) cells, all in ONE compiled program.

    n_clients spans 8..16 so the planner's heterogeneous-M fusion is on
    the measured path; 8 cells x 4 seeds = 32 batch elements shard evenly
    over 1/2/4 devices.
    """
    from repro.sim import CampaignSpec

    return CampaignSpec.from_grid(
        base=dict(rounds=rounds or ROUNDS, local_epochs=2, b_mode="fixed"),
        axes={"n_clients": (8, 12, 16, 10), "lr": (0.01, 0.02)},
        seeds=SEEDS,
    )


def run_inner(rounds: int | None = None, reps: int = REPS) -> dict:
    """Measure this process's device configuration (child entry point)."""
    import jax

    from .common import campaign_task
    from repro.sim import plan_campaign, run_campaign

    spec = throughput_spec(rounds)
    plan = plan_campaign(spec, shard=True)
    run_campaign(spec, campaign_task, shard=True, with_acc=False)  # warm-up
    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = run_campaign(spec, campaign_task, shard=True, with_acc=False)
        wall = time.perf_counter() - t0
        cps = len(spec.cells) * len(spec.seeds) / wall
        if best is None or cps > best["cells_per_sec"]:
            best = {
                "cells_per_sec": cps,
                "wall_s": wall,
                "n_devices": jax.device_count(),
                "n_programs": plan.n_programs,
                "n_fused": plan.n_fused,
                "groups": result.groups,
            }
    return best


def main(rounds: int | None = None, device_counts=DEVICE_COUNTS) -> dict:
    from .common import emit

    out: dict = {"rounds": rounds or ROUNDS, "sweep": {}}
    for n_dev in device_counts:
        env = dict(os.environ)
        # Drop any inherited device-count flag (repro.launch.dryrun sets
        # 512 into os.environ when imported) — ours must win.
        inherited = [
            f
            for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f
        ]
        env["XLA_FLAGS"] = " ".join(
            [f"--xla_force_host_platform_device_count={n_dev}", *inherited]
        )
        env["PYTHONPATH"] = (
            os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep
            + env.get("PYTHONPATH", "")
        )
        cmd = [
            sys.executable, "-m", "benchmarks.fig_campaign_throughput",
            "--inner", "--rounds", str(rounds or ROUNDS),
        ]
        res = subprocess.run(
            cmd, capture_output=True, text=True, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )
        if res.returncode != 0:
            raise RuntimeError(
                f"device-count={n_dev} child failed:\n{res.stderr[-3000:]}"
            )
        payload = json.loads(res.stdout.strip().splitlines()[-1])
        assert payload["n_devices"] == n_dev, payload
        out["sweep"][n_dev] = payload
        emit(
            f"campaign_throughput_dev{n_dev}",
            1e6 / payload["cells_per_sec"],
            f"cells_per_sec={payload['cells_per_sec']:.2f};"
            f"programs={payload['n_programs']};fused={payload['n_fused']}",
        )

    counts = sorted(out["sweep"])
    thr = [out["sweep"][k]["cells_per_sec"] for k in counts]
    out["monotone_1_to_max"] = bool(thr[-1] >= thr[0])
    emit(
        "campaign_throughput_scaling",
        1e6 / thr[-1],
        f"speedup_{counts[0]}to{counts[-1]}={thr[-1] / thr[0]:.2f}x;"
        f"monotone={out['monotone_1_to_max']}",
    )

    report = os.path.join(
        os.path.dirname(__file__), "..", "reports",
        "fig_campaign_throughput.json",
    )
    os.makedirs(os.path.dirname(report), exist_ok=True)
    with open(report, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args()
    if args.inner:
        payload = run_inner(args.rounds, args.reps)
        print(json.dumps(payload, default=str))
    else:
        main(args.rounds)
