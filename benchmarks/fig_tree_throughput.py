"""Hierarchical tree aggregation throughput: clients/sec vs edge count.

The ROADMAP's "hierarchical aggregation" item, measured: the M-client
synthetic round (the ``fig_streaming_clients`` task, so the flat
streaming baseline is pinned to the same data and model) is executed as
a clients -> edges -> root count tree for a sweep of edge counts, each
edge mapped onto its own virtual CPU device (``tree_shard``; psum-free
root merge). Three acceptance lines ride the figure:

* **parity gate** — before any timing, a small eager run asserts the
  tree root estimate is **bit-exact** with the flat streaming round at
  zero staleness (the additive count merge is associative);
* **edge-count sweep** — clients/sec at edges in {1, 2, 4} (each edge
  count in a subprocess with ``--xla_force_host_platform_device_count``
  = edges, since the flag must precede jax platform init), plus the
  flat streaming round as the no-tree baseline. ``monotone_1_to_max``
  records whether the max-edge throughput beats the 1-edge tree — a
  *recorded* property, asserted only by the nightly slow test, because
  on a single-core host every virtual device shares one core;
* **Byzantine-edge sweep** — an (attacked-edges x merge-rule) campaign
  at E = 8: the naive additive merge's ``theta_mse`` degrades with the
  number of inflating edges while the rate-median merge holds. The
  campaign JSON (with CI bands) is written next to the figure and the
  trajectory PNG is rendered *from the JSON on disk* via
  ``benchmarks.plots`` — the artifact -> plot path CI exercises.

Writes ``reports/fig_tree_throughput.json``, the stable
``reports/BENCH_tree_throughput.json`` (clients/sec at edges {1, 4},
M = 1e5, CPU — the tracked regression number), and
``reports/fig_tree_throughput_campaign.json`` (+ PNG when matplotlib is
available). ``--smoke`` shrinks every axis for the per-push CI gate.

  PYTHONPATH=src python -m benchmarks.fig_tree_throughput
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import resource
import subprocess
import sys
import time

import numpy as np

from .fig_streaming_clients import CHUNK, _base, _init_params, _task_fn, stream_task

EDGE_COUNTS = (1, 2, 4)
M_SWEEP = int(os.environ.get("PROBIT_TREE_M", "100000"))
M_BYZ = 512
BYZ_EDGES = 8
ROUNDS = int(os.environ.get("PROBIT_STREAM_ROUNDS", "2"))
REPORTS = os.path.join(os.path.dirname(__file__), "..", "reports")


def _tree_cfg(m: int, edges: int, rounds: int, **extra):
    from repro.fl import FLConfig

    return FLConfig(
        **_base(rounds),
        n_clients=m,
        client_chunk=min(CHUNK, m),
        stateless_clients=True,
        tree_edges=edges,
        **extra,
    )


def _make_ctx(cfg):
    from repro.fl import rounds as R
    from repro.models.vision import accuracy, mlp_logits, xent_loss

    cx, cy, test = stream_task(cfg.n_clients)
    return R.make_context(
        cfg,
        _init_params(),
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx,
        cy,
        test,
    )


def parity_gate(m: int = 64, rounds: int = 2) -> float:
    """Bit-exact tree == flat at zero staleness (eager, small M).

    Returns the max |difference| (must be exactly 0.0) — the correctness
    gate that must pass before any throughput number is reported.
    """
    import jax

    from repro.fl import FLConfig, rounds as R

    def run(cfg):
        ctx = _make_ctx(cfg)
        params = R.cell_params(cfg)
        state = R.init_run_state(ctx)
        key = jax.random.PRNGKey(0)
        fn = R.round_fn(ctx)
        with jax.disable_jit():
            for _ in range(rounds):
                key, kb, kr = jax.random.split(key, 3)
                state, _ = fn(ctx, params, kr, state, R.round_batches(ctx, kb))
        return np.asarray(state.w_global)

    flat = run(
        FLConfig(
            **_base(rounds), n_clients=m, client_chunk=16,
            stateless_clients=True,
        )
    )
    tree = run(
        FLConfig(
            **_base(rounds), n_clients=m, client_chunk=16,
            stateless_clients=True, tree_edges=4,
        )
    )
    diff = float(np.abs(flat - tree).max())
    if diff != 0.0:
        raise AssertionError(
            f"tree root estimate not bit-exact with flat round: max diff {diff}"
        )
    return diff


def run_inner(m: int, edges: int, rounds: int) -> dict:
    """One timed cell in this process's device configuration (child).

    ``edges == 0`` is the flat streaming baseline; ``edges >= 1`` runs
    the tree, sharded one edge per device when the parent gave us
    ``device_count == edges``.
    """
    import jax

    from repro.fl import rounds as R

    if edges:
        cfg = _tree_cfg(m, edges, rounds, tree_shard=edges > 1)
    else:
        from repro.fl import FLConfig

        cfg = FLConfig(
            **_base(rounds), n_clients=m, client_chunk=min(CHUNK, m),
            stateless_clients=True,
        )
    ctx = _make_ctx(cfg)
    params = R.cell_params(cfg)
    key = jax.random.PRNGKey(0)
    state = R.init_run_state(ctx)
    jax.block_until_ready(R.run_rounds(ctx, params, key, state, with_acc=False))
    t0 = time.perf_counter()
    _, traj = R.run_rounds(ctx, params, key, state, with_acc=False)
    jax.block_until_ready(traj)
    wall = time.perf_counter() - t0
    return {
        "m": m,
        "edges": edges,
        "n_devices": jax.device_count(),
        "clients_per_sec": m * rounds / wall,
        "wall_s": wall,
        "theta_mse": float(np.mean(traj["theta_mse"])),
        "maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
    }


def _spawn(m: int, edges: int, rounds: int) -> dict:
    n_dev = max(edges, 1)
    env = dict(os.environ)
    inherited = [
        f
        for f in env.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    ]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_dev}", *inherited]
    )
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    cmd = [
        sys.executable, "-m", "benchmarks.fig_tree_throughput",
        "--inner", "--m", str(m), "--edges", str(edges),
        "--rounds", str(rounds),
    ]
    res = subprocess.run(
        cmd, capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if res.returncode != 0:
        raise RuntimeError(
            f"edges={edges} child failed:\n{res.stderr[-3000:]}"
        )
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert payload["n_devices"] == n_dev, payload
    return payload


def byz_campaign(rounds: int, seeds=(0, 1, 2)) -> dict:
    """(byz_edges x merge) grid at E = 8 through the campaign engine.

    Returns the summary dict; writes the campaign JSON artifact and
    renders its trajectory PNG *from the file on disk* (the
    ``benchmarks.plots`` CLI path).
    """
    from repro.sim import CampaignSpec, CellSpec, run_campaign
    from .plots import plot_trajectories

    base = dict(
        **_base(rounds),
        n_clients=M_BYZ,
        client_chunk=min(CHUNK, M_BYZ),
        stateless_clients=True,
        tree_edges=BYZ_EDGES,
        edge_attack="edge_inflate",
    )
    cells = tuple(
        CellSpec(f"byz{b}_{merge}", dict(byz_edges=b, edge_merge=merge))
        for b in (0, 1, 3)
        for merge in ("sum", "median")
    )
    spec = CampaignSpec(base=base, cells=cells, seeds=seeds)
    result = run_campaign(spec, _task_fn, with_acc=False)

    camp_path = os.path.join(REPORTS, "fig_tree_throughput_campaign.json")
    result.save(camp_path)
    png = plot_trajectories(
        camp_path, "theta_mse",
        out_path=camp_path.replace(".json", "_theta_mse.png"),
        title=f"Byzantine edges at E={BYZ_EDGES} (edge_inflate)",
        logy=True,
    )

    mse = {
        c.name: float(np.mean(c.metrics["theta_mse"])) for c in result.cells
    }
    # the robustness headline: at 3/8 inflating edges the median merge
    # must beat the naive sum (recorded; the unit breakdown test asserts
    # the sharper merge-layer version)
    return {
        "theta_mse": mse,
        "median_beats_sum_at_3": bool(mse["byz3_median"] < mse["byz3_sum"]),
        "campaign_json": os.path.relpath(camp_path, REPORTS + "/.."),
        "png": png and os.path.relpath(png, REPORTS + "/.."),
    }


def main(rounds: int | None = None, smoke: bool = False) -> dict:
    from .common import emit

    rounds = ROUNDS if rounds is None else min(rounds, ROUNDS)
    m = 10_000 if smoke else M_SWEEP
    edge_counts = (1, 2) if smoke else EDGE_COUNTS

    out: dict = {"m": m, "rounds": rounds, "smoke": smoke, "sweep": {}}
    out["parity_max_diff"] = parity_gate()
    emit("tree_parity_gate", 0.0, "bit_exact=True")

    out["flat_baseline"] = _spawn(m, 0, rounds)
    for e in edge_counts:
        out["sweep"][e] = _spawn(m, e, rounds)
        r = out["sweep"][e]
        emit(
            f"tree_throughput_E{e}",
            1e6 / r["clients_per_sec"],
            f"clients_per_sec={r['clients_per_sec']:.0f};"
            f"devices={r['n_devices']};maxrss_mb={r['maxrss_mb']:.0f}",
        )
    thr = [out["sweep"][e]["clients_per_sec"] for e in edge_counts]
    out["monotone_1_to_max"] = bool(thr[-1] >= thr[0])
    emit(
        "tree_throughput_scaling",
        1e6 / thr[-1],
        f"speedup_1to{edge_counts[-1]}={thr[-1] / thr[0]:.2f}x;"
        f"monotone={out['monotone_1_to_max']};"
        f"flat_cps={out['flat_baseline']['clients_per_sec']:.0f}",
    )

    out["byzantine"] = byz_campaign(
        min(rounds * 5, 10), seeds=(0,) if smoke else (0, 1, 2)
    )
    emit(
        "tree_byzantine_edges",
        0.0,
        f"median_beats_sum_at_3={out['byzantine']['median_beats_sum_at_3']};"
        + ";".join(
            f"{k}={v:.2e}" for k, v in out["byzantine"]["theta_mse"].items()
        ),
    )

    os.makedirs(REPORTS, exist_ok=True)
    with open(os.path.join(REPORTS, "fig_tree_throughput.json"), "w") as f:
        json.dump(out, f, indent=1, default=str)
    if not smoke:
        # the stable tracked number: clients/sec at edges {1, max}, M, CPU
        bench = {
            "m": m,
            "rounds": rounds,
            "platform": "cpu",
            "clients_per_sec": {
                "flat": round(out["flat_baseline"]["clients_per_sec"]),
                **{
                    f"edges_{e}": round(out["sweep"][e]["clients_per_sec"])
                    for e in edge_counts
                },
            },
            "monotone_1_to_max": out["monotone_1_to_max"],
        }
        with open(os.path.join(REPORTS, "BENCH_tree_throughput.json"), "w") as f:
            json.dump(bench, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--edges", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    if args.inner:
        print(
            json.dumps(
                run_inner(args.m, args.edges, args.rounds or ROUNDS),
                default=str,
            )
        )
    else:
        main(args.rounds, smoke=args.smoke)
