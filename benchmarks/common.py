"""Shared benchmark harness utilities.

Two ways to run FL scenarios from a benchmark module:

* :func:`run_fl` — one sequential :class:`repro.fl.FLSimulation` (kept as
  the reference driver and for parity/timing comparisons);
* :func:`campaign_task` — the task provider that plugs the same
  classification task into the vectorized campaign engine
  (:func:`repro.sim.run_campaign`), which is how the figure/table grids
  run by default.
"""

from __future__ import annotations

import functools
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import make_classification, partition_label_skew  # noqa: E402
from repro.fl import FLConfig, FLSimulation  # noqa: E402
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss  # noqa: E402
from repro.sim import Task  # noqa: E402

# Benchmark scale (CPU container): paper protocol at reduced scale.
ROUNDS = int(os.environ.get("PROBIT_BENCH_ROUNDS", "60"))
N_TRAIN = 3000
PER_CLIENT = 100


@functools.lru_cache(maxsize=None)
def task(n_clients: int, classes_per_client: int = 2, seed: int = 0):
    (xtr, ytr), (xte, yte) = make_classification(seed, n_train=N_TRAIN, n_test=600)
    parts = partition_label_skew(ytr, n_clients, classes_per_client, PER_CLIENT, seed)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    return cx, cy, {"x": xte, "y": yte}


@functools.lru_cache(maxsize=None)
def _mlp_p0(hidden: int = 48):
    return init_mlp(jax.random.PRNGKey(0), hidden=hidden)


def campaign_task(cfg: FLConfig) -> Task:
    """Campaign-engine task provider for the benchmark classification task.

    Same data, partition, and initial model as :func:`run_fl`, keyed on
    the cell's ``n_clients`` (cached), so a campaign cell at a fixed seed
    reproduces the sequential driver bit for bit.
    """
    cx, cy, test = task(cfg.n_clients, 2)
    return Task(
        init_params=_mlp_p0(),
        loss_fn=functools.partial(xent_loss, mlp_logits),
        acc_fn=functools.partial(accuracy, mlp_logits),
        client_x=cx,
        client_y=cy,
        test=test,
    )


def run_fl(n_clients: int, rounds: int = None, classes_per_client: int = 2, **kw) -> FLSimulation:
    cx, cy, test = task(n_clients, classes_per_client)
    cfg = FLConfig(n_clients=n_clients, rounds=rounds or ROUNDS, local_epochs=2, **kw)
    p0 = _mlp_p0()
    sim = FLSimulation(
        cfg,
        p0,
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx,
        cy,
        test,
    )
    sim.run(eval_every=cfg.rounds)
    return sim


def timed(fn, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median microseconds per call (jax-blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
