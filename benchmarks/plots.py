"""Campaign-native trajectory plots: per-round mean ± 95% CI bands.

Matplotlib is an optional dependency of the benchmark harness — every
entry point here degrades to a no-op returning ``None`` when it is not
importable (CI containers without a plotting stack still produce the
JSON artifacts; the figure is a bonus, never a gate).

Two input shapes are accepted:

* a live :class:`repro.sim.metrics.CampaignResult` — full seed axes are
  available, so the band is the z*SEM half-width from
  :meth:`CellResult.trajectory`;
* a saved campaign JSON artifact (path or loaded dict, the
  :meth:`CampaignResult.to_json` structure) — both the per-round means
  and the serialized ``trajectory_ci`` half-widths are read, so a PNG
  rendered from a JSON on disk carries the same mean±CI bands as one
  rendered live (older artifacts without ``trajectory_ci`` degrade to a
  band-less line).

CLI: render any campaign JSON on disk to a trajectory PNG, e.g. the
nightly artifacts::

  PYTHONPATH=src python -m benchmarks.plots reports/fig_bits_frontier.json
  PYTHONPATH=src python -m benchmarks.plots reports/fig_tree_throughput_campaign.json --metric theta_mse --logy
"""

from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

__all__ = ["have_matplotlib", "plot_trajectories"]


def have_matplotlib() -> bool:
    try:
        import matplotlib  # noqa: F401

        return True
    except ImportError:
        return False


def _cell_series(result: Any, metric: str) -> dict[str, tuple[np.ndarray, np.ndarray]]:
    """name -> (mean, ci_half) per-round arrays, from either input shape."""
    if isinstance(result, str):
        with open(result) as f:
            result = json.load(f)
    if isinstance(result, dict):
        series = {}
        for name, cell in result.get("cells", {}).items():
            traj = cell.get("trajectory_mean", {}).get(metric)
            if traj is None:
                continue
            mean = np.asarray(traj, np.float64)
            ci = cell.get("trajectory_ci", {}).get(metric)
            half = (
                np.asarray(ci, np.float64)
                if ci is not None
                else np.zeros_like(mean)
            )
            series[name] = (mean, half)
        return series
    # live CampaignResult
    return {
        c.name: c.trajectory(metric)
        for c in result.cells
        if metric in c.metrics
    }


def plot_trajectories(
    result: Any,
    metric: str = "theta_mse",
    *,
    out_path: str,
    cells: list[str] | None = None,
    title: str | None = None,
    logy: bool = False,
) -> str | None:
    """One line (+ CI band) per campaign cell; returns the written path.

    ``result`` is a CampaignResult, a campaign-JSON dict, or a path to
    one. ``cells`` filters (and orders) the plotted cell names. Returns
    ``None`` when matplotlib is unavailable or no cell carries ``metric``.
    """
    if not have_matplotlib():
        return None
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    series = _cell_series(result, metric)
    if cells is not None:
        series = {n: series[n] for n in cells if n in series}
    if not series:
        return None

    fig, ax = plt.subplots(figsize=(7, 4.2))
    for name, (mean, half) in series.items():
        rounds = np.arange(1, len(mean) + 1)
        (line,) = ax.plot(rounds, mean, label=name, linewidth=1.4)
        if np.any(half > 0):
            ax.fill_between(
                rounds, mean - half, mean + half,
                color=line.get_color(), alpha=0.18, linewidth=0,
            )
    ax.set_xlabel("round")
    ax.set_ylabel(metric)
    if logy:
        ax.set_yscale("log")
    if title:
        ax.set_title(title)
    ax.legend(fontsize=7, ncol=2)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    fig.savefig(out_path, dpi=140)
    plt.close(fig)
    return out_path


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--metric", default="theta_mse")
    ap.add_argument("--out", default=None)
    ap.add_argument("--logy", action="store_true")
    a = ap.parse_args()
    out = a.out or a.json_path.rsplit(".", 1)[0] + f"_{a.metric}.png"
    path = plot_trajectories(a.json_path, a.metric, out_path=out, logy=a.logy)
    print(path or "matplotlib unavailable; no plot written")
