"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark, then the
roofline table from the dry-run reports (if present).

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer FL rounds")
    ap.add_argument("--out", default="reports/bench_results.json")
    args, _ = ap.parse_known_args()

    rounds = 25 if args.quick else None

    from . import fig3_dynamic_b, fig4_clients_privacy, table1_byzantine
    from . import fig_async_staleness, fig_privacy_amplification
    from . import fig_campaign_throughput, fig_streaming_clients
    from . import fig_bits_frontier, fig_tree_throughput
    from . import theorem_rates, kernels_micro, roofline

    results = {}
    print("name,us_per_call,derived")
    print("# --- Theorem validation (Thm 1.3 / Thm 2) ---")
    results["theorems"] = theorem_rates.main()
    print("# --- Kernel microbenchmarks ---")
    results["kernels"] = kernels_micro.main()
    print("# --- Fig. 3: dynamic vs fixed vs oracle b ---")
    results["fig3"] = fig3_dynamic_b.main(rounds)
    print("# --- Fig. 4: clients / privacy sweeps ---")
    results["fig4"] = fig4_clients_privacy.main(rounds)
    print("# --- Table I: Byzantine attack grid (10% malicious) ---")
    results["table1"] = table1_byzantine.main(rounds)
    print("# --- Async staleness: buffer x decay x byz_frac stragglers ---")
    results["fig_async"] = fig_async_staleness.main(rounds)
    print("# --- Privacy amplification: participation x eps x aggregator ---")
    results["fig_privacy"] = fig_privacy_amplification.main(rounds)
    print("# --- Campaign throughput: cells/sec vs virtual device count ---")
    results["fig_throughput"] = fig_campaign_throughput.main(rounds)
    print("# --- Streaming clients: dense vs chunked vs sharded M-sweep ---")
    results["fig_streaming"] = fig_streaming_clients.main(
        m_grid=(1_000, 10_000, 100_000) if args.quick else None
    )
    print("# --- Bits frontier: wire_bits x byz_frac x eps grid ---")
    results["fig_bits"] = fig_bits_frontier.main(rounds)
    print("# --- Tree throughput: clients/sec vs edge count ---")
    # --quick runs the reduced (smoke) grid: smaller M, fewer edge counts
    results["fig_tree"] = fig_tree_throughput.main(rounds, smoke=args.quick)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# results written to {args.out}")

    print("# --- Roofline (from dry-run reports) ---")
    roofline.main()


if __name__ == "__main__":
    main()
