"""Theorem-level microbenchmarks: Thm 1.3 O(1/M) transmission error decay
and Thm 2 Byzantine deviation vs the 2*beta*||b|| bound."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import emit

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import probit_plus_from_updates, stochastic_binarize, probit_plus_aggregate, flip_codes  # noqa: E402


def main() -> dict:
    key = jax.random.PRNGKey(0)
    d = 4096
    theta = 0.02 * jax.random.normal(key, (d,))
    b = jnp.full((d,), 0.05)
    out: dict = {"error_vs_M": {}, "byzantine": {}}

    for m in (4, 16, 64, 256):
        upd = jnp.tile(theta[None], (m, 1))
        t0 = time.time()
        keys = jax.random.split(jax.random.fold_in(key, m), 100)
        errs = jax.vmap(
            lambda k: jnp.sum((probit_plus_from_updates(k, upd, b) - theta) ** 2)
        )(keys)
        measured = float(jnp.mean(errs))
        predicted = float(jnp.sum(b**2 - theta**2) / m)
        out["error_vs_M"][m] = {"measured": measured, "predicted": predicted}
        emit(
            f"thm1_error_M{m}",
            (time.time() - t0) / 100 * 1e6,
            f"measured={measured:.4f};predicted={predicted:.4f};ratio={measured/predicted:.3f}",
        )

    m = 64
    upd = theta + 0.01 * jax.random.normal(jax.random.fold_in(key, 9), (m, d))
    for beta in (0.1, 0.3):
        n_byz = int(m * beta)
        t0 = time.time()
        keys = jax.random.split(jax.random.fold_in(key, n_byz), 100)

        def est(k, attacked):
            ks = jax.random.split(k, m)
            codes = jax.vmap(stochastic_binarize, in_axes=(0, 0, None))(ks, upd, b)
            if attacked:
                codes = flip_codes(codes, n_byz)
            return probit_plus_aggregate(codes, b)

        clean = jnp.mean(jax.vmap(lambda k: est(k, False))(keys), 0)
        evil = jnp.mean(jax.vmap(lambda k: est(k, True))(keys), 0)
        dev = float(jnp.linalg.norm(clean - evil))
        bound = 2 * beta * float(jnp.linalg.norm(b))
        out["byzantine"][beta] = {"deviation": dev, "bound": bound}
        emit(
            f"thm2_byz_beta{beta}",
            (time.time() - t0) / 200 * 1e6,
            f"deviation={dev:.4f};bound={bound:.4f};tight={dev/bound:.3f}",
        )
    return out


if __name__ == "__main__":
    main()
