"""Roofline table formatter: reads the dry-run JSON reports and prints the
per-(arch x shape x mesh) roofline terms + bottleneck + MODEL_FLOPS ratio,
plus the packed-wire kernel roofline from ``bench_results.json`` (written
by ``benchmarks.kernels_micro``): per stage, the bytes it must move, the
achieved bytes/s, and the fraction of the measured memcpy bandwidth bound
— per backend and dispatch engine, so an interpret-mode number can never
read as a kernel result.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens processed:
  train_4k: global_batch*seq*(1+local recompute)  — we report plain 6ND
  prefill:  2*N*D (forward only)
  decode:   2*N_active per token * batch
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models.config import SHAPES  # noqa: E402


def model_flops(rep: dict) -> float:
    shape = SHAPES[rep["shape"]]
    n = rep.get("n_active_params") or 0
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_reports(directory: str = "reports") -> list[dict]:
    reps = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        r = json.load(open(f))
        if isinstance(r, dict) and "arch" in r:  # skip non-dryrun JSONs
            reps.append(r)
    return reps


def load_wire_reports(directory: str = "reports") -> list[dict]:
    reps = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        r = json.load(open(f))
        if isinstance(r, dict) and "roofline" in r and "meta" in r:
            reps.append(r)
    return reps


def wire_roofline(directory: str = "reports") -> None:
    """Packed-wire stage roofline (from ``kernels_micro``'s report JSON).

    ``frac`` ~ 1 means the stage runs at the measured streaming-bandwidth
    bound — memory-bound, the best a 1-bit wire can do; a small ``frac``
    means compute/launch overhead dominates and fusion should help.
    """
    reps = load_wire_reports(directory)
    if not reps:
        print(
            "no wire-roofline reports found — run: "
            "python -m benchmarks.kernels_micro"
        )
        return
    for r in reps:
        meta, roof = r["meta"], r["roofline"]
        print(
            f"\npacked-wire roofline: backend={meta['backend']} "
            f"engine={meta['dispatch_engine']} interpret={meta['interpret']} "
            f"n={meta['n']} M={meta['m']} "
            f"memcpy_bound={roof['memcpy_bound_gbs']:.2f} GB/s"
        )
        hdr = (
            f"{'stage':<18} {'us':>12} {'bytes':>14} "
            f"{'achieved GB/s':>14} {'frac of bound':>14}"
        )
        print(hdr)
        print("-" * len(hdr))
        for name, s in roof["stages"].items():
            print(
                f"{name:<18} {s['us']:>12.1f} {s['bytes']:>14d} "
                f"{s['achieved_gbs']:>14.3f} {s['frac_of_bound']:>14.3f}"
            )
        ratio = r["kernels"].get("kernel_vs_jax_ratio")
        if ratio is not None:
            print(f"kernel/pure-JAX pipeline ratio: {ratio:.2f}x")


def main(directory: str = "reports") -> None:
    wire_roofline(directory)
    reps = load_reports(directory)
    if not reps:
        print("no dry-run reports found — run: python -m repro.launch.dryrun --all --out reports/")
        return
    hdr = (
        f"{'arch':<22} {'shape':<12} {'mesh':<8} {'variant':<10} {'t_comp(s)':>10} {'t_mem(s)':>10} "
        f"{'t_coll(s)':>10} {'bottleneck':<11} {'useful%':>8} {'peakGiB':>8}"
    )
    print(hdr)
    print("-" * len(hdr))
    for r in reps:
        var = r.get("variant", "baseline")
        if r.get("status") == "skipped":
            print(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} {var:<10} {'skip: ' + r['reason']}")
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} {var:<10} ERROR {r.get('error','')[:60]}")
            continue
        n_dev = 512 if r["mesh"] == "2x16x16" else 256
        mf = model_flops(r) / n_dev
        useful = 100.0 * mf / max(r["flops_per_device"], 1.0)
        peak = r.get("peak_bytes_per_device", 0) / 2**30
        # prefer post-fusion HLO bytes x loop correction for the memory term
        # (older reports stored pre-fusion logical bytes in t_memory_s)
        t_mem = r["t_memory_s"]
        if "hlo_bytes_per_device" in r and "loop_correction_rho" in r:
            from repro.launch.analysis import HBM_BW

            t_mem = r["hlo_bytes_per_device"] * r["loop_correction_rho"] / HBM_BW
        tc, tm, tl = r["t_compute_s"], t_mem, r["t_collective_s"]
        bott = max(("compute", tc), ("memory", tm), ("collective", tl), key=lambda kv: kv[1])[0]
        print(
            f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} {var:<10} "
            f"{tc:>10.4f} {tm:>10.4f} {tl:>10.4f} "
            f"{bott:<11} {useful:>7.1f}% {peak:>8.2f}"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "reports")
