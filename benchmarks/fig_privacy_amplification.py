"""Beyond-paper figure: accuracy vs *reported* DP budget under
amplification by subsampling.

The mechanism is unchanged by the accountant — a (participation x
dp_epsilon x aggregator) campaign grid measures accuracy once, and the
privacy ledger then prices the same runs two ways: the conservative
``basic`` composition (what the runtime reported before the ledger) and
the ``subsampled`` accountant, where a round sampling clients at rate
``q`` costs only ``ln(1 + q*(e^eps - 1)) < eps``. The gap between the two
budgets at equal accuracy is the figure's point: partial participation
buys reported privacy for free.

Every cell lands in its own execution group (participation shapes the
cohort, eps the DP branch, the aggregator the wire), so this exercises
the campaign engine's grouped fallback; the ``dp_accountant`` field
deliberately does NOT split groups (``repro.sim.ACCOUNTING_FIELDS``).

``main`` writes the campaign JSON artifact — including each cell's
cumulative ``eps_spent`` trajectory under the subsampled accountant — to
``reports/fig_privacy_amplification.json`` (uploaded by the CI ``slow``
job next to the other campaign artifacts) and emits per-cell rows with
both budgets. Tier-1 keeps a fast smoke path over a tiny grid at 2
rounds (``tests/test_privacy_ledger.py``) via the ``participations`` /
``epsilons`` / ``aggregators`` / ``n_clients`` parameters.
"""

from __future__ import annotations

import os
from typing import Sequence

from .common import ROUNDS, campaign_task, emit  # sets sys.path first

from repro.sim import CampaignSpec, run_campaign  # noqa: E402

N_CLIENTS = 20
PARTICIPATIONS = (0.25, 0.5, 1.0)
EPSILONS = (0.1, 1.0)
AGGREGATORS = ("probit_plus", "signsgd_mv")


def fig_privacy_spec(
    rounds: int | None = None,
    participations: Sequence[float] = PARTICIPATIONS,
    epsilons: Sequence[float] = EPSILONS,
    aggregators: Sequence[str] = AGGREGATORS,
    n_clients: int = N_CLIENTS,
    seeds: Sequence[int] = (0, 1, 2),
) -> CampaignSpec:
    """The (participation x eps x aggregator) amplification sweep."""
    return CampaignSpec.from_grid(
        base=dict(
            n_clients=n_clients,
            rounds=rounds or ROUNDS,
            local_epochs=2,
            dp_accountant="subsampled",
        ),
        axes={
            "participation": tuple(participations),
            "dp_epsilon": tuple(epsilons),
            "aggregator": tuple(aggregators),
        },
        seeds=tuple(seeds),
    )


def main(rounds: int | None = None, out: str | None = None) -> dict:
    spec = fig_privacy_spec(rounds)
    result = run_campaign(spec, campaign_task, with_acc=True)
    rows = {name: us for name, us, _ in result.emit_rows("fig_priv")}
    summary: dict = {}
    for cell_spec in spec.cells:
        cfg = spec.config(cell_spec)
        cell = result.cell(cell_spec.name)
        acc, acc_ci = cell.final("acc")
        led = cfg.ledger()
        eps_sub = led.eps_at(cfg.rounds, "subsampled")
        eps_basic = led.eps_at(cfg.rounds, "basic")
        assert abs(cell.eps_spent() - eps_sub) < 1e-9  # JSON carries the same budget
        summary[cell_spec.name] = {
            "acc": acc,
            "acc_ci": acc_ci,
            "q": cfg.sampling_rate,
            "eps_subsampled": eps_sub,
            "eps_basic": eps_basic,
            "amplification_gain": eps_basic - eps_sub,
        }
        emit(
            f"fig_priv_{cell_spec.name}",
            rows[f"fig_priv_{cell_spec.name}"],
            f"acc={acc:.4f};eps_sub={eps_sub:.4f};eps_basic={eps_basic:.4f}",
        )
    path = out or os.path.join(
        os.path.dirname(__file__), "..", "reports", "fig_privacy_amplification.json"
    )
    result.save(path)
    emit("fig_priv_artifact", result.wall_s * 1e6, path)
    return summary


if __name__ == "__main__":
    main()
