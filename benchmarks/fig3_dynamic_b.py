"""Paper Fig. 3: training with dynamic vs fixed vs oracle quantization
parameter b (Byzantine- and DP-free, as in the paper's ablation).

Declared as a 3-cell ``CampaignSpec`` over the ``b_mode`` axis. ``b_mode``
shapes the compiled program (oracle computes a per-coordinate max), so the
planner lowers this to one program per mode, each scanned over rounds and
AOT-compiled through the process-wide cache — still one declaration, no
per-cell Python driver::

    plan = plan_campaign(fig3_spec(rounds))     # 3 cells -> 3 programs
    result = run_campaign(fig3_spec(rounds), common.campaign_task)
    result.cell("dynamic").metrics["b"]   # (n_seeds, rounds) b trajectory
"""

from __future__ import annotations

from .common import ROUNDS, campaign_task, emit  # sets sys.path first

from repro.sim import CampaignSpec, CellSpec, plan_campaign, run_campaign  # noqa: E402

MODES = ("dynamic", "fixed", "oracle")


def fig3_spec(rounds: int | None = None) -> CampaignSpec:
    return CampaignSpec(
        base=dict(
            n_clients=20, rounds=rounds or ROUNDS, local_epochs=2,
            aggregator="probit_plus",
        ),
        cells=tuple(CellSpec(mode, {"b_mode": mode}) for mode in MODES),
        seeds=(0,),
    )


def main(rounds: int | None = None) -> dict:
    spec = fig3_spec(rounds)
    result = run_campaign(spec, campaign_task, plan=plan_campaign(spec))
    out = {}
    for name, us, _derived in result.emit_rows("fig3_b"):
        cell = result.cell(name.removeprefix("fig3_b_"))
        acc = float(cell.metrics["acc"][0, -1])
        b_final = float(cell.metrics["b"][0, -1])
        out[cell.name] = {"acc": acc, "b_final": b_final}
        emit(name, us, f"acc={acc:.4f};b_final={b_final:.5f}")
    return out


if __name__ == "__main__":
    main()
