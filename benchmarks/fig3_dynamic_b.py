"""Paper Fig. 3: training with dynamic vs fixed vs oracle quantization
parameter b (Byzantine- and DP-free, as in the paper's ablation)."""

from __future__ import annotations

import time

from .common import emit, run_fl


def main(rounds: int | None = None) -> dict:
    out = {}
    for mode in ("dynamic", "fixed", "oracle"):
        t0 = time.time()
        sim = run_fl(20, rounds, aggregator="probit_plus", b_mode=mode)
        acc = sim.history[-1]["acc"]
        out[mode] = {"acc": acc, "b_final": sim.history[-1]["b"]}
        emit(
            f"fig3_b_{mode}",
            (time.time() - t0) / sim.cfg.rounds * 1e6,
            f"acc={acc:.4f};b_final={sim.history[-1]['b']:.5f}",
        )
    return out


if __name__ == "__main__":
    main()
