"""Bits frontier: wire_bits x byz_frac x eps through the campaign engine.

The PR-9 capstone grid. Every cell is the same classification task under
the PRoBit+ protocol at a different wire width k in {1, 2, 4} — the k-bit
plane-major wire with the L-level count MLE — crossed with the paper's
two stressors: a Byzantine cohort fraction (Gaussian payload attack) and
a per-round DP budget (b-floor margin at k=1, L-level randomized
response at k>1). The frontier the JSON captures is
*uplink-bytes-per-round vs aggregation error*: k buys accuracy (step
variance shrinks as 1/(2^k-1)^2) at linearly more bytes, and the
stressors move each point.

Acceptance line (asserted here, gated by the nightly slow lane): in the
clean corner — ``eps=0, byz_frac=0`` — the k=2 cell's trailing theta-MSE
must be strictly below the k=1 cell's; the 2-bit grid is a strict
refinement of the paper's 1-bit wire, so anything else is a wire bug.

  PYTHONPATH=src python -m benchmarks.fig_bits_frontier [--rounds R]
"""

from __future__ import annotations

import argparse
import json
import os

BITS_GRID = (1, 2, 4)
BYZ_GRID = (0.0, 0.1)
EPS_GRID = (0.0, 0.5)
ROUNDS = int(os.environ.get("PROBIT_BENCH_ROUNDS", "60")) // 2 or 1
SEEDS = (0, 1, 2)
N_CLIENTS = 20
TAIL = 5  # trailing rounds averaged for the frontier point

REPORT = os.path.join(
    os.path.dirname(__file__), "..", "reports", "fig_bits_frontier.json"
)


def frontier_spec(rounds: int | None = None):
    """The bits x byz_frac x eps grid as one campaign spec.

    ``byz_frac`` needs an attack to bite — Byzantine cells run the
    Gaussian payload attack (a pre-quantization delta corruption, valid
    at every wire width; wire-level bit flips are a separate k=1-only
    axis). ``attack`` is a traced vmap field, so the clean and attacked
    cells of one (bits, eps) pair still share a compiled program.
    """
    from repro.sim import CampaignSpec, CellSpec

    cells = []
    for bits in BITS_GRID:
        for byz in BYZ_GRID:
            for eps in EPS_GRID:
                cells.append(
                    CellSpec(
                        name=f"bits={bits}|byz={byz}|eps={eps}",
                        overrides=dict(
                            wire_bits=bits,
                            byz_frac=byz,
                            attack="gaussian" if byz > 0 else "none",
                            dp_epsilon=eps,
                        ),
                    )
                )
    return CampaignSpec(
        base=dict(
            n_clients=N_CLIENTS,
            rounds=rounds or ROUNDS,
            local_epochs=2,
            aggregator="probit_plus",
        ),
        cells=tuple(cells),
        seeds=SEEDS,
    )


def main(rounds: int | None = None) -> dict:
    from .common import campaign_task, emit
    from .plots import plot_trajectories
    from repro.core.quantizer import wire_bytes
    from repro.sim import run_campaign

    spec = frontier_spec(rounds)
    result = run_campaign(spec, campaign_task, with_acc=False)

    # Uplink cost of one cohort round at each width, for the frontier's
    # byte axis (model dim of the benchmark MLP task).
    task = campaign_task(spec.config(spec.cells[0]))
    import jax

    d = sum(int(leaf.size) for leaf in jax.tree.leaves(task.init_params))

    out: dict = {
        "rounds": rounds or ROUNDS,
        "seeds": list(SEEDS),
        "n_clients": N_CLIENTS,
        "model_dim": d,
        "tail_rounds": TAIL,
        "frontier": [],
    }
    for cell in result.cells:
        ov = cell.overrides
        mse_mean, mse_ci = cell.final("theta_mse")
        point = {
            "bits": ov["wire_bits"],
            "byz_frac": ov["byz_frac"],
            "eps": ov["dp_epsilon"],
            "uplink_bytes_per_client": wire_bytes(d, ov["wire_bits"]),
            "theta_mse_final": mse_mean,
            "theta_mse_final_ci": mse_ci,
            "theta_mse_tail": cell.mean_over_rounds("theta_mse", tail=TAIL),
        }
        out["frontier"].append(point)

    def tail_mse(bits: int, byz: float, eps: float) -> float:
        return next(
            p["theta_mse_tail"]
            for p in out["frontier"]
            if p["bits"] == bits and p["byz_frac"] == byz and p["eps"] == eps
        )

    # The acceptance line: clean-corner MSE strictly improves 1 -> 2 bits.
    clean = {k: tail_mse(k, 0.0, 0.0) for k in BITS_GRID}
    out["clean_tail_mse"] = clean
    out["k2_below_k1"] = bool(clean[2] < clean[1])
    assert out["k2_below_k1"], (
        f"k=2 wire did not beat k=1 at eps=0, byz_frac=0: {clean}"
    )

    os.makedirs(os.path.dirname(REPORT), exist_ok=True)
    with open(REPORT, "w") as f:
        json.dump(out, f, indent=1)
    out["report"] = os.path.normpath(REPORT)

    png = plot_trajectories(
        result,
        "theta_mse",
        out_path=REPORT.rsplit(".", 1)[0] + "_theta_mse.png",
        cells=[f"bits={k}|byz=0.0|eps=0.0" for k in BITS_GRID],
        title="PRoBit+ aggregation error vs wire width (clean corner)",
        logy=True,
    )
    out["plot"] = png and os.path.normpath(png)

    for k in BITS_GRID:
        emit(
            f"bits_frontier_k{k}",
            1e6 * clean[k],
            f"tail_mse={clean[k]:.3e};bytes={wire_bytes(d, k)}",
        )
    emit(
        "bits_frontier_gate",
        1e6 * clean[2],
        f"k2_below_k1={out['k2_below_k1']}",
    )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    res = main(args.rounds)
    print(f"# frontier written to {res['report']}")
