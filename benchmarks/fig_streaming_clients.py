"""Streaming client-chunk scaling: million-client PRoBit+ rounds on CPU.

The ROADMAP's "streaming client aggregation" item, measured: an M-sweep
up to 1e6 clients where each cell runs the chunked round
(``client_chunk > 0`` + ``stateless_clients``) through the campaign
engine, so resident memory stays O(chunk * d/8) instead of O(M * d/8).
Per M the figure reports

* ``clients_per_sec`` for the **dense** round (only up to ``DENSE_MAX`` —
  beyond that the (M, d) update matrix stops fitting comfortably),
  the **streaming** round, and (at the largest M) the **sharded
  streaming** round, where the chunk's client axis is split over
  virtual CPU devices and vote counts are psum-reduced;
* ``peak_bytes_est`` — the executor's per-device resident-wire estimate
  (``sim.campaign`` group stats) for the streaming vs dense path;
* ``theta_mse`` averaged over rounds.

With b fixed above the update range the PRoBit+ estimate is unbiased and
Theorem 1 gives per-coordinate variance ~ b^2 / M, so the log-log
theta_mse slope across the sweep must sit in ``SLOPE_WINDOW`` (~ -1);
``main`` asserts this — it is the acceptance line for the streaming
execution path at scales the dense round cannot reach.

The sharded point runs in a **subprocess** (the
``--xla_force_host_platform_device_count`` flag must be set before jax
initializes); the child re-enters this module with ``--inner`` and
prints one JSON line, mirroring ``fig_campaign_throughput``.

  PYTHONPATH=src python -m benchmarks.fig_streaming_clients
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import resource
import subprocess
import sys
import time

import numpy as np

M_GRID = tuple(
    int(m)
    for m in os.environ.get(
        "PROBIT_STREAM_M_GRID", "1000,10000,100000,1000000"
    ).split(",")
)
DENSE_MAX = 10_000  # largest M the dense (M, d) round is run at
CHUNK = 4096  # streaming client-chunk size (cohort rows resident at once)
PACK = 512  # pack_chunk: d padded to 512 -> 64-byte wire rows
ROUNDS = int(os.environ.get("PROBIT_STREAM_ROUNDS", "2"))
SHARD_DEVICES = int(os.environ.get("PROBIT_STREAM_DEVICES", "4"))
SLOPE_WINDOW = (-1.35, -0.65)

DIM = 8
PER_CLIENT = 2
HIDDEN = 16


@functools.lru_cache(maxsize=None)
def stream_task(m: int, seed: int = 0):
    """Synthetic per-client data at cross-device scale.

    Hyperplane labels over Gaussian features with a per-client mean
    shift (mild heterogeneity); at M=1e6 the arrays are ~72 MB — the
    data fits, it is the dense update matrix that does not.
    """
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(DIM).astype(np.float32)

    def draw(rows, per, shift):
        x = rng.standard_normal((rows, per, DIM), dtype=np.float32)
        if shift:
            x += 0.3 * rng.standard_normal((rows, 1, 1)).astype(np.float32)
        y = (x @ w > 0).astype(np.int32)
        return x, y

    cx, cy = draw(m, PER_CLIENT, shift=True)
    tx, ty = draw(1, 512, shift=False)
    return cx, cy, {"x": tx[0], "y": ty[0]}


def _overrides(m: int, stream: bool) -> dict:
    ov = dict(n_clients=m)
    if stream:
        ov.update(client_chunk=min(CHUNK, m), stateless_clients=True)
    return ov


def _base(rounds: int) -> dict:
    # Fixed b above the update range -> unbiased compressor (Theorem 1),
    # so theta_mse is pure O(1/M) aggregation error.
    return dict(
        rounds=rounds,
        local_epochs=1,
        batch_size=PER_CLIENT,
        lr=0.01,
        b_mode="fixed",
        b_init=0.1,
        pack_chunk=PACK,
    )


def _init_params():
    import jax

    from repro.models.vision import init_mlp

    return init_mlp(jax.random.PRNGKey(0), in_dim=DIM, hidden=HIDDEN, classes=2)


def _task_fn(cfg):
    from repro.models.vision import accuracy, mlp_logits, xent_loss
    from repro.sim import Task

    cx, cy, test = stream_task(cfg.n_clients)
    return Task(
        init_params=_init_params(),
        loss_fn=functools.partial(xent_loss, mlp_logits),
        acc_fn=functools.partial(accuracy, mlp_logits),
        client_x=cx,
        client_y=cy,
        test=test,
    )


def run_cell(m: int, rounds: int, stream: bool) -> dict:
    """One single-cell campaign at M clients; timed on the warm rerun."""
    from repro.sim import CampaignSpec, CellSpec, run_campaign
    from repro.sim.plan import CompileCache, plan_campaign

    spec = CampaignSpec(
        base=_base(rounds),
        cells=(CellSpec(f"M={m}", _overrides(m, stream)),),
        seeds=(0,),
    )
    # The dense baseline must stay dense: past STREAM_M_THRESHOLD the
    # default planner would silently stream the cell.
    plan = None if stream else plan_campaign(spec, stream_threshold=1 << 62)
    cache = CompileCache()
    run_campaign(spec, _task_fn, plan=plan, with_acc=False, compile_cache=cache)
    t0 = time.perf_counter()
    result = run_campaign(
        spec, _task_fn, plan=plan, with_acc=False, compile_cache=cache
    )
    wall = time.perf_counter() - t0
    g = result.groups[0]
    return {
        "m": m,
        "mode": "stream" if stream else "dense",
        "clients_per_sec": m * rounds / wall,
        "wall_s": wall,
        "theta_mse": float(np.mean(result.cells[0].metrics["theta_mse"])),
        "client_chunk": g["client_chunk"],
        "peak_bytes_est": g["peak_bytes_est"],
    }


def run_inner(m: int, rounds: int) -> dict:
    """Sharded streaming round (child entry point): the chunk's client
    axis is split over this process's devices, counts psum-reduced."""
    import jax

    from repro.fl import FLConfig
    from repro.fl import rounds as R
    from repro.models.vision import accuracy, mlp_logits, xent_loss

    cx, cy, test = stream_task(m)
    cfg = FLConfig(
        **_base(rounds),
        **_overrides(m, stream=True),
        stream_shard=True,
    )
    ctx = R.make_context(
        cfg,
        _init_params(),
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx,
        cy,
        test,
    )
    params = R.cell_params(cfg)
    key = jax.random.PRNGKey(0)
    state = R.init_run_state(ctx)
    jax.block_until_ready(
        R.run_rounds(ctx, params, key, state, with_acc=False)
    )
    t0 = time.perf_counter()
    _, traj = R.run_rounds(ctx, params, key, state, with_acc=False)
    jax.block_until_ready(traj)
    wall = time.perf_counter() - t0
    return {
        "m": m,
        "mode": "stream_sharded",
        "n_devices": jax.device_count(),
        "clients_per_sec": m * rounds / wall,
        "wall_s": wall,
        "theta_mse": float(np.mean(traj["theta_mse"])),
        "maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
    }


def run_sharded(m: int, rounds: int, n_dev: int) -> dict:
    env = dict(os.environ)
    inherited = [
        f
        for f in env.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    ]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_dev}", *inherited]
    )
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    cmd = [
        sys.executable, "-m", "benchmarks.fig_streaming_clients",
        "--inner", "--m", str(m), "--rounds", str(rounds),
    ]
    res = subprocess.run(
        cmd, capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if res.returncode != 0:
        raise RuntimeError(f"sharded child failed:\n{res.stderr[-3000:]}")
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert payload["n_devices"] == n_dev, payload
    return payload


def main(rounds: int | None = None, m_grid=None) -> dict:
    from .common import emit

    rounds = ROUNDS if rounds is None else min(rounds, ROUNDS)
    m_grid = tuple(m_grid or M_GRID)
    out: dict = {"rounds": rounds, "chunk": CHUNK, "sweep": {}}

    for m in m_grid:
        row: dict = {"stream": run_cell(m, rounds, stream=True)}
        if m <= DENSE_MAX:
            row["dense"] = run_cell(m, rounds, stream=False)
        out["sweep"][m] = row
        s = row["stream"]
        mem = (
            f";peak_stream={s['peak_bytes_est']};"
            f"peak_dense={row['dense']['peak_bytes_est']}"
            if "dense" in row
            else f";peak_stream={s['peak_bytes_est']}"
        )
        emit(
            f"streaming_clients_M{m}",
            1e6 / s["clients_per_sec"],
            f"clients_per_sec={s['clients_per_sec']:.0f};"
            + (
                f"dense_cps={row['dense']['clients_per_sec']:.0f}"
                if "dense" in row
                else "dense_cps=skipped"
            )
            + f";theta_mse={s['theta_mse']:.3e}" + mem,
        )

    out["sharded"] = run_sharded(max(m_grid), rounds, SHARD_DEVICES)
    emit(
        f"streaming_clients_sharded_M{max(m_grid)}",
        1e6 / out["sharded"]["clients_per_sec"],
        f"clients_per_sec={out['sharded']['clients_per_sec']:.0f};"
        f"devices={out['sharded']['n_devices']};"
        f"maxrss_mb={out['sharded']['maxrss_mb']:.0f}",
    )
    out["maxrss_mb"] = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024

    ms = sorted(out["sweep"])
    mses = [out["sweep"][m]["stream"]["theta_mse"] for m in ms]
    if len(ms) >= 2:
        slope = float(np.polyfit(np.log(ms), np.log(mses), 1)[0])
        lo, hi = SLOPE_WINDOW
        out["slope"] = slope
        out["slope_ok"] = bool(lo <= slope <= hi)
        emit(
            "streaming_clients_slope",
            0.0,
            f"slope={slope:.3f};window=[{lo},{hi}];ok={out['slope_ok']}",
        )

    report = os.path.join(
        os.path.dirname(__file__), "..", "reports", "fig_streaming_clients.json"
    )
    os.makedirs(os.path.dirname(report), exist_ok=True)
    with open(report, "w") as f:
        json.dump(out, f, indent=1, default=str)

    if len(ms) >= 2:
        assert out["slope_ok"], (
            f"theta_mse log-log slope {out['slope']:.3f} outside "
            f"{SLOPE_WINDOW} — O(1/M) decay broken: {dict(zip(ms, mses))}"
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    if args.inner:
        print(json.dumps(run_inner(args.m, args.rounds or ROUNDS), default=str))
    else:
        main(args.rounds)
