"""Kernel microbenchmarks: us/call of the Pallas paths (interpret mode on
this CPU container — wall numbers are for CI tracking, not TPU projection)
plus the analytic communication-compression ratios the kernels realize."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, timed

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.kernels import ops  # noqa: E402


def main(n: int = 262_144, m: int = 16) -> dict:
    key = jax.random.PRNGKey(0)
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.05)
    out: dict = {}

    us = timed(lambda: ops.stoch_quant_pack(key, delta, b), reps=10)
    ratio = 32.0  # fp32 -> 1 bit
    out["stoch_quant_pack"] = us
    emit("kernel_stoch_quant_pack", us, f"n={n};upload_compression={ratio:.0f}x")

    packed = jnp.stack(
        [ops.stoch_quant_pack(jax.random.fold_in(key, i), delta, b) for i in range(m)]
    )
    us = timed(lambda: ops.bit_aggregate(packed, b, n), reps=10)
    out["bit_aggregate"] = us
    hbm_ratio = 4.0 * m * n / (m * n / 8 + 4 * n)
    emit("kernel_bit_aggregate", us, f"M={m};hbm_read_reduction={hbm_ratio:.1f}x")

    w = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mom = jnp.zeros(n)
    us = timed(lambda: ops.prox_sgd(w, w * 0.9, g, mom, 0.01, 0.2, 0.5), reps=10)
    out["prox_sgd"] = us
    emit("kernel_prox_sgd", us, "fused_passes=1_vs_4")
    return out


if __name__ == "__main__":
    main()
