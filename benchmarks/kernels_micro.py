"""Kernel + pipeline microbenchmarks: us/call of the Pallas paths
(interpret mode on this CPU container — wall numbers are for CI tracking,
not TPU projection) plus the measured wire/memory traffic of the packed
aggregation pipeline vs the dense reference path."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, timed

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import build_pipeline, padded_dim, probit_plus_from_updates  # noqa: E402
from repro.core.quantizer import packed_counts  # noqa: E402
from repro.kernels import ops  # noqa: E402


def popcount_counts(n: int = 262_144, m: int = 256) -> dict:
    """Wire-count reduction: population_count vs unpack-and-sum.

    Both produce identical integer counts from the same (M, n/8) uint8
    wire; the popcount path transposes octets of client rows and reduces
    whole bytes, the reference path unpacks each bit to int8 first. The
    measured ratio is the satellite number for the streaming-aggregation
    PR (the count reduction runs once per client chunk there).
    """
    key = jax.random.PRNGKey(3)
    packed = jax.random.randint(key, (m, n // 8), 0, 256, jnp.uint8)
    out: dict = {}
    us_ref = None
    for label, use_pop in (("unpack", False), ("popcount", True)):
        run = jax.jit(lambda p, u=use_pop: packed_counts(p, use_popcount=u))
        us = timed(lambda: run(packed), reps=10)
        out[f"counts_{label}_us"] = us
        us_ref = us_ref or us
        emit(
            f"counts_{label}",
            us,
            f"M={m};n={n};speedup_vs_unpack={us_ref / us:.2f}x",
        )
    return out


def pipeline_traffic(n: int = 262_144, m: int = 16) -> dict:
    """End-to-end AggregatorPipeline: packed wire vs dense f32 codes.

    Reports the bytes each path moves for one aggregation round:
      * dense reference: (M, n) f32 code matrix read by the server
        -> 4 * M * n bytes (what the pre-pipeline runtime materialized);
      * dense int8 codes: M * n bytes (sign bytes, signSGD-style);
      * packed wire: (M, P) uint8, P = ceil(n/8 per alignment) -> ~M * n/8
        bytes — 8x below int8 codes, 32x below f32 codes.
    """
    key = jax.random.PRNGKey(0)
    deltas = 0.01 * jax.random.normal(key, (m, n))
    res = jnp.zeros((m, n), jnp.float32)
    b = jnp.float32(0.05)
    out: dict = {}

    dense_f32_bytes = 4 * m * n
    dense_i8_bytes = m * n

    for label, pipe, pad in [
        ("jax_packed", build_pipeline("probit_plus"), padded_dim(n)),
        ("kernel_packed", build_pipeline("probit_plus", use_kernels=True),
         ops.padded_len(n)),
    ]:
        run = jax.jit(lambda k, d, bb, r, p=pipe: p(k, d, bb, r)[0])
        us = timed(lambda: run(key, deltas, b, res), reps=10)
        wire_bytes = m * pad // 8  # (M, d_pad/8) uint8 — static, no re-run
        out[f"pipeline_{label}_us"] = us
        out[f"pipeline_{label}_wire_bytes"] = wire_bytes
        emit(
            f"pipeline_{label}",
            us,
            f"M={m};n={n};wire_bytes={wire_bytes}"
            f";vs_int8_codes={dense_i8_bytes / wire_bytes:.1f}x"
            f";vs_f32_codes={dense_f32_bytes / wire_bytes:.1f}x",
        )

    # dense reference path (f32 codes materialized, pre-pipeline behavior)
    bvec = jnp.full((n,), 0.05)
    dense = jax.jit(lambda k, d: probit_plus_from_updates(k, d, bvec))
    us = timed(lambda: dense(key, deltas), reps=10)
    out["pipeline_dense_reference_us"] = us
    emit(
        "pipeline_dense_reference",
        us,
        f"M={m};n={n};codes_bytes_f32={dense_f32_bytes}",
    )
    return out


def main(n: int = 262_144, m: int = 16) -> dict:
    key = jax.random.PRNGKey(0)
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.05)
    out: dict = {}

    us = timed(lambda: ops.stoch_quant_pack(key, delta, b), reps=10)
    ratio = 32.0  # fp32 -> 1 bit
    out["stoch_quant_pack"] = us
    emit("kernel_stoch_quant_pack", us, f"n={n};upload_compression={ratio:.0f}x")

    packed = jnp.stack(
        [ops.stoch_quant_pack(jax.random.fold_in(key, i), delta, b) for i in range(m)]
    )
    us = timed(lambda: ops.bit_aggregate(packed, b, n), reps=10)
    out["bit_aggregate"] = us
    hbm_ratio = 4.0 * m * n / (m * n / 8 + 4 * n)
    emit("kernel_bit_aggregate", us, f"M={m};hbm_read_reduction={hbm_ratio:.1f}x")

    w = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mom = jnp.zeros(n)
    us = timed(lambda: ops.prox_sgd(w, w * 0.9, g, mom, 0.01, 0.2, 0.5), reps=10)
    out["prox_sgd"] = us
    emit("kernel_prox_sgd", us, "fused_passes=1_vs_4")

    out.update(pipeline_traffic(n, m))
    out.update(popcount_counts(n))
    return out


if __name__ == "__main__":
    # Standalone entry writes the same artifact path as benchmarks.run so
    # the nightly job can upload kernel numbers without the full figure
    # sweep.
    import json

    results = {"kernels": main()}
    report = os.path.join(
        os.path.dirname(__file__), "..", "reports", "bench_results.json"
    )
    os.makedirs(os.path.dirname(report), exist_ok=True)
    with open(report, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# results written to {report}")
