"""Kernel + pipeline microbenchmarks with dispatch metadata and a roofline.

Times the ``use_kernels=True`` wire against the pure-JAX packed wire and
stamps *what actually ran* — backend, resolved dispatch engine, interpret
flag — into the report JSON, so an interpret-mode emulator number can
never masquerade as a kernel result again (a prior report did exactly
that: ~6.4 s interpret-mode Pallas recorded as the "kernel" pipeline vs
~56 ms pure-JAX).

Sections of ``reports/bench_results.json``:

* ``meta``    — backend, dispatch engine, interpret, problem size;
* ``kernels`` — the us/call numbers (same keys as before);
* ``roofline`` — a measured memcpy bandwidth bound plus, per stage, the
  bytes the stage must move, achieved bytes/s, and the achieved/bound
  fraction. A stage at fraction ~1 is memory-bound (the best a 1-bit wire
  can do); a small fraction means compute or launch overhead dominates.

Guard rails: when the kernel/pure-JAX pipeline ratio exceeds
``RATIO_THRESHOLD`` the script prints a ``::warning::`` line (picked up by
the nightly CI log); ``--smoke`` runs a small size and *fails* (exit 1) on
the same condition — the per-push regression gate for the dispatch policy.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

from .common import emit, timed

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import build_pipeline, padded_dim, probit_plus_from_updates  # noqa: E402
from repro.core.quantizer import packed_counts, wire_bytes  # noqa: E402
from repro.kernels import ops  # noqa: E402

# use_kernels=True must stay within this factor of the pure-JAX packed
# wire on every backend; beyond it the dispatch policy has regressed.
RATIO_THRESHOLD = 1.5


def report_meta(n: int, m: int, bits: int = 1) -> dict:
    engine = ops.resolve_engine()
    return {
        "backend": jax.default_backend(),
        "dispatch_engine": engine,
        "interpret": engine == "interpret",
        "n": n,
        "m": m,
        "wire_bits": bits,
    }


def memcpy_bound_gbs(nbytes: int = 1 << 26) -> float:
    """Measured streaming-bandwidth bound: GB/s of a jitted f32 a+1 copy
    (reads + writes ``nbytes`` each). Every wire stage below is held to
    this number, not a datasheet figure."""
    x = jnp.zeros(nbytes // 4, jnp.float32)
    run = jax.jit(lambda v: v + 1.0)
    us = timed(lambda: run(x), reps=10)
    return 2.0 * nbytes / (us * 1e-6) / 1e9


def _stage(us: float, nbytes: float, bound_gbs: float) -> dict:
    achieved = nbytes / (us * 1e-6) / 1e9
    return {
        "bytes": int(nbytes),
        "us": us,
        "achieved_gbs": achieved,
        "bound_gbs": bound_gbs,
        "frac_of_bound": achieved / bound_gbs if bound_gbs > 0 else 0.0,
    }


def popcount_counts(n: int = 262_144, m: int = 256) -> dict:
    """Wire-count reduction: population_count vs unpack-and-sum.

    Both produce identical integer counts from the same (M, n/8) uint8
    wire; the popcount path transposes octets of client rows and reduces
    whole bytes, the reference path unpacks each bit to int8 first. The
    in-kernel ``bit_aggregate`` vote count now rides the same popcount
    reduction (octet transpose + ``jax.lax.population_count``).
    """
    key = jax.random.PRNGKey(3)
    packed = jax.random.randint(key, (m, n // 8), 0, 256, jnp.uint8)
    out: dict = {}
    us_ref = None
    for label, use_pop in (("unpack", False), ("popcount", True)):
        run = jax.jit(lambda p, u=use_pop: packed_counts(p, use_popcount=u))
        us = timed(lambda: run(packed), reps=10)
        out[f"counts_{label}_us"] = us
        us_ref = us_ref or us
        emit(
            f"counts_{label}",
            us,
            f"M={m};n={n};speedup_vs_unpack={us_ref / us:.2f}x",
        )
    return out


def pipeline_traffic(n: int = 262_144, m: int = 16, bits: int = 1) -> dict:
    """End-to-end AggregatorPipeline: packed wire vs dense f32 codes.

    Reports the bytes each path moves for one aggregation round:
      * dense reference: (M, n) f32 code matrix read by the server
        -> 4 * M * n bytes (what the pre-pipeline runtime materialized);
      * dense int8 codes: M * n bytes (sign bytes, signSGD-style);
      * packed wire: (M, bits * P) uint8, P = ceil(n/8 per alignment) ->
        ~bits * M * n/8 bytes — 8x below int8 codes and 32x below f32
        codes at the paper's bits=1; uplink ratios come from the shared
        ``repro.core.quantizer.wire_bytes`` helper so this report can
        never drift from the actual wire.

    The kernel pipeline runs whatever engine the dispatch policy resolves
    for this backend (TPU -> Pallas, else the pure-JAX ref wire); the
    emitted ``kernel_vs_jax_ratio`` is the regression gate, at every
    ``bits`` (k > 1 routes both pipelines through the same chunked packer,
    so the ratio stays near 1 by construction).
    """
    key = jax.random.PRNGKey(0)
    deltas = 0.01 * jax.random.normal(key, (m, n))
    res = jnp.zeros((m, n), jnp.float32)
    b = jnp.float32(0.05)
    out: dict = {}

    dense_f32_bytes = 4 * m * n
    dense_i8_bytes = m * n

    for label, pipe, pad in [
        ("jax_packed", build_pipeline("probit_plus", wire_bits=bits),
         padded_dim(n)),
        ("kernel_packed",
         build_pipeline("probit_plus", use_kernels=True, wire_bits=bits),
         ops.padded_len(n)),
    ]:
        run = jax.jit(lambda k, d, bb, r, p=pipe: p(k, d, bb, r)[0])
        us = timed(lambda: run(key, deltas, b, res), reps=10)
        row_bytes = wire_bytes(n, bits, d_pad=pad)  # static, no re-run
        total_bytes = m * row_bytes
        out[f"pipeline_{label}_us"] = us
        out[f"pipeline_{label}_wire_bytes"] = total_bytes
        emit(
            f"pipeline_{label}",
            us,
            f"M={m};n={n};bits={bits};wire_bytes={total_bytes}"
            f";vs_int8_codes={dense_i8_bytes / total_bytes:.1f}x"
            f";vs_f32_codes={dense_f32_bytes / total_bytes:.1f}x",
        )

    ratio = out["pipeline_kernel_packed_us"] / out["pipeline_jax_packed_us"]
    out["kernel_vs_jax_ratio"] = ratio
    emit("kernel_vs_jax_ratio", ratio, f"threshold={RATIO_THRESHOLD}")

    # dense reference path (f32 codes materialized, pre-pipeline behavior)
    bvec = jnp.full((n,), 0.05)
    dense = jax.jit(lambda k, d: probit_plus_from_updates(k, d, bvec))
    us = timed(lambda: dense(key, deltas), reps=10)
    out["pipeline_dense_reference_us"] = us
    emit(
        "pipeline_dense_reference",
        us,
        f"M={m};n={n};codes_bytes_f32={dense_f32_bytes}",
    )
    return out


def roofline_stages(n: int, m: int, kernels: dict) -> dict:
    """Achieved-vs-bound bytes/s per wire stage, from the timings above.

    Traffic models (the *minimum* HBM bytes each stage must move):
      * stoch_quant:   read 4n delta + 4n b, write n/8 packed;
      * bit_aggregate: read M*n/8 wire + 4n b, write 4n theta;
      * counts:        read M*n/8 wire, write 4n counts;
      * pipelines:     compress of M rows + aggregate.
    """
    bound = memcpy_bound_gbs()
    per_client = 8.0 * n + n / 8.0
    agg = m * n / 8.0 + 8.0 * n
    stages = {
        "stoch_quant": _stage(kernels["stoch_quant_pack"], per_client, bound),
        "bit_aggregate": _stage(kernels["bit_aggregate"], agg, bound),
        "counts_popcount": _stage(
            kernels["counts_popcount_us"], 256 * n / 8.0 + 4.0 * n, bound
        ),
        "pipeline_kernel": _stage(
            kernels["pipeline_kernel_packed_us"], m * per_client + agg, bound
        ),
        "pipeline_jax": _stage(
            kernels["pipeline_jax_packed_us"], m * per_client + agg, bound
        ),
    }
    for name, s in stages.items():
        emit(
            f"roofline_{name}",
            s["us"],
            f"achieved={s['achieved_gbs']:.2f}GB/s"
            f";bound={s['bound_gbs']:.2f}GB/s"
            f";frac={s['frac_of_bound']:.3f}",
        )
    return {"memcpy_bound_gbs": bound, "stages": stages}


def main(n: int = 262_144, m: int = 16, bits: int = 1) -> dict:
    key = jax.random.PRNGKey(0)
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.05)
    out: dict = {}

    us = timed(lambda: ops.stoch_quant_pack(key, delta, b), reps=10)
    ratio = 4.0 * n / wire_bytes(n)  # fp32 -> 1 bit (the 1-bit kernel)
    out["stoch_quant_pack"] = us
    emit("kernel_stoch_quant_pack", us, f"n={n};upload_compression={ratio:.0f}x")

    packed = jnp.stack(
        [ops.stoch_quant_pack(jax.random.fold_in(key, i), delta, b) for i in range(m)]
    )
    us = timed(lambda: ops.bit_aggregate(packed, b, n), reps=10)
    out["bit_aggregate"] = us
    hbm_ratio = 4.0 * m * n / (m * n / 8 + 4 * n)
    emit("kernel_bit_aggregate", us, f"M={m};hbm_read_reduction={hbm_ratio:.1f}x")

    w = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mom = jnp.zeros(n)
    us = timed(lambda: ops.prox_sgd(w, w * 0.9, g, mom, 0.01, 0.2, 0.5), reps=10)
    out["prox_sgd"] = us
    emit("kernel_prox_sgd", us, "fused_passes=1_vs_4")

    out.update(pipeline_traffic(n, m, bits))
    out.update(popcount_counts(n, max(m, 256)))
    return out


def run(n: int, m: int, out_path: str | None, smoke: bool, bits: int = 1) -> int:
    kernels = main(n, m, bits)
    results = {
        "meta": report_meta(n, m, bits),
        "kernels": kernels,
        "roofline": roofline_stages(n, m, kernels),
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"# results written to {out_path}")
    ratio = kernels["kernel_vs_jax_ratio"]
    if ratio > RATIO_THRESHOLD:
        print(
            f"::warning::use_kernels=True pipeline is {ratio:.2f}x the "
            f"pure-JAX packed wire on {jax.default_backend()} "
            f"(engine={results['meta']['dispatch_engine']}, "
            f"threshold={RATIO_THRESHOLD}x) — dispatch policy regression?"
        )
        if smoke:
            return 1
    return 0


if __name__ == "__main__":
    # Standalone entry writes the same artifact path as benchmarks.run so
    # the nightly job can upload kernel numbers without the full figure
    # sweep; --smoke is the per-push dispatch-policy regression gate.
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--n", type=int, default=262_144)
    parser.add_argument("--m", type=int, default=16)
    parser.add_argument(
        "--bits",
        type=int,
        default=1,
        choices=(1, 2, 4),
        help="wire width for the pipeline cells (1 = the paper's wire; "
        "CI smoke also runs a k=2 cell)",
    )
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="small size, no artifact, exit 1 if kernel/jax ratio "
        f"exceeds {RATIO_THRESHOLD}x",
    )
    parser.add_argument(
        "--out",
        default=os.path.join(
            os.path.dirname(__file__), "..", "reports", "bench_results.json"
        ),
    )
    a = parser.parse_args()
    if a.smoke:
        a.n, a.m, a.out = 65_536, 8, None
    sys.exit(run(a.n, a.m, a.out, a.smoke, a.bits))
