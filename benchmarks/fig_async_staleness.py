"""Beyond-paper figure: buffered-async PRoBit+ under timing adversaries.

Sweeps the three knobs the paper's synchronous analysis cannot express —
server buffer size x staleness-decay x Byzantine fraction — under the
``straggler+sign_flip`` composite adversary (Byzantine clients upload a
sign-flipped delta AND withhold it so it sits in the buffer at maximal
staleness). The whole sweep is one ``CampaignSpec``: the staleness-decay
axis is traced (one vmapped program per (buffer, byz_frac) signature
group), so the grid compiles ``len(BUFFERS) * len(BYZ_FRACS)`` programs
for ``len(BUFFERS) * len(DECAYS) * len(BYZ_FRACS)`` cells.

Reads on the output: with decay 0 a withheld Byzantine vote keeps full
weight forever (theta-MSE grows with byz_frac); raising the decay
discounts exactly those frozen votes, which is the defense the
``tests/test_async_rounds.py`` regression pins down.

``main`` writes the campaign JSON artifact to
``reports/fig_async_staleness.json`` (the CI ``slow`` job uploads it next
to the statistical-suite artifacts) and emits per-cell summary rows.
"""

from __future__ import annotations

import os

from .common import ROUNDS, campaign_task, emit  # sets sys.path first

from repro.sim import CampaignSpec, run_campaign  # noqa: E402

N_CLIENTS = 10
BUFFERS = (5, 10)
DECAYS = (0.0, 0.5, 1.0)
BYZ_FRACS = (0.0, 0.1, 0.3)
LATENCY = 1.0


def fig_async_spec(rounds: int | None = None, seeds=(0, 1, 2)) -> CampaignSpec:
    """The buffer x decay x byz_frac straggler sweep as one campaign."""
    return CampaignSpec.from_grid(
        base=dict(
            n_clients=N_CLIENTS,
            rounds=rounds or ROUNDS,
            local_epochs=2,
            attack="straggler+sign_flip",
            async_latency=LATENCY,
            b_mode="fixed",
        ),
        axes={
            "async_buffer": BUFFERS,
            "staleness_decay": DECAYS,
            "byz_frac": BYZ_FRACS,
        },
        seeds=seeds,
    )


def main(rounds: int | None = None, out: str | None = None) -> dict:
    spec = fig_async_spec(rounds)
    result = run_campaign(spec, campaign_task, with_acc=True)
    for name, us, derived in result.emit_rows("fig_async"):
        emit(name, us, derived)
    path = out or os.path.join(
        os.path.dirname(__file__), "..", "reports", "fig_async_staleness.json"
    )
    result.save(path)
    emit("fig_async_artifact", result.wall_s * 1e6, path)
    return result.final("acc")


if __name__ == "__main__":
    main()
