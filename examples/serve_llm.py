"""Serve a small model with batched requests — the inference side of the
framework: after FL training aggregates a global model, deploy it behind
the batched decode engine (greedy or sampled, ring-window optional).

Run:  PYTHONPATH=src python examples/serve_llm.py
"""

import sys
import time

import jax

sys.path.insert(0, "src")

from repro import configs
from repro.models import build_specs
from repro.models.spec import init_params
from repro.serving import ServeConfig, ServingEngine


def main():
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))

    engine = ServingEngine(
        cfg, params, ServeConfig(batch_size=4, max_len=64, max_new_tokens=12)
    )
    rng = jax.random.PRNGKey(7)
    prompts = [
        list(map(int, jax.random.randint(jax.random.fold_in(rng, i), (n,), 0, cfg.vocab)))
        for i, n in enumerate([5, 9, 3, 7, 6, 4])  # 6 requests > batch 4
    ]
    t0 = time.time()
    out = engine.generate(prompts)
    dt = time.time() - t0
    total = sum(len(o) for o in out)
    for i, o in enumerate(out):
        print(f"req{i} ({len(prompts[i])} prompt toks) -> {len(o)} generated: {o[:8]}...")
    print(f"\n{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s batched, CPU)")


if __name__ == "__main__":
    main()
