"""Differentially-private federated fine-tuning of a transformer LM.

Shows PRoBit+ as a first-class feature of the framework: the SAME
aggregation pipeline that served the MLP/CNN experiments drives a
transformer from the model zoo (reduced qwen2 family), with (eps,0)-local
DP enforced by the quantizer's b-floor (Theorem 3).

Run:  PYTHONPATH=src python examples/private_federated_lm.py
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro import configs
from repro.data import make_lm_streams
from repro.fl import FLConfig, FLSimulation
from repro.models import build_specs, train_loss
from repro.models.spec import init_params


def main():
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    params0 = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    params0 = jax.tree.map(lambda a: a.astype(jnp.float32), params0)

    m, seq, per_client = 6, 48, 24
    streams = make_lm_streams(0, m, cfg.vocab, seq + 1, per_client)
    cx = np.stack(streams)  # (M, per_client, seq+1)
    cy = cx[..., 0]  # unused placeholder labels for the runtime API

    def loss_fn(params, batch):
        toks = batch["x"]
        return train_loss(
            params, {"tokens": toks[..., :-1], "labels": toks[..., 1:]}, cfg
        )

    def ppl_metric(params, batch):
        return -loss_fn(params, batch)  # higher is better

    test = {"x": cx[:, :4].reshape(-1, seq + 1), "y": cy[:, :4].reshape(-1)}

    # Half the cohort participates per round: the subsampled accountant
    # (FLConfig.dp_accountant default) prices each round at the amplified
    # ln(1 + q(e^eps - 1)) < eps, so the cumulative eps_spent the ledger
    # reports is strictly below the conservative eps * rounds.
    for eps in (0.0, 0.1, 0.01):
        fl = FLConfig(
            n_clients=m, aggregator="probit_plus", rounds=8,
            local_epochs=1, batch_size=4, dp_epsilon=eps,
            participation=0.5,
        )
        sim = FLSimulation(fl, params0, loss_fn, ppl_metric, cx, cy, test)
        sim.run(eval_every=8)
        tag = "no DP" if eps == 0 else f"eps={eps}"
        spent = sim.ledger.eps_spent
        conservative = sim.ledger.compose("basic")[0]
        print(f"{tag:>9}: final test NLL {-sim.history[-1]['acc']:.4f} "
              f"(b={sim.history[-1]['b']:.4f}, "
              f"eps_spent={spent:.4f} vs basic {conservative:.4f})")


if __name__ == "__main__":
    main()
