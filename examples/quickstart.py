"""Quickstart: PRoBit+ vs full-precision FedAvg on a heterogeneous FL task.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import functools
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss


def main():
    # 1. a 10-class task, 20 clients, each holding only 2 classes (paper §VI-A)
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=4000, n_test=800)
    m = 20
    parts = partition_label_skew(ytr, m, classes_per_client=2, per_client=100)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])

    loss_fn = functools.partial(xent_loss, mlp_logits)
    acc_fn = functools.partial(accuracy, mlp_logits)
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=64)

    # 2. run both aggregators with the identical protocol
    for agg in ("fedavg", "probit_plus"):
        cfg = FLConfig(n_clients=m, aggregator=agg, rounds=100, local_epochs=2)
        sim = FLSimulation(cfg, p0, loss_fn, acc_fn, cx, cy, {"x": xte, "y": yte})
        sim.run(eval_every=25, verbose=True)
        bits = 1 if agg == "probit_plus" else 32
        print(f"--> {agg}: final acc {sim.history[-1]['acc']:.3f} "
              f"(uplink: {bits} bit/param/round)\n")


if __name__ == "__main__":
    main()
