"""End-to-end driver: federated fine-tuning of a transformer LM through
the packed one-bit pytree wire, with a FedAvg full-precision baseline.

Default is a CPU-friendly ~6M model for a quick demonstration; pass
``--full`` for the ~100M-parameter qwen2 variant and a few hundred rounds
(sized for a real accelerator — it will run on CPU, just slowly).

Per round it reports the uplink wire bytes of the packed one-bit wire
next to the int8 (8x) and f32 (32x) baselines; after training it
evaluates next-token accuracy on held-out client streams for BOTH the
PRoBit+ run and the FedAvg baseline run (same data, same init, same
round budget) — the acc-vs-FedAvg comparison the paper's experiments
make. ``--json-out`` writes the whole report.

Run:  PYTHONPATH=src python examples/train_100m.py [--full] [--rounds N] \
          [--json-out report.json] [--skip-fedavg]
"""

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.core import build_pipeline
from repro.data import make_lm_streams
from repro.fl.pytree_wire import pytree_wire_bytes
from repro.launch.fl_step import DistFLConfig, make_fl_train_step
from repro.distributed import set_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import build_specs
from repro.models.config import ModelConfig
from repro.models.model import prefill
from repro.models.spec import count_params, init_params, param_pspecs


def model_config(full: bool) -> ModelConfig:
    if full:  # ~100M-parameter qwen2-family model
        return dataclasses.replace(
            configs.get_config("qwen2-1.5b"),
            name="qwen2-100m",
            n_layers=8, d_model=640, n_heads=10, n_kv_heads=2,
            d_ff=1792, vocab=32768, d_head=64,
        )
    return dataclasses.replace(
        configs.get_config("qwen2-1.5b"),
        name="qwen2-6m",
        n_layers=4, d_model=192, n_heads=6, n_kv_heads=2,
        d_ff=512, vocab=4096, d_head=32,
    )


def next_token_accuracy(params, cfg, tokens, labels, batch_size=8):
    """Mean next-token top-1 accuracy under the training objective's
    shift/mask convention (matches ``train_loss``: labels rolled by -1,
    last position masked)."""
    correct = total = 0
    for i in range(0, tokens.shape[0], batch_size):
        tb = tokens[i : i + batch_size]
        lb = labels[i : i + batch_size]
        logits = prefill(params, {"tokens": tb}, cfg)
        pred = jnp.argmax(logits, axis=-1)
        shifted = jnp.roll(lb, -1, axis=1)
        hit = (pred == shifted)[:, :-1]  # last position has no next token
        correct += int(jnp.sum(hit))
        total += int(hit.size)
    return correct / max(total, 1)


def run_training(cfg, fl, rounds, clients, seq, streams, report_every):
    """One federated run: returns (params, per-round history)."""
    specs = build_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    step = jax.jit(make_fl_train_step(cfg, fl, param_pspecs(specs)))
    b = jnp.float32(0.01)
    key = jax.random.PRNGKey(1)
    history = []
    t0 = time.time()
    for r in range(rounds):
        toks = np.stack(
            [s[4 * r : 4 * (r + 1)].reshape(2, 2, seq + 1) for s in streams]
        )[:, None]
        batch = {
            "tokens": jnp.asarray(toks[..., :-1]),
            "labels": jnp.asarray(toks[..., 1:]),
        }
        key, kr = jax.random.split(key)
        params, b, metrics = step(params, b, batch, kr)
        history.append(
            {
                "round": r,
                "loss_first": float(metrics["loss_first"]),
                "loss_last": float(metrics["loss_last"]),
                "b": float(b),
                "wire_bytes": float(metrics["wire_bytes"]),
            }
        )
        if r % report_every == 0 or r == rounds - 1:
            print(
                f"  [{fl.aggregator}] round {r:4d}: loss "
                f"{history[-1]['loss_first']:.4f} -> {history[-1]['loss_last']:.4f}  "
                f"b={float(b):.5f}  wire={history[-1]['wire_bytes']/1e6:.3f}MB  "
                f"[{time.time()-t0:.0f}s]"
            )
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-seqs", type=int, default=32)
    ap.add_argument("--skip-fedavg", action="store_true")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/probit_ckpts")
    args = ap.parse_args()
    rounds = args.rounds or (300 if args.full else 30)

    cfg = model_config(args.full)
    with set_mesh(make_host_mesh()):
        specs = build_specs(cfg)
        print(f"{cfg.name}: {count_params(specs)/1e6:.1f}M params, {rounds} rounds")
        params0 = init_params(specs, jax.random.PRNGKey(0))
        wire = pytree_wire_bytes(
            build_pipeline("probit_plus"), params0, args.clients
        )
        print(
            f"uplink/round ({args.clients} clients): "
            f"{wire['wire_bytes']/1e6:.3f} MB packed "
            f"(ideal {wire['wire_bytes_ideal']/1e6:.3f}) — "
            f"{wire['wire_bytes_int8']/max(wire['wire_bytes_ideal'],1):.1f}x smaller than int8, "
            f"{wire['wire_bytes_f32']/max(wire['wire_bytes_ideal'],1):.1f}x smaller than f32"
        )
        del params0

        # training + held-out streams (held-out = fresh sequences from the
        # same per-client bigram models, different seed)
        streams = make_lm_streams(0, args.clients, cfg.vocab, args.seq + 1, 4 * rounds)
        ev = make_lm_streams(7, args.clients, cfg.vocab, args.seq + 1, args.eval_seqs)
        ev_toks = jnp.asarray(np.concatenate(ev))[:, :-1]
        ev_labels = jnp.asarray(np.concatenate(ev))[:, 1:]

        report_every = max(rounds // 10, 1)
        fl = DistFLConfig(clients_per_round=args.clients, local_steps=2, lr=0.02)
        print("training: PRoBit+ (packed one-bit wire)")
        params, hist = run_training(
            cfg, fl, rounds, args.clients, args.seq, streams, report_every
        )
        acc = next_token_accuracy(params, cfg, ev_toks, ev_labels)
        print(f"PRoBit+ next-token accuracy: {acc:.4f}")

        result = {
            "arch": cfg.name,
            "rounds": rounds,
            "clients": args.clients,
            "wire": wire,
            "probit_plus": {"history": hist, "accuracy": acc},
        }

        if not args.skip_fedavg:
            print("training: FedAvg fp32 baseline (same data, init, budget)")
            fl_avg = dataclasses.replace(fl, aggregator="fedavg_fp32")
            params_avg, hist_avg = run_training(
                cfg, fl_avg, rounds, args.clients, args.seq, streams, report_every
            )
            acc_avg = next_token_accuracy(params_avg, cfg, ev_toks, ev_labels)
            print(
                f"FedAvg next-token accuracy:  {acc_avg:.4f}  "
                f"(PRoBit+ {acc:.4f} at {wire['wire_bytes_f32']/max(wire['wire_bytes'],1):.1f}x "
                "less uplink)"
            )
            result["fedavg"] = {"history": hist_avg, "accuracy": acc_avg}
            result["acc_vs_fedavg"] = acc - acc_avg

        if args.ckpt_dir:
            path = save_checkpoint(args.ckpt_dir, rounds, params, {"arch": cfg.name})
            print("saved:", path)
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(result, f, indent=2)
            print("json:", args.json_out)


if __name__ == "__main__":
    main()
