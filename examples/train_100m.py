"""End-to-end driver: federated training of a transformer LM with the
distributed PRoBit+ round (the paper's kind of system, at driver scale).

Default is a CPU-friendly ~6M model for a quick demonstration; pass
``--full`` for a ~100M-parameter model and a few hundred rounds (sized for
a real accelerator — it will run on CPU, just slowly).

Run:  PYTHONPATH=src python examples/train_100m.py [--full] [--rounds N]
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.data import make_lm_streams
from repro.launch.fl_step import DistFLConfig, make_fl_train_step
from repro.distributed import set_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import build_specs
from repro.models.config import ModelConfig
from repro.models.spec import count_params, init_params, param_pspecs


def model_config(full: bool) -> ModelConfig:
    if full:  # ~100M-parameter qwen2-family model
        return dataclasses.replace(
            configs.get_config("qwen2-1.5b"),
            name="qwen2-100m",
            n_layers=8, d_model=640, n_heads=10, n_kv_heads=2,
            d_ff=1792, vocab=32768, d_head=64,
        )
    return dataclasses.replace(
        configs.get_config("qwen2-1.5b"),
        name="qwen2-6m",
        n_layers=4, d_model=192, n_heads=6, n_kv_heads=2,
        d_ff=512, vocab=4096, d_head=32,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/probit_ckpts")
    args = ap.parse_args()
    rounds = args.rounds or (300 if args.full else 30)

    cfg = model_config(args.full)
    with set_mesh(make_host_mesh()):
        specs = build_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        print(f"{cfg.name}: {count_params(specs)/1e6:.1f}M params, {rounds} rounds")

        fl = DistFLConfig(clients_per_round=args.clients, local_steps=2, lr=0.02)
        step = jax.jit(make_fl_train_step(cfg, fl, param_pspecs(specs)))
        b = jnp.float32(0.01)
        streams = make_lm_streams(0, args.clients, cfg.vocab, args.seq + 1, 4 * rounds)

        key = jax.random.PRNGKey(1)
        t0 = time.time()
        for r in range(rounds):
            toks = np.stack(
                [s[4 * r : 4 * (r + 1)].reshape(2, 2, args.seq + 1) for s in streams]
            )[:, None]
            batch = {
                "tokens": jnp.asarray(toks[..., :-1]),
                "labels": jnp.asarray(toks[..., 1:]),
            }
            key, kr = jax.random.split(key)
            params, b, metrics = step(params, b, batch, kr)
            if r % max(rounds // 10, 1) == 0 or r == rounds - 1:
                print(
                    f"round {r:4d}: client loss {float(metrics['loss_first']):.4f} -> "
                    f"{float(metrics['loss_last']):.4f}  b={float(b):.5f}  "
                    f"[{time.time()-t0:.0f}s]"
                )
        path = save_checkpoint(args.ckpt_dir, rounds, params, {"arch": cfg.name})
        print("saved:", path)


if __name__ == "__main__":
    main()
