"""Byzantine-attack demo (paper §VI-D): 30% malicious clients launch each
of the four attacks; compare PRoBit+ against FedAvg and signSGD-MV.

Run:  PYTHONPATH=src python examples/byzantine_robustness.py
"""

import functools
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss


def main():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=3000, n_test=600)
    m = 10
    parts = partition_label_skew(ytr, m, 2, 100)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    loss_fn = functools.partial(xent_loss, mlp_logits)
    acc_fn = functools.partial(accuracy, mlp_logits)
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=48)

    print(f"{'attack':<18} {'PRoBit+':>8} {'FedAvg':>8} {'signSGD-MV':>11}")
    for attack in ("gaussian", "sign_flip", "zero_gradient", "sample_duplicate"):
        row = []
        for agg in ("probit_plus", "fedavg", "signsgd_mv"):
            cfg = FLConfig(
                n_clients=m, aggregator=agg, rounds=60, local_epochs=2,
                byz_frac=0.3, attack=attack, b_mode="fixed",
            )
            sim = FLSimulation(cfg, p0, loss_fn, acc_fn, cx, cy, {"x": xte, "y": yte})
            sim.run(eval_every=60)
            row.append(sim.history[-1]["acc"])
        print(f"{attack:<18} {row[0]:>8.3f} {row[1]:>8.3f} {row[2]:>11.3f}")


if __name__ == "__main__":
    main()
