"""Serving engine + sparse PRoBit+ + DP-composition tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.privacy import advanced_composition, basic_composition, rounds_for_budget
from repro.core.sparse import sparse_aggregate, topk_binarize
from repro.models import build_specs
from repro.models.spec import init_params
from repro.serving import ServeConfig, ServingEngine


class TestServingEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
        params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
        return cfg, params

    def test_batched_generation(self, engine):
        cfg, params = engine
        eng = ServingEngine(cfg, params, ServeConfig(batch_size=2, max_len=32, max_new_tokens=5))
        prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]  # 3 requests > batch 2
        out = eng.generate(prompts)
        assert len(out) == 3
        assert all(len(o) == 5 for o in out)
        assert all(0 <= t < cfg.vocab for o in out for t in o)

    def test_greedy_matches_prefill_argmax(self, engine):
        """First generated token == argmax of prefill logits at the last
        prompt position (the engine's decode path is consistent)."""
        from repro.models import prefill

        cfg, params = engine
        eng = ServingEngine(cfg, params, ServeConfig(batch_size=1, max_len=32, max_new_tokens=1))
        prompt = [3, 1, 4, 1, 5]
        out = eng.generate([prompt])
        logits = prefill(params, {"tokens": jnp.asarray([prompt])}, cfg)
        want = int(jnp.argmax(logits[0, -1]))
        assert out[0][0] == want

    def test_sampled_generation_runs(self, engine):
        cfg, params = engine
        eng = ServingEngine(
            cfg, params,
            ServeConfig(batch_size=2, max_len=32, max_new_tokens=4, temperature=0.8),
        )
        out = eng.generate([[1, 2], [3]])
        assert all(len(o) == 4 for o in out)

    def test_ssm_family_serves(self):
        cfg = configs.reduced(configs.get_config("xlstm-350m"))
        params = init_params(build_specs(cfg), jax.random.PRNGKey(1))
        eng = ServingEngine(cfg, params, ServeConfig(batch_size=2, max_len=16, max_new_tokens=3))
        out = eng.generate([[1, 2, 3]])
        assert len(out[0]) == 3


class TestSparseProbit:
    def test_dense_limit_matches_eq13(self):
        """k = d reduces to the dense ML estimate."""
        key = jax.random.PRNGKey(0)
        d, m = 64, 12
        delta = 0.01 * jax.random.normal(key, (m, d))
        b = jnp.full((d,), 0.05)
        keys = jax.random.split(key, m)
        idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
            keys, delta, b, d
        )
        theta = sparse_aggregate(idx, codes, b, d)
        # compare against dense path with identical per-client randomness is
        # not possible (different draw order) — check unbiasedness instead
        reps = 400
        kk = jax.random.split(jax.random.fold_in(key, 1), reps)

        def est(k2):
            ks = jax.random.split(k2, m)
            i2, c2 = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
                ks, delta, b, d
            )
            return sparse_aggregate(i2, c2, b, d)

        mean_est = jnp.mean(jax.vmap(est)(kk), axis=0)
        target = jnp.mean(delta, axis=0)
        se = 0.05 / np.sqrt(m * reps)
        assert float(jnp.max(jnp.abs(mean_est - target))) < 6 * se

    def test_sparse_only_touches_reported_coords(self):
        d, m, k = 32, 4, 4
        key = jax.random.PRNGKey(2)
        delta = jnp.zeros((m, d)).at[:, :k].set(1.0)  # top-k is coords 0..k-1
        b = jnp.full((d,), 2.0)
        keys = jax.random.split(key, m)
        idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
            keys, delta, b, k
        )
        theta = sparse_aggregate(idx, codes, b, d)
        assert bool(jnp.all(theta[k:] == 0.0))

    def test_topk_with_dp_is_refused(self):
        from repro.fl import FLConfig

        with pytest.raises(ValueError):
            FLConfig(topk_frac=0.1, dp_epsilon=0.1)

    def test_sparse_fl_learns(self):
        import functools

        from repro.data import make_classification, partition_label_skew
        from repro.fl import FLConfig, FLSimulation
        from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

        (xtr, ytr), (xte, yte) = make_classification(0, n_train=2000, n_test=400)
        parts = partition_label_skew(ytr, 8, 2, 80, seed=1)
        cx = np.stack([xtr[i] for i in parts])
        cy = np.stack([ytr[i] for i in parts])
        p0 = init_mlp(jax.random.PRNGKey(0), hidden=32)
        cfg = FLConfig(
            n_clients=8, aggregator="probit_plus", topk_frac=0.25,
            rounds=40, local_epochs=2,
        )
        sim = FLSimulation(
            cfg, p0,
            functools.partial(xent_loss, mlp_logits),
            functools.partial(accuracy, mlp_logits),
            cx, cy, {"x": xte, "y": yte},
        )
        sim.run(eval_every=40)
        assert sim.history[-1]["acc"] > 0.15  # learning with 4x fewer coords


class TestDPComposition:
    def test_advanced_beats_basic_for_many_rounds(self):
        eps = 0.1
        t = 300  # the paper's round count
        basic = basic_composition(eps, t)
        adv, delta = advanced_composition(eps, t, 1e-5)
        assert adv < basic
        assert delta == 1e-5

    def test_rounds_for_budget_monotone(self):
        r1 = rounds_for_budget(5.0, 0.1)
        r2 = rounds_for_budget(10.0, 0.1)
        assert r2 > r1 > 0
