"""Minimal stand-in for the optional ``hypothesis`` dependency.

The tier-1 suite must run green on a bare container (no ``pip install``).
When ``hypothesis`` is absent, test modules fall back to this shim::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st

It implements just the API surface the suite uses — ``@given`` /
``@settings`` and the ``integers`` / ``floats`` / ``sampled_from``
strategies — by running each property test on a deterministic sample of
pseudo-random examples (seeded per test name, so failures reproduce).
No shrinking, no database; install ``hypothesis`` for the real engine.
"""

from __future__ import annotations

import random
import zlib

# Cap the fallback's example count: the shim has no deadline management,
# so keep bare-container suite runtime bounded while still exercising a
# meaningful sample of the property space.
_MAX_FALLBACK_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))


st = _Strategies()


def given(*strategies: _Strategy):
    """Run the test body over deterministically sampled examples.

    Works for plain functions and methods: any positional args supplied by
    pytest (e.g. ``self``) are passed through first, then the drawn values.
    """

    def deco(fn):
        def run(*args):
            n = min(getattr(run, "_max_examples", 20), _MAX_FALLBACK_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                fn(*args, *[s.draw(rng) for s in strategies])

        # NOTE: deliberately not functools.wraps(fn) — copying __wrapped__
        # would make pytest see the original (drawn) parameters as fixtures.
        run.__name__ = fn.__name__
        run.__qualname__ = fn.__qualname__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._max_examples = 20
        return run

    return deco


def settings(*, max_examples: int | None = None, **_kw):
    """Accepts (and mostly ignores) hypothesis settings; honors max_examples."""

    def deco(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn

    return deco
