"""Property-based tests for the ServerAggregator MLE (core/aggregation.py).

Randomized over shapes and values (hypothesis when installed, the
deterministic fallback shim otherwise):

* the Eq.-13 estimate is bounded by the public range: |theta_hat_i| <= b_i
  for any vote counts — the amplitude-immunity invariant;
* theta_hat is monotone in the vote count, coordinate-wise;
* packed-wire aggregation equals the dense-codes reference on random
  (M, d) shapes, including d not divisible by 8 (pad-bit handling).
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    build_pipeline,
    codes_to_counts,
    ml_estimate_from_counts,
    packed_counts,
    probit_plus_aggregate,
)
from repro.core.aggregation import _unpack_rows


@settings(deadline=None, max_examples=20)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 64),
    st.integers(1, 257),
)
def test_estimate_bounded_by_b(seed, m, d):
    """|theta_hat_i| <= b_i for every possible count vector 0..M."""
    key = jax.random.PRNGKey(seed)
    counts = jax.random.randint(key, (d,), 0, m + 1)
    b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (d,))) + 1e-3
    theta = ml_estimate_from_counts(counts, m, b)
    assert bool(jnp.all(jnp.abs(theta) <= b * (1 + 1e-6)))
    # extremes reach exactly +/- b
    np.testing.assert_allclose(
        np.asarray(ml_estimate_from_counts(jnp.full((d,), m), m, b)),
        np.asarray(b),
        rtol=1e-6,
    )


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(2, 64), st.integers(1, 100))
def test_estimate_monotone_in_counts(seed, m, d):
    """Adding a +1 vote to one coordinate raises exactly that estimate."""
    key = jax.random.PRNGKey(seed)
    counts = jax.random.randint(key, (d,), 0, m)  # leave headroom for +1
    b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (d,))) + 1e-3
    i = int(jax.random.randint(jax.random.fold_in(key, 2), (), 0, d))
    theta = ml_estimate_from_counts(counts, m, b)
    theta_up = ml_estimate_from_counts(counts.at[i].add(1), m, b)
    assert float(theta_up[i]) > float(theta[i])
    mask = jnp.arange(d) != i
    np.testing.assert_array_equal(
        np.asarray(theta_up[mask]), np.asarray(theta[mask])
    )


@settings(deadline=None, max_examples=10)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 12),
    st.sampled_from([1, 3, 8, 13, 64, 131, 256]),
)
def test_packed_wire_matches_dense_reference(seed, m, d):
    """Pipeline on the packed wire == dense-codes math, any (M, d) —
    d values deliberately include non-multiples of 8."""
    key = jax.random.PRNGKey(seed)
    deltas = 0.02 * jax.random.normal(key, (m, d))
    b = jnp.float32(0.05)
    pipe = build_pipeline("probit_plus", chunk=64)
    wire, _ = pipe.compressor.compress(key, deltas, b, jnp.zeros((m, d)))
    codes = _unpack_rows(wire.packed, d)
    np.testing.assert_array_equal(
        np.asarray(packed_counts(wire.packed, chunk=64)[:d]),
        np.asarray(codes_to_counts(codes)),
    )
    theta, _ = pipe(key, deltas, b, jnp.zeros((m, d)))
    np.testing.assert_allclose(
        np.asarray(theta),
        np.asarray(probit_plus_aggregate(codes, wire.b)),
        rtol=1e-6,
        atol=1e-8,
    )
