"""Wire-format parity tests for the shared AggregatorPipeline.

Fixed-seed assertions that the packed uint8 wire (pure-JAX chunked path
and Pallas kernel interpret path) reproduces the dense reference math for
PRoBit+ — with and without error feedback, top-k, and the DP margin — and
that every registered aggregator matches its legacy formula exactly.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DPConfig,
    available_aggregators,
    build_pipeline,
    codes_to_counts,
    fedavg_aggregate,
    geometric_median,
    ml_estimate_from_counts,
    packed_counts,
    probit_plus_aggregate,
    rsa_aggregate,
    signsgd_mv_aggregate,
)
from repro.core.aggregation import PackedWire, SparseWire, _unpack_rows
from repro.core.sparse import sparse_aggregate, topk_binarize

M, D = 8, 3000
CHUNK = 512  # small chunk to force a multi-chunk wire in tests
KEY = jax.random.PRNGKey(42)
B = jnp.float32(0.05)


@pytest.fixture(scope="module")
def deltas():
    return 0.01 * jax.random.normal(KEY, (M, D))


@pytest.fixture(scope="module")
def zeros_res():
    return jnp.zeros((M, D), jnp.float32)


def _unpacked_theta(wire: PackedWire):
    """Dense-reference Eq. 13 estimate from the wire's own codes."""
    codes = _unpack_rows(wire.packed, wire.d)
    return probit_plus_aggregate(codes, wire.b), codes


def test_registry_has_all_five_aggregators():
    assert available_aggregators() == (
        "fed_gm",
        "fedavg",
        "probit_plus",
        "rsa",
        "signsgd_mv",
    )


def test_packed_counts_match_dense_counts(deltas, zeros_res):
    pipe = build_pipeline("probit_plus", chunk=CHUNK)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    codes = _unpack_rows(wire.packed, D)
    np.testing.assert_array_equal(
        np.asarray(packed_counts(wire.packed, chunk=CHUNK)[:D]),
        np.asarray(codes_to_counts(codes)),
    )


def test_packed_pipeline_matches_dense_reference(deltas, zeros_res):
    """Chunked packed path == dense codes math, bit for bit."""
    pipe = build_pipeline("probit_plus", chunk=CHUNK)
    theta, res = pipe(KEY, deltas, B, zeros_res)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    theta_ref, _ = _unpacked_theta(wire)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(zeros_res))


def test_packed_pipeline_with_error_feedback(deltas):
    """EF residual == eff - c*b for the codes actually on the wire, and the
    residual feeds back into the next round's effective update."""
    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    res0 = 1e-3 * jax.random.normal(jax.random.fold_in(KEY, 7), (M, D))
    eff = deltas + res0
    wire, res1 = pipe.compressor.compress(KEY, deltas, B, res0)
    _, codes = _unpacked_theta(wire)
    np.testing.assert_allclose(
        np.asarray(res1),
        np.asarray(eff - codes.astype(jnp.float32) * wire.b),
        rtol=1e-5,
        atol=1e-7,
    )
    assert float(jnp.max(jnp.abs(res1))) > 0.0


def test_packed_pipeline_with_dp_margin(deltas, zeros_res):
    """The DP b-floor (Thm 3 margin) must be applied on the wire's b."""
    eps, sens = 0.1, 2e-4
    pipe = build_pipeline(
        "probit_plus", dp=DPConfig(eps, sens), chunk=CHUNK
    )
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    b_expected = float(B) + (1.0 + 1.0 / eps) * sens
    np.testing.assert_allclose(np.asarray(wire.b), b_expected, rtol=1e-6)
    theta, _ = pipe(KEY, deltas, B, zeros_res)
    counts = packed_counts(wire.packed, chunk=CHUNK)[:D]
    np.testing.assert_allclose(
        np.asarray(theta),
        np.asarray(ml_estimate_from_counts(counts, M, wire.b)),
        rtol=1e-6,
    )


def test_topk_pipeline_matches_sparse_reference(deltas, zeros_res):
    """Top-k wire reproduces core/sparse exactly (same key schedule)."""
    frac = 0.25
    pipe = build_pipeline("probit_plus", topk_frac=frac, chunk=CHUNK)
    theta, _ = pipe(KEY, deltas, B, zeros_res)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    assert isinstance(wire, SparseWire)
    k = max(int(D * frac), 1)
    keys = jax.random.split(KEY, M)
    b_vec = jnp.full((D,), B, jnp.float32)
    idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
        keys, deltas, b_vec, k
    )
    theta_ref = sparse_aggregate(idx, codes, b_vec, D)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref), rtol=1e-6)


@pytest.mark.parametrize("chunk", [CHUNK, 8192])
@pytest.mark.parametrize("error_feedback", [False, True])
def test_kernel_pipeline_matches_pure_exactly(deltas, chunk, error_feedback):
    """use_kernels=True is bit-exact with the pure-JAX packed path: the
    engines share the counter-derived uniform schedule, the popcount count
    reduction, and the Eq.-13 float expression — distributional tolerance
    is no longer needed (or accepted). EF residuals match exactly too."""
    res0 = (
        1e-3 * jax.random.normal(jax.random.fold_in(KEY, 3), (M, D))
        if error_feedback
        else jnp.zeros((M, D), jnp.float32)
    )
    pk = build_pipeline(
        "probit_plus", use_kernels=True, chunk=chunk,
        error_feedback=error_feedback,
    )
    pj = build_pipeline(
        "probit_plus", chunk=chunk, error_feedback=error_feedback
    )
    assert pk.compressor.use_kernels and pk.server.use_kernels
    theta_k, res_k = pk(KEY, deltas, B, res0)
    theta_j, res_j = pj(KEY, deltas, B, res0)
    np.testing.assert_array_equal(np.asarray(theta_k), np.asarray(theta_j))
    np.testing.assert_array_equal(np.asarray(res_k), np.asarray(res_j))


def test_kernel_wire_is_bit_exact_with_pure_wire(deltas, zeros_res):
    """The packed bytes themselves agree on the common prefix; the wider
    wire's extra pad bytes are deterministically zero (so either server
    realigns losslessly)."""
    pj = build_pipeline("probit_plus", chunk=CHUNK)
    pk = build_pipeline("probit_plus", use_kernels=True, chunk=CHUNK)
    wire_j, _ = pj.compressor.compress(KEY, deltas, B, zeros_res)
    wire_k, _ = pk.compressor.compress(KEY, deltas, B, zeros_res)
    prefix = min(wire_j.packed.shape[1], wire_k.packed.shape[1])
    np.testing.assert_array_equal(
        np.asarray(wire_j.packed[:, :prefix]),
        np.asarray(wire_k.packed[:, :prefix]),
    )
    assert not np.any(np.asarray(wire_j.packed[:, prefix:]))
    assert not np.any(np.asarray(wire_k.packed[:, prefix:]))


@pytest.mark.parametrize("jax_chunk", [1024, 8192])  # 8192 = default, pads
def test_kernel_and_jax_wires_are_interchangeable(deltas, zeros_res, jax_chunk):
    """One canonical wire: the kernel server must decode the pure-JAX wire
    and vice versa, bit for bit — including when the two paths' pad widths
    differ (default chunk 8192 vs 1024-lane kernel)."""
    pj = build_pipeline("probit_plus", chunk=jax_chunk)
    pk = build_pipeline("probit_plus", use_kernels=True)
    wire_j, _ = pj.compressor.compress(KEY, deltas, B, zeros_res)
    wire_k, _ = pk.compressor.compress(KEY, deltas, B, zeros_res)
    # kernel server on the pure-JAX wire
    theta_a = pk.server.aggregate(wire_j)
    theta_b = pj.server.aggregate(wire_j)
    np.testing.assert_array_equal(np.asarray(theta_a), np.asarray(theta_b))
    # pure-JAX server on the kernel wire
    theta_c = pj.server.aggregate(wire_k)
    theta_d = pk.server.aggregate(wire_k)
    np.testing.assert_array_equal(np.asarray(theta_c), np.asarray(theta_d))


@pytest.mark.parametrize("error_feedback", [False, True])
def test_topk_kernel_path_matches_pure_exactly(deltas, error_feedback):
    """The newly unlocked topk_frac < 1 kernel path: same key schedule and
    top-k gather, binarize+pack through the kernel engine — indices,
    packed codes, EF residuals, and the sparse estimate all bit-exact with
    the pure path (no silent fallback: the compressor keeps use_kernels)."""
    frac = 0.25
    res0 = (
        1e-3 * jax.random.normal(jax.random.fold_in(KEY, 5), (M, D))
        if error_feedback
        else jnp.zeros((M, D), jnp.float32)
    )
    pk = build_pipeline(
        "probit_plus", topk_frac=frac, use_kernels=True,
        error_feedback=error_feedback,
    )
    pj = build_pipeline(
        "probit_plus", topk_frac=frac, error_feedback=error_feedback
    )
    assert pk.compressor.use_kernels  # the old builder silently dropped it
    wire_k, res_k = pk.compressor.compress(KEY, deltas, B, res0)
    wire_j, res_j = pj.compressor.compress(KEY, deltas, B, res0)
    assert isinstance(wire_k, SparseWire)
    np.testing.assert_array_equal(np.asarray(wire_k.indices), np.asarray(wire_j.indices))
    np.testing.assert_array_equal(np.asarray(wire_k.packed), np.asarray(wire_j.packed))
    np.testing.assert_array_equal(np.asarray(res_k), np.asarray(res_j))
    theta_k = pk.server.aggregate(wire_k)
    theta_j = pj.server.aggregate(wire_j)
    np.testing.assert_array_equal(np.asarray(theta_k), np.asarray(theta_j))


def test_baseline_pipelines_match_legacy_formulas(deltas, zeros_res):
    sign_codes = jnp.where(deltas >= 0, jnp.int8(1), jnp.int8(-1))
    cases = {
        "fedavg": fedavg_aggregate(deltas),
        "fed_gm": geometric_median(deltas, 16),
        "signsgd_mv": signsgd_mv_aggregate(sign_codes, 0.01),
        "rsa": rsa_aggregate(sign_codes, 0.01),
    }
    for name, ref in cases.items():
        pipe = build_pipeline(name, agg_step=0.01, gm_iters=16, chunk=CHUNK)
        theta, res = pipe(KEY, deltas, B, zeros_res)
        np.testing.assert_allclose(
            np.asarray(theta), np.asarray(ref), rtol=1e-5, atol=1e-7, err_msg=name
        )
        np.testing.assert_array_equal(np.asarray(res), np.asarray(zeros_res))


def test_simulation_kernel_path_matches_dense_reference():
    """FLSimulation(use_kernels=True) vs use_kernels=False on a fixed seed.

    On any non-TPU backend the dispatch policy resolves the kernel wire to
    the pure-JAX ref engine, which shares the uniform schedule, count
    reduction, and local-solver arithmetic with the pure path — so the two
    runs are *bit-identical*. On TPU (compiled Pallas) the quantizer draws
    agree but fused-fma ordering may differ at ulp level; fall back to the
    stochastic tolerance there."""
    from repro.data import make_classification, partition_label_skew
    from repro.fl import FLConfig, FLSimulation
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=800, n_test=200)
    m = 4
    parts = partition_label_skew(ytr, m, 2, 50, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=16)

    sims = {}
    for use_kernels in (False, True):
        cfg = FLConfig(
            n_clients=m, aggregator="probit_plus", rounds=1, local_epochs=1,
            use_kernels=use_kernels, seed=0,
        )
        sim = FLSimulation(
            cfg, p0,
            functools.partial(xent_loss, mlp_logits),
            functools.partial(accuracy, mlp_logits),
            cx, cy, {"x": xte, "y": yte},
        )
        assert sim.pipeline.compressor.use_kernels == use_kernels
        sim.run(rounds=1, eval_every=1)
        sims[use_kernels] = sim

    w_dense = sims[False].w_global
    w_kernel = sims[True].w_global
    from repro.kernels import resolve_engine

    if resolve_engine() == "ref":
        np.testing.assert_array_equal(np.asarray(w_dense), np.asarray(w_kernel))
    else:
        d = w_dense.shape[0]
        b = float(sims[False].history[-1]["b"]) if sims[False].history else 0.01
        tol = 6.0 * b * np.sqrt(2.0 * d / m)
        diff = float(jnp.linalg.norm(w_dense - w_kernel))
        assert diff < tol, (diff, tol)
