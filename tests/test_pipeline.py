"""Wire-format parity tests for the shared AggregatorPipeline.

Fixed-seed assertions that the packed uint8 wire (pure-JAX chunked path
and Pallas kernel interpret path) reproduces the dense reference math for
PRoBit+ — with and without error feedback, top-k, and the DP margin — and
that every registered aggregator matches its legacy formula exactly.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DPConfig,
    available_aggregators,
    build_pipeline,
    codes_to_counts,
    fedavg_aggregate,
    geometric_median,
    ml_estimate_from_counts,
    packed_counts,
    probit_plus_aggregate,
    rsa_aggregate,
    signsgd_mv_aggregate,
)
from repro.core.aggregation import PackedWire, SparseWire, _unpack_rows
from repro.core.sparse import sparse_aggregate, topk_binarize

M, D = 8, 3000
CHUNK = 512  # small chunk to force a multi-chunk wire in tests
KEY = jax.random.PRNGKey(42)
B = jnp.float32(0.05)


@pytest.fixture(scope="module")
def deltas():
    return 0.01 * jax.random.normal(KEY, (M, D))


@pytest.fixture(scope="module")
def zeros_res():
    return jnp.zeros((M, D), jnp.float32)


def _unpacked_theta(wire: PackedWire):
    """Dense-reference Eq. 13 estimate from the wire's own codes."""
    codes = _unpack_rows(wire.packed, wire.d)
    return probit_plus_aggregate(codes, wire.b), codes


def test_registry_has_all_five_aggregators():
    assert available_aggregators() == (
        "fed_gm",
        "fedavg",
        "probit_plus",
        "rsa",
        "signsgd_mv",
    )


def test_packed_counts_match_dense_counts(deltas, zeros_res):
    pipe = build_pipeline("probit_plus", chunk=CHUNK)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    codes = _unpack_rows(wire.packed, D)
    np.testing.assert_array_equal(
        np.asarray(packed_counts(wire.packed, chunk=CHUNK)[:D]),
        np.asarray(codes_to_counts(codes)),
    )


def test_packed_pipeline_matches_dense_reference(deltas, zeros_res):
    """Chunked packed path == dense codes math, bit for bit."""
    pipe = build_pipeline("probit_plus", chunk=CHUNK)
    theta, res = pipe(KEY, deltas, B, zeros_res)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    theta_ref, _ = _unpacked_theta(wire)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(zeros_res))


def test_packed_pipeline_with_error_feedback(deltas):
    """EF residual == eff - c*b for the codes actually on the wire, and the
    residual feeds back into the next round's effective update."""
    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    res0 = 1e-3 * jax.random.normal(jax.random.fold_in(KEY, 7), (M, D))
    eff = deltas + res0
    wire, res1 = pipe.compressor.compress(KEY, deltas, B, res0)
    _, codes = _unpacked_theta(wire)
    np.testing.assert_allclose(
        np.asarray(res1),
        np.asarray(eff - codes.astype(jnp.float32) * wire.b),
        rtol=1e-5,
        atol=1e-7,
    )
    assert float(jnp.max(jnp.abs(res1))) > 0.0


def test_packed_pipeline_with_dp_margin(deltas, zeros_res):
    """The DP b-floor (Thm 3 margin) must be applied on the wire's b."""
    eps, sens = 0.1, 2e-4
    pipe = build_pipeline(
        "probit_plus", dp=DPConfig(eps, sens), chunk=CHUNK
    )
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    b_expected = float(B) + (1.0 + 1.0 / eps) * sens
    np.testing.assert_allclose(np.asarray(wire.b), b_expected, rtol=1e-6)
    theta, _ = pipe(KEY, deltas, B, zeros_res)
    counts = packed_counts(wire.packed, chunk=CHUNK)[:D]
    np.testing.assert_allclose(
        np.asarray(theta),
        np.asarray(ml_estimate_from_counts(counts, M, wire.b)),
        rtol=1e-6,
    )


def test_topk_pipeline_matches_sparse_reference(deltas, zeros_res):
    """Top-k wire reproduces core/sparse exactly (same key schedule)."""
    frac = 0.25
    pipe = build_pipeline("probit_plus", topk_frac=frac, chunk=CHUNK)
    theta, _ = pipe(KEY, deltas, B, zeros_res)
    wire, _ = pipe.compressor.compress(KEY, deltas, B, zeros_res)
    assert isinstance(wire, SparseWire)
    k = max(int(D * frac), 1)
    keys = jax.random.split(KEY, M)
    b_vec = jnp.full((D,), B, jnp.float32)
    idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
        keys, deltas, b_vec, k
    )
    theta_ref = sparse_aggregate(idx, codes, b_vec, D)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref), rtol=1e-6)


def test_kernel_pipeline_matches_dense_within_quantizer_tolerance(
    deltas, zeros_res
):
    """Pallas interpret-mode wire: independent draws, same distribution.

    Each coordinate of theta_hat has std <= b/sqrt(M); both paths must land
    within 6 sigma of the true mean and of each other (union bound over
    D coords keeps the false-positive probability negligible)."""
    mean_delta = jnp.mean(deltas, axis=0)
    sigma = float(B) / np.sqrt(M)
    pk = build_pipeline("probit_plus", use_kernels=True)
    pj = build_pipeline("probit_plus", chunk=CHUNK)
    assert pk.compressor.use_kernels and pk.server.use_kernels
    theta_k, _ = pk(KEY, deltas, B, zeros_res)
    theta_j, _ = pj(KEY, deltas, B, zeros_res)
    assert float(jnp.max(jnp.abs(theta_k - mean_delta))) < 6 * sigma
    assert float(jnp.max(jnp.abs(theta_j - mean_delta))) < 6 * sigma
    assert float(jnp.max(jnp.abs(theta_k - theta_j))) < 12 * sigma


@pytest.mark.parametrize("jax_chunk", [1024, 8192])  # 8192 = default, pads
def test_kernel_and_jax_wires_are_interchangeable(deltas, zeros_res, jax_chunk):
    """One canonical wire: the kernel server must decode the pure-JAX wire
    and vice versa, coordinate for coordinate — including when the two
    paths' pad widths differ (default chunk 8192 vs 1024-lane kernel)."""
    pj = build_pipeline("probit_plus", chunk=jax_chunk)
    pk = build_pipeline("probit_plus", use_kernels=True)
    wire_j, _ = pj.compressor.compress(KEY, deltas, B, zeros_res)
    wire_k, _ = pk.compressor.compress(KEY, deltas, B, zeros_res)
    # kernel server on the pure-JAX wire
    theta_a = pk.server.aggregate(wire_j)
    theta_b = pj.server.aggregate(wire_j)
    np.testing.assert_allclose(np.asarray(theta_a), np.asarray(theta_b), rtol=1e-6)
    # pure-JAX server on the kernel wire
    theta_c = pj.server.aggregate(wire_k)
    theta_d = pk.server.aggregate(wire_k)
    np.testing.assert_allclose(np.asarray(theta_c), np.asarray(theta_d), rtol=1e-6)


def test_baseline_pipelines_match_legacy_formulas(deltas, zeros_res):
    sign_codes = jnp.where(deltas >= 0, jnp.int8(1), jnp.int8(-1))
    cases = {
        "fedavg": fedavg_aggregate(deltas),
        "fed_gm": geometric_median(deltas, 16),
        "signsgd_mv": signsgd_mv_aggregate(sign_codes, 0.01),
        "rsa": rsa_aggregate(sign_codes, 0.01),
    }
    for name, ref in cases.items():
        pipe = build_pipeline(name, agg_step=0.01, gm_iters=16, chunk=CHUNK)
        theta, res = pipe(KEY, deltas, B, zeros_res)
        np.testing.assert_allclose(
            np.asarray(theta), np.asarray(ref), rtol=1e-5, atol=1e-7, err_msg=name
        )
        np.testing.assert_array_equal(np.asarray(res), np.asarray(zeros_res))


def test_simulation_kernel_path_matches_dense_reference():
    """FLSimulation(use_kernels=True) runs the packed Pallas wire and its
    per-round global update stays within stochastic-quantizer tolerance of
    the dense reference on a fixed seed."""
    from repro.data import make_classification, partition_label_skew
    from repro.fl import FLConfig, FLSimulation
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=800, n_test=200)
    m = 4
    parts = partition_label_skew(ytr, m, 2, 50, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=16)

    sims = {}
    for use_kernels in (False, True):
        cfg = FLConfig(
            n_clients=m, aggregator="probit_plus", rounds=1, local_epochs=1,
            use_kernels=use_kernels, seed=0,
        )
        sim = FLSimulation(
            cfg, p0,
            functools.partial(xent_loss, mlp_logits),
            functools.partial(accuracy, mlp_logits),
            cx, cy, {"x": xte, "y": yte},
        )
        assert sim.pipeline.compressor.use_kernels == use_kernels
        sim.run(rounds=1, eval_every=1)
        sims[use_kernels] = sim

    w_dense = sims[False].w_global
    w_kernel = sims[True].w_global
    d = w_dense.shape[0]
    # theta_hat coordinates differ by independent quantizer draws with std
    # <= b/sqrt(M) each; allow 6x the resulting rms over d coordinates
    # (the prox-SGD kernel's fused fma ordering adds only ~ulp-level noise).
    b = float(sims[False].history[-1]["b"]) if sims[False].history else 0.01
    tol = 6.0 * b * np.sqrt(2.0 * d / m)
    diff = float(jnp.linalg.norm(w_dense - w_kernel))
    assert diff < tol, (diff, tol)
