"""The ``bit_flip`` wire adversary as a first-class attack (paper §VI-D).

``bit_flip`` inverts Byzantine clients' *post-quantization* codes directly
on the packed wire — the strongest bit-level adversary, the one Theorem 2
actually bounds. These tests pin down the paper's robustness comparison
at the aggregation level, where the claims are exact:

* PRoBit+ degrades **gracefully**: the expected-estimate deviation obeys
  the Theorem-2 line (per-coordinate ``<= 2 beta b``) and grows linearly
  in beta, smoothly *through* the beta = 1/2 majority threshold.
* signSGD-MV **breaks first**: below the threshold the majority vote
  hides the attack entirely (zero deviation — no warning), and crossing
  it flips the vote to the full ``2 * step`` dynamic range on every
  coordinate — maximal wrong-direction steps, a phase transition rather
  than degradation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_pipeline, flip_codes
from repro.core.aggregation import PackedWire, _unpack_rows

M, D = 40, 128
B = 0.05
STEP = 0.01
REPS = 400
KEY = jax.random.PRNGKey(0)
BETAS = (0.2, 0.45, 0.6)


@pytest.fixture(scope="module")
def updates():
    """Heterogeneous updates with strong per-coordinate signal |mean| = b/2
    (so clean signSGD-MV votes are near-certain and any breakage is the
    attack's doing, not vote noise)."""
    signs = jnp.where(jax.random.bernoulli(KEY, 0.5, (D,)), 1.0, -1.0)
    theta = 0.5 * B * signs
    noise = 0.15 * B * jax.random.normal(jax.random.fold_in(KEY, 1), (M, D))
    return theta, theta + noise


def _mean_estimate(pipe, upd, beta):
    """E[theta_hat] over the quantizer randomness at flip fraction beta."""
    n = int(M * beta)
    res0 = jnp.zeros((M, D))
    keys = jax.random.split(jax.random.fold_in(KEY, 2), REPS)
    f = jax.jit(
        jax.vmap(
            lambda k: pipe(k, upd, jnp.float32(B), res0, flip_n=n, flip_gate=True)[0]
        )
    )
    return jnp.mean(f(keys), axis=0)


def test_wire_flip_equals_dense_flip_codes(updates):
    """The packed-wire bit inversion is exactly flip_codes on the codes."""
    _, upd = updates
    pipe = build_pipeline("probit_plus")
    n = M // 4
    wire, _ = pipe.compressor.compress(KEY, upd, jnp.float32(B), jnp.zeros((M, D)))
    from repro.core import flip_wire

    flipped = flip_wire(wire, n)
    assert isinstance(flipped, PackedWire)
    codes = _unpack_rows(wire.packed, D)
    codes_flipped = _unpack_rows(flipped.packed, D)
    np.testing.assert_array_equal(
        np.asarray(codes_flipped), np.asarray(flip_codes(codes, n))
    )


def test_probit_degrades_gracefully(updates):
    """Deviation stays on the Theorem-2 line: <= 2 beta b per coordinate,
    ~linear in beta, no discontinuity at the beta = 1/2 threshold."""
    _, upd = updates
    pipe = build_pipeline("probit_plus")
    clean = _mean_estimate(pipe, upd, 0.0)
    devs = {}
    for beta in BETAS:
        att = _mean_estimate(pipe, upd, beta)
        devs[beta] = float(jnp.max(jnp.abs(att - clean)))
        assert devs[beta] <= 2 * beta * B * 1.05, (beta, devs[beta])
    # linear growth (beta ratio 3 between the endpoints), smooth across 1/2
    assert devs[0.2] < devs[0.45] < devs[0.6]
    assert 2.0 <= devs[0.6] / devs[0.2] <= 3.3
    assert devs[0.6] / devs[0.45] <= 1.6  # no phase transition at 1/2


def test_signsgd_mv_breaks_at_majority_threshold(updates):
    """Majority voting hides the attack below 1/2 (zero deviation), then
    reverses every coordinate at full step amplitude above it."""
    theta, upd = updates
    pipe = build_pipeline("signsgd_mv", agg_step=STEP)
    clean = _mean_estimate(pipe, upd, 0.0)
    dev_pre = float(jnp.max(jnp.abs(_mean_estimate(pipe, upd, 0.45) - clean)))
    att = _mean_estimate(pipe, upd, 0.6)
    dev_post = float(jnp.max(jnp.abs(att - clean)))
    wrong = float(jnp.mean(jnp.sign(att) != jnp.sign(theta)))
    assert dev_pre <= 0.1 * STEP, dev_pre  # silent until the threshold...
    assert dev_post >= 1.9 * STEP, dev_post  # ...then the full dynamic range
    assert wrong >= 0.95, wrong  # every coordinate steps the wrong way


def test_probit_outlasts_signsgd(updates):
    """The comparison the paper's Table I makes, in estimate space: past
    the majority threshold signSGD-MV's error is maximal relative to its
    own output range (ratio ~1), while PRoBit+'s stays the graceful
    2-beta-b fraction of its range."""
    _, upd = updates
    beta = 0.6
    probit = build_pipeline("probit_plus")
    signsgd = build_pipeline("signsgd_mv", agg_step=STEP)
    rel = {}
    for name, pipe, full_range in (
        ("probit", probit, 2 * B),
        ("signsgd", signsgd, 2 * STEP),
    ):
        clean = _mean_estimate(pipe, upd, 0.0)
        att = _mean_estimate(pipe, upd, beta)
        rel[name] = float(jnp.max(jnp.abs(att - clean))) / full_range
    assert rel["signsgd"] >= 0.9  # broken: worst representable output
    assert rel["probit"] <= beta * 1.05  # graceful: the 2*beta*b / 2b line
    assert rel["probit"] < rel["signsgd"]
