"""Beyond-paper extensions + architecture sanity checks."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

# analytic parameter-count targets (from the model cards / papers); the
# assembled spec tree must land within tolerance of the advertised size.
EXPECTED_PARAMS = {
    "starcoder2-3b": (3.0e9, 0.35),
    "xlstm-350m": (350e6, 0.55),  # our mLSTM uses pf=2 everywhere (~0.5B)
    "hubert-xlarge": (1.0e9, 0.25),
    "pixtral-12b": (12e9, 0.25),
    "qwen2-1.5b": (1.5e9, 0.35),
    "minitron-8b": (8e9, 0.25),
    "jamba-1.5-large-398b": (398e9, 0.15),
    "qwen3-moe-30b-a3b": (30e9, 0.15),
    "llama4-scout-17b-a16e": (109e9, 0.25),  # 109B total, 17B active
    "qwen1.5-4b": (4e9, 0.30),
}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_count_matches_model_card(arch):
    cfg = configs.get_config(arch)
    target, tol = EXPECTED_PARAMS[arch]
    n = cfg.n_params()
    assert abs(n - target) / target < tol, (arch, f"{n/1e9:.2f}B vs {target/1e9:.2f}B")


def test_moe_active_less_than_total():
    cfg = configs.get_config("qwen3-moe-30b-a3b")
    act, tot = cfg.n_active_params(), cfg.n_params()
    assert act < tot / 5  # ~3B active of ~30B
    assert 1.5e9 < act < 6e9


def test_error_feedback_runs_and_is_neutral():
    """EF-PRoBit+ (beyond paper) must run; because the Eq.-5 compressor is
    UNBIASED, EF is expected to be ~neutral (it corrects bias, not
    variance) — assert it at least does not catastrophically hurt."""
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=2000, n_test=400)
    parts = partition_label_skew(ytr, 8, 2, 80, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=32)
    accs = {}
    for ef in (False, True):
        cfg = FLConfig(
            n_clients=8, aggregator="probit_plus", rounds=30,
            local_epochs=2, error_feedback=ef,
        )
        sim = FLSimulation(
            cfg, p0,
            functools.partial(xent_loss, mlp_logits),
            functools.partial(accuracy, mlp_logits),
            cx, cy, {"x": xte, "y": yte},
        )
        sim.run(eval_every=30)
        accs[ef] = sim.history[-1]["acc"]
    assert accs[True] > accs[False] - 0.1


def test_ef_disabled_under_dp():
    """EF must be disabled when DP is on (residual reuse breaks the
    per-round accounting) — residuals stay zero."""
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, 4, 2, 50, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=16)
    cfg = FLConfig(
        n_clients=4, aggregator="probit_plus", rounds=3,
        local_epochs=1, error_feedback=True, dp_epsilon=0.1,
    )
    sim = FLSimulation(
        cfg, p0,
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx, cy, {"x": xte, "y": yte},
    )
    sim.run(eval_every=3)
    assert float(jnp.max(jnp.abs(sim.residuals))) == 0.0


def test_long500k_window_plan():
    from repro.launch.dryrun import cache_plan
    from repro.models.config import SHAPES

    # native window respected
    sc = configs.get_config("starcoder2-3b")
    assert cache_plan(sc, SHAPES["long_500k"]) == (4096, 4096)
    # dense variant window
    q = configs.get_config("qwen2-1.5b")
    assert cache_plan(q, SHAPES["long_500k"]) == (8192, 8192)
    # hybrid keeps full attention cache on its attn layers
    j = configs.get_config("jamba-1.5-large-398b")
    assert cache_plan(j, SHAPES["long_500k"]) == (524_288, 0)
    # decode_32k full cache for full-attention archs
    assert cache_plan(q, SHAPES["decode_32k"]) == (32_768, 0)


def test_unsampled_residuals_untouched():
    """Partial participation + error feedback: a round must update the EF
    residuals of exactly the sampled clients and leave every unsampled
    row bit-identical — guards the ``residuals.at[sel].set`` bookkeeping
    in the round core."""
    from repro.fl import rounds as R

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, 8, 2, 50, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=16)
    cfg = FLConfig(
        n_clients=8, participation=0.5, error_feedback=True,
        aggregator="probit_plus", rounds=2, local_epochs=1,
    )
    ctx = R.make_context(
        cfg, p0,
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx, cy, {"x": xte, "y": yte},
    )
    params = R.cell_params(cfg)
    state = R.init_state(ctx)
    key = jax.random.PRNGKey(cfg.seed)
    for _ in range(2):
        key, kb, kr = jax.random.split(key, 3)
        prev = np.asarray(state.residuals)
        state, _ = R.fl_round(ctx, params, kr, state, R.round_batches(ctx, kb))
        # recompute the round's participation sample with its exact key
        sel = np.asarray(
            jax.random.choice(
                jax.random.fold_in(kr, 99), cfg.n_clients,
                (cfg.n_active,), replace=False,
            )
        )
        unsampled = np.setdiff1d(np.arange(cfg.n_clients), sel)
        after = np.asarray(state.residuals)
        np.testing.assert_array_equal(after[unsampled], prev[unsampled])
        # sampled clients quantized something, so their residuals moved
        assert np.all(np.any(after[sel] != prev[sel], axis=1)), sel


def test_partial_participation():
    """Cross-device sampling: only a fraction of clients trains per round;
    the global model still learns and unsampled locals are untouched.

    Client subsampling makes this the most MC-chaotic tier-1 scenario, so
    the bounds are calibrated over seeds 0-19 (campaign engine, this
    exact config): final acc 0.1422 +/- 0.0218 (min 0.1075), final/first
    round mean-local-loss ratio <= 0.155 on every seed (loss starts at
    ~2.1). The learning signal is therefore asserted on the *loss*
    (final < 1.0, >3x margin over the worst observed 0.327) where the
    trajectory is robust, plus the acc at its mean - 3 sigma bound; the
    pinned seed 0 (acc 0.1075, loss 0.327) passes deterministically.
    """
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=2000, n_test=400)
    parts = partition_label_skew(ytr, 10, 2, 80, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=32)
    cfg = FLConfig(
        n_clients=10, participation=0.4, aggregator="probit_plus",
        rounds=40, local_epochs=2, seed=0,
    )
    assert cfg.n_active == 4
    sim = FLSimulation(
        cfg, p0,
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        cx, cy, {"x": xte, "y": yte},
    )
    sim.run(eval_every=40)
    assert sim.history[-1]["loss"] < 1.0, sim.history[-1]
    assert sim.history[-1]["acc"] > 0.075, sim.history[-1]
