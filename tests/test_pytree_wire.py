"""Pytree-wire parity suite.

The contract under test: per-layer chunked compress/aggregate over a
real parameter pytree is **bit-exact** with a flatten-per-leaf dense
reference built straight from the shared pipeline — including leaves
with size % 8 != 0, EF residual carry-over across rounds, top-k sparse
wires, and the kernel engine resolved via ``kernels/ops.resolve_engine``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_pipeline
from repro.fl.pytree_wire import (
    PytreeWireState,
    aggregate_pytree,
    init_wire_state,
    leaf_key,
    pytree_wire_bytes,
    stream_aggregate_pytree,
)
from repro.kernels import ops as kops

M = 6


def make_tree(key, m=M):
    """Deltas over a small pytree; the (7,) leaf has size % 8 != 0 and the
    (4, 5) leaf has size % 8 == 4, exercising pad-bit slicing."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": 0.02 * jax.random.normal(k1, (m, 4, 5)),
        "bias": 0.02 * jax.random.normal(k2, (m, 7)),
        "v": 0.02 * jax.random.normal(k3, (m, 2, 8)),
    }


def params_like(tree):
    return jax.tree.map(lambda x: x[0], tree)


def leafwise_dense_reference(pipeline, key, deltas, b_scalar, state):
    """The flatten-and-concat oracle: each leaf flattened to (M, d_l) and
    compressed/aggregated densely through the *same* pipeline with the
    same per-leaf key; thetas concatenated in tree_flatten order."""
    leaves, _ = jax.tree_util.tree_flatten(deltas)
    res_leaves = jax.tree.leaves(state.residuals)
    thetas, res_out = [], []
    for i, (dl, rl) in enumerate(zip(leaves, res_leaves)):
        m = dl.shape[0]
        d = int(dl[0].size)
        wire, r_new = pipeline.compressor.compress(
            leaf_key(key, i),
            dl.reshape(m, d).astype(jnp.float32),
            b_scalar,
            rl.reshape(m, d).astype(jnp.float32),
        )
        thetas.append(np.asarray(pipeline.estimate(wire)).ravel())
        res_out.append(np.asarray(r_new).ravel())
    return np.concatenate(thetas), np.concatenate(res_out)


def flat_theta(theta_tree):
    return np.concatenate(
        [np.asarray(l).ravel() for l in jax.tree.leaves(theta_tree)]
    )


@pytest.mark.parametrize("scheme", ["probit_plus", "signsgd_mv", "rsa"])
@pytest.mark.parametrize("client_chunk", [2, 3])
def test_stream_equals_oneshot_bit_exact(scheme, client_chunk):
    """Client-streamed == one-shot, exactly, for every count scheme."""
    pipeline = build_pipeline(scheme)
    deltas = make_tree(jax.random.PRNGKey(0))
    state = init_wire_state(params_like(deltas), M)
    key = jax.random.PRNGKey(42)
    b = jnp.float32(0.05)
    t1, s1 = aggregate_pytree(pipeline, key, deltas, b, state)
    t2, s2 = stream_aggregate_pytree(
        pipeline, key, deltas, b, state, client_chunk=client_chunk
    )
    for a, c in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        assert np.array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(jax.tree.leaves(s1.residuals), jax.tree.leaves(s2.residuals)):
        assert np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("rand_bits", [32, 16])
def test_leafwise_dense_reference(rand_bits):
    """Pytree aggregate == per-leaf dense pipeline reference, bit-exact."""
    pipeline = build_pipeline("probit_plus", rand_bits=rand_bits)
    deltas = make_tree(jax.random.PRNGKey(1))
    state = init_wire_state(params_like(deltas), M)
    key = jax.random.PRNGKey(7)
    b = jnp.float32(0.05)
    ref, _ = leafwise_dense_reference(pipeline, key, deltas, b, state)
    theta, _ = aggregate_pytree(pipeline, key, deltas, b, state)
    assert np.array_equal(flat_theta(theta), ref)
    # streamed path agrees with the same dense reference
    t_stream, _ = stream_aggregate_pytree(
        pipeline, key, deltas, b, state, client_chunk=3
    )
    assert np.array_equal(flat_theta(t_stream), ref)


def test_ef_carryover_two_rounds():
    """EF residuals advance identically on pytree and dense-reference
    paths across two rounds (carry-over is where EF bugs hide)."""
    pipeline = build_pipeline("probit_plus", error_feedback=True)
    b = jnp.float32(0.05)
    state = None
    deltas0 = make_tree(jax.random.PRNGKey(2))
    state = init_wire_state(params_like(deltas0), M)
    ref_state = state
    for r in range(2):
        deltas = make_tree(jax.random.PRNGKey(10 + r))
        key = jax.random.fold_in(jax.random.PRNGKey(5), r)
        ref_theta, ref_res = leafwise_dense_reference(
            pipeline, key, deltas, b, ref_state
        )
        theta, state = aggregate_pytree(pipeline, key, deltas, b, state)
        assert np.array_equal(flat_theta(theta), ref_theta)
        got_res = np.concatenate(
            [np.asarray(l).ravel() for l in jax.tree.leaves(state.residuals)]
        )
        assert np.array_equal(got_res, ref_res)
        # manually advance the reference state the same way
        leaves, treedef = jax.tree_util.tree_flatten(deltas)
        rl = jax.tree.leaves(ref_state.residuals)
        new_rl = []
        off = 0
        for dl, r0 in zip(leaves, rl):
            n = r0.size
            new_rl.append(
                jnp.asarray(ref_res[off : off + n]).reshape(r0.shape)
            )
            off += n
        ref_state = PytreeWireState(
            residuals=jax.tree_util.tree_unflatten(treedef, new_rl)
        )
    # EF actually carries mass: residuals are not all zero
    assert np.abs(got_res).max() > 0


def test_topk_pytree_matches_dense_reference():
    pipeline = build_pipeline("probit_plus", topk_frac=0.5)
    deltas = make_tree(jax.random.PRNGKey(3))
    state = init_wire_state(params_like(deltas), M)
    key = jax.random.PRNGKey(9)
    b = jnp.float32(0.05)
    ref, _ = leafwise_dense_reference(pipeline, key, deltas, b, state)
    theta, _ = aggregate_pytree(pipeline, key, deltas, b, state)
    assert np.array_equal(flat_theta(theta), ref)
    with pytest.raises(ValueError, match="top-k"):
        stream_aggregate_pytree(pipeline, key, deltas, b, state, client_chunk=2)


def test_kernel_engine_parity():
    """The kernel wire (resolved via resolve_engine — "ref" on CPU, the
    bit-identical engine) produces the same thetas as the pure path."""
    assert kops.resolve_engine() in ("ref", "pallas")
    pure = build_pipeline("probit_plus")
    kern = build_pipeline("probit_plus", use_kernels=True)
    deltas = make_tree(jax.random.PRNGKey(4))
    state = init_wire_state(params_like(deltas), M)
    key = jax.random.PRNGKey(11)
    b = jnp.float32(0.05)
    t_pure, _ = aggregate_pytree(pure, key, deltas, b, state)
    t_kern, _ = aggregate_pytree(kern, key, deltas, b, state)
    assert np.array_equal(flat_theta(t_pure), flat_theta(t_kern))


@pytest.mark.parametrize("rand_bits", [32, 16])
def test_counts_exact_past_255_clients(rand_bits):
    """M > 255 saturated cohort: every client votes a certain +1, so the
    Eq.-13 estimate is exactly +b. A uint8 count accumulator would wrap
    (300 % 256 = 44 -> theta ~ -0.70 b); int32 counts stay exact."""
    m = 300
    pipeline = build_pipeline("probit_plus", rand_bits=rand_bits)
    deltas = {"w": jnp.ones((m, 3, 3)), "bias": jnp.ones((m, 5))}
    state = init_wire_state(params_like(deltas), m)
    b = jnp.float32(0.5)  # deltas >= b everywhere -> p = 1.0
    theta, _ = aggregate_pytree(pipeline, jax.random.PRNGKey(0), deltas, b, state)
    for leaf in jax.tree.leaves(theta):
        assert np.array_equal(np.asarray(leaf), np.full(leaf.shape, 0.5, np.float32))


def test_weighted_counts_match_unweighted_at_unit_weights():
    pipeline = build_pipeline("probit_plus")
    deltas = make_tree(jax.random.PRNGKey(6))
    state = init_wire_state(params_like(deltas), M)
    key = jax.random.PRNGKey(13)
    b = jnp.float32(0.05)
    t0, _ = aggregate_pytree(pipeline, key, deltas, b, state)
    t1, _ = aggregate_pytree(
        pipeline, key, deltas, b, state, weights=jnp.ones((M,))
    )
    for a, c in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=0)


def test_wire_bytes_report():
    """8x/32x accounting: ideal packed bytes are ceil(d/8) per leaf."""
    pipeline = build_pipeline("probit_plus")
    deltas = make_tree(jax.random.PRNGKey(8))
    report = pytree_wire_bytes(pipeline, params_like(deltas), M)
    d_total = 4 * 5 + 7 + 2 * 8
    assert report["wire_bytes_int8"] == M * d_total
    assert report["wire_bytes_f32"] == M * 4 * d_total
    ideal = M * sum((d + 7) // 8 for d in (20, 7, 16))
    assert report["wire_bytes_ideal"] == ideal
    assert report["wire_bytes"] >= ideal
    # dense pipelines ship f32
    dense = pytree_wire_bytes(build_pipeline("fedavg"), params_like(deltas), M)
    assert dense["wire_bytes"] == M * 4 * d_total
