"""Property-based tests for the staleness weighting and the age-weighted
MLE (core/aggregation.py, core/quantizer.py).

Randomized over shapes, ages, decays, and weights (hypothesis when
installed, the deterministic fallback shim otherwise):

* staleness weights are non-negative, bounded by 1, exactly uniform at
  decay 0, monotone non-increasing in age, and normalize to a probability
  vector over valid slots;
* the age-weighted Eq.-13 estimate keeps the amplitude-immunity bound
  |theta_hat_i| <= b_i for arbitrary non-negative weights, including
  packed inputs with d % 8 != 0 (pad-bit handling);
* unit weights reproduce the integer vote counts exactly — the algebraic
  half of the async zero-latency bit-exactness guarantee.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    build_pipeline,
    ml_estimate_from_counts,
    packed_counts,
    packed_weighted_counts,
    staleness_weights,
)


@settings(deadline=None, max_examples=20)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 64),
    st.floats(0.0, 4.0),
)
def test_staleness_weights_basic_properties(seed, n, decay):
    """Non-negative, <= 1, zero on invalid slots, normalizable."""
    key = jax.random.PRNGKey(seed)
    ages = jax.random.randint(key, (n,), 0, 100)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7, (n,))
    w = np.asarray(staleness_weights(ages, jnp.float32(decay), valid))
    assert np.all(w >= 0.0) and np.all(w <= 1.0)
    assert np.all(w[~np.asarray(valid)] == 0.0)
    if w.sum() > 0:  # normalized weights form a probability vector
        p = w / w.sum()
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
        assert np.all(p >= 0.0) and np.all(p <= 1.0)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.floats(0.0, 4.0))
def test_staleness_weights_monotone_in_age(seed, n, decay):
    """Aging any upload by one round never raises its weight."""
    ages = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 100)
    d = jnp.float32(decay)
    w_now = np.asarray(staleness_weights(ages, d))
    w_older = np.asarray(staleness_weights(ages + 1, d))
    assert np.all(w_older <= w_now + 1e-7)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_staleness_weights_uniform_at_zero_decay(seed, n):
    """decay = 0 reduces to exactly uniform (all-ones) weighting — the
    degenerate case the bit-exact sync parity rides on."""
    ages = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 100)
    w = np.asarray(staleness_weights(ages, jnp.float32(0.0)))
    np.testing.assert_array_equal(w, np.ones(int(n), np.float32))


@settings(deadline=None, max_examples=10)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 12),
    st.sampled_from([1, 3, 8, 13, 64, 131, 256]),
)
def test_weighted_mle_bounded_by_b(seed, m, d):
    """|theta_hat_i| <= b_i for any non-negative staleness weights on any
    packed wire — d values deliberately include non-multiples of 8, so
    pad bits run through the weighted count path too."""
    key = jax.random.PRNGKey(seed)
    deltas = 0.05 * jax.random.normal(key, (m, d))
    b = jnp.float32(0.05)
    pipe = build_pipeline("probit_plus", chunk=64)
    wire, _ = pipe.compressor.compress(key, deltas, b, jnp.zeros((m, d)))
    ages = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, 20)
    decay = jax.random.uniform(jax.random.fold_in(key, 2), (), minval=0.0, maxval=3.0)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8, (m,))
    w = staleness_weights(ages, decay, valid)
    theta = np.asarray(pipe.estimate(wire, weights=w))
    assert theta.shape == (d,)
    assert np.all(np.isfinite(theta))
    assert np.all(np.abs(theta) <= np.asarray(wire.b) * (1 + 1e-6))


@settings(deadline=None, max_examples=10)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 12),
    st.sampled_from([1, 3, 8, 13, 64, 131]),
)
def test_unit_weights_reproduce_integer_counts(seed, m, d):
    """sum_m(1.0 * bit) == popcount: the weighted count at unit weights is
    exactly the integer vote count, and the weighted estimate equals the
    unweighted pipeline estimate bit for bit."""
    key = jax.random.PRNGKey(seed)
    deltas = 0.02 * jax.random.normal(key, (m, d))
    b = jnp.float32(0.05)
    pipe = build_pipeline("probit_plus", chunk=64)
    wire, _ = pipe.compressor.compress(key, deltas, b, jnp.zeros((m, d)))
    wcounts = np.asarray(
        packed_weighted_counts(wire.packed, jnp.ones((m,)), chunk=64)
    )
    counts = np.asarray(packed_counts(wire.packed, chunk=64))
    np.testing.assert_array_equal(wcounts, counts.astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(pipe.estimate(wire, weights=jnp.ones((m,)))),
        np.asarray(pipe.estimate(wire)),
    )


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 100))
def test_zero_weight_rows_drop_out(seed, m, d):
    """A zero-weighted (empty / fully stale) buffer slot contributes
    nothing: estimating with rows {0..m-1} and weight_j = 0 equals
    estimating the sub-wire without row j."""
    key = jax.random.PRNGKey(seed)
    deltas = 0.02 * jax.random.normal(key, (m, d))
    b = jnp.float32(0.05)
    pipe = build_pipeline("probit_plus", chunk=64)
    wire, _ = pipe.compressor.compress(key, deltas, b, jnp.zeros((m, d)))
    j = int(jax.random.randint(jax.random.fold_in(key, 1), (), 0, m))
    w = jnp.ones((m,)).at[j].set(0.0)
    import dataclasses

    sub = dataclasses.replace(
        wire, packed=jnp.delete(wire.packed, j, axis=0)
    )
    np.testing.assert_allclose(
        np.asarray(pipe.estimate(wire, weights=w)),
        np.asarray(pipe.estimate(sub, weights=jnp.ones((m - 1,)))),
        rtol=1e-5,
        atol=1e-7,
    )
