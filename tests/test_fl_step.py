"""Regression tests for the three hand-rolled-wire bugs fixed by routing
``launch/fl_step.py`` through the shared packed pipeline:

1. rand_bits=16 threshold wrap: ``(p * 65536).astype(uint16)`` is 0 at
   p = 1.0 — a *certain* +1 vote transmitted as a certain -1;
2. uint8 count accumulation wrapping mod 256 past 255 clients;
3. b-controller drift vs ``core.bcontrol.update_b_from_vote``.

Each test fails on the pre-rewrite implementation and passes now.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.aggregation import ClientCompressor, build_pipeline
from repro.core.bcontrol import (
    BControlConfig,
    BState,
    update_b,
    update_b_from_vote,
)
from repro.core.quantizer import threshold_u16, unpack_bits
from repro.distributed import set_mesh
from repro.launch import fl_step
from repro.launch.fl_step import DistFLConfig, make_fl_train_step, update_b_dist
from repro.launch.mesh import make_host_mesh
from repro.models import build_specs
from repro.models.spec import init_params, param_pspecs


# ---------------------------------------------------------------------------
# Bug 1: saturated-vote sign flip on the 16-bit wire
# ---------------------------------------------------------------------------

def test_threshold_u16_keeps_saturated_votes_certain():
    # p = 1.0 maps to 65536 — above every uint16 draw, so the vote stays
    # a certain +1. The buggy uint16 cast wraps it to 0 (a certain -1):
    assert int(threshold_u16(jnp.float32(1.0))) == 65536
    # The old uint16 threshold cannot represent certainty: whether the
    # out-of-range cast wraps (0, a certain -1) or saturates (65535),
    # some uint16 draw fails `u < thresh` — a saturated +1 vote can be
    # transmitted as -1. The uint32 threshold beats every draw.
    buggy = (jnp.float32(1.0) * 65536.0).astype(jnp.uint16)
    assert not bool(jnp.uint16(65535) < buggy)
    assert bool(jnp.uint32(65535) < threshold_u16(jnp.float32(1.0)))
    # interior probabilities are the plain floor
    assert int(threshold_u16(jnp.float32(0.5))) == 32768
    assert int(threshold_u16(jnp.float32(0.0))) == 0


@pytest.mark.parametrize("rand_bits", [32, 16])
def test_saturated_deltas_transmit_certain_votes(rand_bits):
    """|delta| >= b must produce deterministic codes for BOTH draw widths."""
    d = 12
    comp = ClientCompressor(rand_bits=rand_bits)
    b = jnp.float32(0.25)
    for sign in (1.0, -1.0):
        deltas = jnp.full((3, d), sign * 0.25, jnp.float32)
        wire, _ = comp.compress(
            jax.random.PRNGKey(0), deltas, b, jnp.zeros((3, d))
        )
        codes = np.asarray(
            jax.vmap(lambda p: unpack_bits(p, d))(wire.packed)
        )
        assert np.all(codes == sign), (rand_bits, sign, codes)


# ---------------------------------------------------------------------------
# Bug 2 (+1 end-to-end): exact counts past 255 clients through the real
# distributed train step
# ---------------------------------------------------------------------------

def tiny_cfg():
    return dataclasses.replace(
        configs.get_config("qwen2-1.5b"),
        name="qwen2-micro",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
        d_ff=64, vocab=64, d_head=16,
    )


@pytest.mark.parametrize("rand_bits", [32, 16])
def test_fl_step_counts_exact_at_m300(monkeypatch, rand_bits):
    """Rigged cohort of M = 300 clients whose every delta saturates at
    +1.0 >> b: all votes are certain +1, so counts == 300 exactly and the
    Eq.-13 update is precisely +b on every parameter.

    The pre-rewrite step fails this twice over: uint8 count accumulation
    wraps 300 -> 44 (theta ~ -0.70 b), and at rand_bits=16 the threshold
    wrap turns every certain +1 into a certain -1 (theta == -b).
    """
    m = 300
    cfg = tiny_cfg()
    # A loss whose gradient is exactly -100 per coordinate: one prox-free
    # local step at lr = 0.01 moves every weight by +1.0.
    fake_loss = lambda p, sb, c: -100.0 * sum(
        jnp.sum(l.astype(jnp.float32)) for l in jax.tree.leaves(p)
    )
    monkeypatch.setattr(fl_step, "train_loss", fake_loss)
    with set_mesh(make_host_mesh()):
        specs = build_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        fl = DistFLConfig(
            clients_per_round=m, local_steps=1, lr=0.01, rand_bits=rand_bits
        )
        step = jax.jit(make_fl_train_step(cfg, fl, param_pspecs(specs)))
        b = jnp.float32(0.5)
        batch = {"x": jnp.zeros((m, 1, 1, 1, 2), jnp.float32)}
        new_params, b_new, metrics = step(params, b, batch, jax.random.PRNGKey(1))
        expected = jax.tree.map(
            lambda w: (w.astype(jnp.float32) + 0.5).astype(w.dtype), params
        )
        # counts are exactly 300; under jit XLA folds the /M of Eq. 13 into
        # a reciprocal multiply (theta = 0.5 + O(1e-8)), so compare at float
        # tolerance — the bug signals are 0.85 b (uint8 wrap) and 2 b
        # (uint16 threshold), seven orders of magnitude above it.
        for got, want in zip(jax.tree.leaves(new_params), jax.tree.leaves(expected)):
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                atol=1e-5, rtol=0,
            )
        # constant loss across the single local step -> no-progress vote,
        # tie/negative contracts b by b_down (shared controller semantics)
        assert np.isclose(float(b_new), 0.5 * fl.b_down)
        assert float(metrics["wire_bytes"]) > 0


# ---------------------------------------------------------------------------
# Bug 3: b-controller parity with the simulation path
# ---------------------------------------------------------------------------

def test_update_b_parity_with_simulation():
    fl = DistFLConfig(b_up=1.05, b_down=0.9)
    cfg = BControlConfig(mode="dynamic", up=fl.b_up, down=fl.b_down)
    b0 = jnp.float32(0.02)
    for vote in (-4.0, 0.0, 7.0):
        got = update_b_dist(b0, jnp.float32(vote), fl)
        ref = update_b_from_vote(
            BState(b=b0, prev_vote=jnp.float32(0.0)), jnp.float32(vote), cfg
        ).b
        assert float(got) == float(ref), vote
    # tie vote contracts — the case a hand-rolled `votes > 0` branch can
    # silently get wrong relative to fl/rounds.py
    assert np.isclose(float(update_b_dist(b0, jnp.float32(0.0), fl)), 0.02 * 0.9)
    # one-shot bit-stream composition used by fl/rounds agrees too
    bits = jnp.asarray([1, -1, -1, 1, 1], jnp.int8)
    ref_stream = update_b(
        BState(b=b0, prev_vote=jnp.float32(0.0)), bits, cfg
    ).b
    got_stream = update_b_dist(b0, jnp.sum(bits.astype(jnp.float32)), fl)
    assert float(got_stream) == float(ref_stream)


# ---------------------------------------------------------------------------
# Wire-schedule parity: the mesh step speaks the pytree-wire schedule
# ---------------------------------------------------------------------------

def test_fl_step_pipeline_uses_shared_registry():
    """The step's quantizer/estimator are the registry pipeline objects —
    no hand-rolled math left to drift."""
    pipe = build_pipeline("probit_plus", rand_bits=16)
    assert pipe.compressor.rand_bits == 16
    with pytest.raises(ValueError, match="rand_bits"):
        build_pipeline("probit_plus", rand_bits=8)
    with pytest.raises(ValueError, match="kernel"):
        ClientCompressor(rand_bits=16, use_kernels=True)
    with pytest.raises(ValueError, match="top-k"):
        ClientCompressor(rand_bits=16, topk_frac=0.5)
