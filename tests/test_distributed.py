"""Distribution-layer tests.

The production 512-device dry-run is exercised by ``repro.launch.dryrun``
(separate process — XLA device-count flag). Here we test:
  - the logical-axis sharding rules,
  - the distributed FL round on a 1-device host mesh (semantics),
  - a REAL subprocess dry-run of one reduced case on 8 fake devices.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import set_mesh, spec_for, use_batch_axes
from repro.launch.fl_step import DistFLConfig, make_fl_train_step
from repro.launch.mesh import make_host_mesh
from repro.models import build_specs, sample_batch
from repro.models.spec import init_params, param_pspecs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spec_rules_divisibility():
    mesh = make_host_mesh()  # sizes 1 -> everything divisible
    with set_mesh(mesh):
        assert spec_for(("batch", None), (4, 8)) == P("data", None)
        assert spec_for(("heads", None), (3, 8)) == P("model", None)


def test_spec_rules_drop_nondivisible():
    # simulate a 2-way model axis with a 3-head tensor: must replicate
    import repro.distributed as dist

    class FakeMesh:
        axis_names = ("data", "model")
        axis_sizes = (2, 2)
        empty = False

    old = dist.current_mesh
    dist.current_mesh = lambda: FakeMesh()
    try:
        assert dist.spec_for(("heads",), (3,)) == P(None)
        assert dist.spec_for(("heads",), (4,)) == P("model")
        # duplicate axis use: second logical wanting "model" is dropped
        assert dist.spec_for(("seq", "kv"), (8, 8)) == P("model", None)
    finally:
        dist.current_mesh = old


def test_fl_round_semantics_host_mesh():
    """The distributed FL round must decrease client loss and keep the
    global params finite on a 1-device mesh (pure semantics check)."""
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    with set_mesh(make_host_mesh()):
        specs = build_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        fl = DistFLConfig(clients_per_round=2, local_steps=2, lr=0.05)
        step = jax.jit(make_fl_train_step(cfg, fl, param_pspecs(specs)))
        b = jnp.float32(0.01)
        sb = sample_batch(cfg, 2, 32, "train")
        batch = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None, None], (2, 1, 2) + a.shape), sb
        )
        losses = []
        key = jax.random.PRNGKey(1)
        for r in range(8):
            key, kr = jax.random.split(key)
            params, b, m = step(params, b, batch, kr)
            losses.append(float(m["loss_first"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0], losses  # global model is learning
        gn = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(params))
        assert bool(jnp.isfinite(gn))


def test_counts_bounded_by_clients():
    """Vote counts are in [0, M] — the ML estimate stays within [-b, b]."""
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    with set_mesh(make_host_mesh()):
        specs = build_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        p0 = jax.tree.map(lambda a: a.astype(jnp.float32), params)
        fl = DistFLConfig(clients_per_round=4, local_steps=1, lr=0.0)  # lr=0: delta=0
        step = jax.jit(make_fl_train_step(cfg, fl, param_pspecs(specs)))
        sb = sample_batch(cfg, 2, 32, "train")
        batch = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None, None], (4, 1, 1) + a.shape), sb
        )
        b = jnp.float32(0.01)
        new_params, _, _ = step(params, b, batch, jax.random.PRNGKey(3))
        # with delta == 0 the update is pure quantization noise <= b
        # (plus one bf16 rounding ulp of the parameter value, ~0.008 near 1.0)
        diff = jax.tree.map(
            lambda a, c: jnp.max(jnp.abs(a.astype(jnp.float32) - c.astype(jnp.float32))),
            new_params, p0,
        )
        assert max(float(x) for x in jax.tree.leaves(diff)) <= 0.01 + 0.008


@pytest.mark.slow
def test_dryrun_subprocess_8_devices(tmp_path):
    """True SPMD lower+compile in a subprocess with 8 placeholder devices
    and a reduced config — the same code path as the 512-chip dry-run."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json, sys
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import build_specs, abstract_params
        from repro.models.spec import param_pspecs
        from repro.launch.fl_step import DistFLConfig, make_fl_train_step
        from repro.models import input_specs, input_logical
        from repro.distributed import set_mesh, spec_for
        from repro.launch.mesh import make_mesh

        cfg = configs.reduced(configs.get_config("qwen3-moe-30b-a3b"))
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        with set_mesh(mesh):
            specs = build_specs(cfg)
            pspecs = param_pspecs(specs, fsdp_axis="data")
            params_abs = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                    sharding=NamedSharding(mesh, sp)),
                abstract_params(specs), pspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            struct = input_specs(cfg, 2, 64, "train")
            batch_abs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct((2, 2, 1) + a.shape, a.dtype,
                    sharding=NamedSharding(mesh, P(None, "pod", None, "data"))),
                struct, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            step = make_fl_train_step(cfg, DistFLConfig(clients_per_round=4), pspecs)
            b_abs = jax.ShapeDtypeStruct((), jnp.float32, sharding=NamedSharding(mesh, P()))
            k_abs = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=NamedSharding(mesh, P()))
            compiled = jax.jit(step).lower(params_abs, b_abs, batch_abs, k_abs).compile()
            txt = compiled.as_text()
            has_coll = any(op in txt for op in ("all-reduce", "all-gather", "reduce-scatter"))
            print(json.dumps({"ok": True, "has_collectives": has_coll}))
        """
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        timeout=540,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert payload["ok"] and payload["has_collectives"]
