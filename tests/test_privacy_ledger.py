"""Privacy-ledger subsystem + DP-accounting boundary regressions.

Covers the ISSUE-4 acceptance criteria:

* ``privacy_loss`` is finite for all ``delta in [-b, b]`` including the
  endpoints (where Eq. 5's probability is exactly 0/1);
* ``rounds_for_budget`` returns 0 when one round already busts the
  budget, and T at a budget exactly equal to the T-round cost;
* degenerate-input identities: ``rounds = 0`` reports eps = 0 under
  every accountant, and ``q = 1`` amplification is bit-identical to the
  unamplified per-round eps (no log/exp float drift);
* ledger invariants (monotone in rounds, monotone-decreasing in q,
  amplified <= unamplified per accountant) and the closed-form match
  after real runs through both ``FLSimulation`` and a ``run_campaign``
  grid over (participation, eps);
* the tier-1 smoke path of ``benchmarks/fig_privacy_amplification.py``
  (tiny grid, 2 rounds).
"""

import functools
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ACCOUNTANTS,
    PrivacyLedger,
    advanced_composition,
    amplified_epsilon,
    basic_composition,
    privacy_loss,
    rounds_for_budget,
    subsampled_composition,
)
from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import CampaignSpec, Task, group_signature, run_campaign

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig_privacy_amplification import fig_privacy_spec  # noqa: E402


# ---------------------------------------------------------------------------
# Satellite 1: privacy_loss boundary regression
# ---------------------------------------------------------------------------


class TestPrivacyLossBoundary:
    def test_finite_at_exact_boundary(self):
        """delta = +-b exactly (binarize prob 0/1) must not produce inf/NaN."""
        b = jnp.full((4,), 0.05)
        delta_a = jnp.array([0.05, -0.05, 0.05, -0.05])
        delta_b = jnp.array([-0.05, 0.05, 0.0, -0.05])
        pl = privacy_loss(delta_a, delta_b, b)
        assert bool(jnp.isfinite(pl))

    def test_finite_on_full_range_grid(self):
        """Finite for every delta in [-b, b] including both endpoints."""
        b = jnp.float32(0.03)
        grid = jnp.linspace(-0.03, 0.03, 61)  # includes +-b exactly
        da, db = jnp.meshgrid(grid, grid)
        pl = jax.vmap(
            lambda a, c: privacy_loss(a[None], c[None], b[None])
        )(da.ravel(), db.ravel())
        assert bool(jnp.all(jnp.isfinite(pl)))

    def test_finite_beyond_range(self):
        """Out-of-range updates clip to the boundary and stay finite."""
        pl = privacy_loss(jnp.array([5.0]), jnp.array([-5.0]), jnp.array([0.01]))
        assert bool(jnp.isfinite(pl))

    def test_near_boundary_interior_loss_not_shrunk(self):
        """The clamp sits on the float32 probability-grid edges, so a
        representable interior probability — even one ulp from 0 — must
        pass through unclamped (no silent under-reporting)."""
        b = jnp.float32(1.0)
        # delta/b = -1 + 2^-24 is representable; Eq. 5 gives p = 2^-25,
        # the smallest realizable nonzero probability.
        da = jnp.float32(-1.0 + 2.0**-24)
        db = jnp.float32(0.0)
        pa = float(jnp.log(jnp.float32(2.0**-25)))
        expected = abs(pa - math.log(0.5))  # loss on the +1 outcome
        pl = float(privacy_loss(da[None], db[None], b[None]))
        assert pl == pytest.approx(expected, rel=1e-6)

    def test_interior_losses_unchanged_by_clamp(self):
        """The clamp only bites at the boundary: a Theorem-3-respecting b
        keeps probabilities far inside [1e-6, 1-1e-6], so the loss is
        still bounded by eps (the original Theorem-3 test contract)."""
        from repro.core import DPConfig, dp_b_floor

        key = jax.random.PRNGKey(0)
        eps, delta1 = 0.1, 2e-4
        delta_a = 0.01 * jax.random.normal(key, (32,))
        v = jax.random.normal(jax.random.fold_in(key, 1), (32,))
        delta_b = delta_a + v / jnp.sum(jnp.abs(v)) * delta1
        floor = dp_b_floor(
            jnp.maximum(jnp.abs(delta_a), jnp.abs(delta_b)).max(),
            DPConfig(eps, delta1),
        )
        pl = float(privacy_loss(delta_a, delta_b, jnp.full((32,), floor)))
        assert 0.0 < pl <= eps * 1.0001


# ---------------------------------------------------------------------------
# Satellite 2: rounds_for_budget boundaries
# ---------------------------------------------------------------------------


class TestRoundsForBudget:
    def test_zero_when_budget_below_one_round(self):
        eps = 0.1
        one_round = advanced_composition(eps, 1)[0]
        assert rounds_for_budget(one_round * 0.99, eps) == 0
        assert rounds_for_budget(0.0, eps) == 0
        assert rounds_for_budget(-1.0, eps) == 0

    def test_exactly_one_round(self):
        eps = 0.1
        one_round = advanced_composition(eps, 1)[0]
        assert rounds_for_budget(one_round, eps) == 1

    def test_budget_exactly_at_T_rounds(self):
        eps = 0.05
        for T in (2, 7, 31):
            budget = advanced_composition(eps, T)[0]
            assert rounds_for_budget(budget, eps) == T

    def test_returned_T_affordable_and_maximal(self):
        eps, budget = 0.1, 3.0
        t = rounds_for_budget(budget, eps)
        assert advanced_composition(eps, t)[0] <= budget
        assert advanced_composition(eps, t + 1)[0] > budget

    def test_disabled_dp_rejected(self):
        """eps_per_round <= 0 would make every horizon affordable — the
        old code spun the search loop to its 10M cap; now it raises."""
        with pytest.raises(ValueError, match="eps_per_round"):
            rounds_for_budget(1.0, 0.0)
        with pytest.raises(ValueError, match="eps_per_round"):
            rounds_for_budget(1.0, -0.1)


# ---------------------------------------------------------------------------
# Satellite 3: degenerate-input identities (property-tested)
# ---------------------------------------------------------------------------


class TestDegenerateIdentities:
    @settings(deadline=None, max_examples=30)
    @given(st.floats(1e-4, 2.0))
    def test_zero_rounds_is_zero_eps_every_accountant(self, eps):
        # zero mechanisms spend neither eps nor delta — matches the
        # ledger's empty event log exactly
        assert advanced_composition(eps, 0) == (0.0, 0.0)
        assert basic_composition(eps, 0) == 0.0
        assert subsampled_composition(eps, 0, 0.5) == 0.0
        led = PrivacyLedger(eps, 0.5)
        for acc in ACCOUNTANTS:
            assert led.compose(acc) == (0.0, 0.0)
            assert led.eps_at(0, acc) == 0.0
            assert led.trajectory(0, acc).shape == (0,)

    @settings(deadline=None, max_examples=30)
    @given(st.floats(1e-4, 2.0))
    def test_q1_amplification_bit_identical(self, eps):
        """q = 1 must short-circuit: no ln(1 + (e^eps - 1)) round-trip."""
        assert amplified_epsilon(eps, 1.0) == eps
        led = PrivacyLedger(eps, 1.0, "subsampled")
        led_basic = PrivacyLedger(eps, 1.0, "basic")
        led.record_round(5)
        led_basic.record_round(5)
        assert led.per_round_epsilon == eps
        assert led.eps_spent == led_basic.eps_spent
        assert np.array_equal(led.trajectory(9), led_basic.trajectory(9))

    @settings(deadline=None, max_examples=30)
    @given(st.floats(1e-4, 2.0), st.floats(0.01, 0.99))
    def test_amplification_strictly_tightens(self, eps, q):
        amp = amplified_epsilon(eps, q)
        assert 0.0 < amp < eps

    def test_edge_rates(self):
        assert amplified_epsilon(0.5, 0.0) == 0.0
        assert amplified_epsilon(0.0, 0.5) == 0.0
        assert amplified_epsilon(-1.0, 0.5) == 0.0


# ---------------------------------------------------------------------------
# Satellite 4a: ledger invariants
# ---------------------------------------------------------------------------


class TestLedgerInvariants:
    @settings(deadline=None, max_examples=20)
    @given(st.floats(1e-3, 1.0), st.floats(0.05, 1.0))
    def test_monotone_in_rounds(self, eps, q):
        for acc in ACCOUNTANTS:
            traj = PrivacyLedger(eps, q, acc).trajectory(12)
            assert np.all(np.diff(traj) > 0.0), acc

    @settings(deadline=None, max_examples=20)
    @given(st.floats(1e-3, 1.0))
    def test_monotone_decreasing_in_q(self, eps):
        qs = (0.1, 0.3, 0.6, 1.0)
        spent = []
        for q in qs:
            led = PrivacyLedger(eps, q, "subsampled")
            led.record_round(10)
            spent.append(led.eps_spent)
        assert all(a < b for a, b in zip(spent, spent[1:]))

    @settings(deadline=None, max_examples=20)
    @given(st.floats(1e-3, 1.0), st.floats(0.05, 0.95))
    def test_amplified_le_unamplified_every_accountant(self, eps, q):
        for acc in ACCOUNTANTS:
            sub, full = PrivacyLedger(eps, q, acc), PrivacyLedger(eps, 1.0, acc)
            sub.record_round(8)
            full.record_round(8)
            assert sub.eps_spent <= full.eps_spent, acc
        # and the subsampled accountant beats basic strictly at q < 1
        led = PrivacyLedger(eps, q)
        led.record_round(8)
        assert led.compose("subsampled")[0] < led.compose("basic")[0]

    def test_compose_matches_closed_form_trajectory(self):
        """Recording T homogeneous events == the closed-form curve, bit
        for bit (fsum of T copies is the correctly-rounded product)."""
        for acc in ACCOUNTANTS:
            led = PrivacyLedger(0.1, 0.5, acc)
            for t in range(1, 25):
                led.record_round()
                assert led.eps_spent == led.trajectory(t)[-1], (acc, t)

    def test_heterogeneous_events(self):
        led = PrivacyLedger(0.1, 0.5)
        led.record(0.1, 0.5)
        led.record(0.2, 1.0)
        assert led.compose("basic")[0] == pytest.approx(0.3)
        assert led.compose("subsampled")[0] == pytest.approx(
            amplified_epsilon(0.1, 0.5) + 0.2
        )
        # trajectory() follows the heterogeneous log, not the configured
        # homogeneous closed form — its last point IS eps_spent
        for acc in ACCOUNTANTS:
            traj = led.trajectory(accountant=acc)
            assert traj.shape == (2,)
            assert traj[-1] == led.compose(acc)[0], acc
            assert traj[0] == PrivacyLedger(0.1, 0.5, acc).eps_at(1), acc
        # record() validates like the constructor
        with pytest.raises(ValueError, match="q must be"):
            led.record(0.1, 1.5)
        led.record(-1.0)  # negative eps clamps to 0, like the constructor
        assert led.events[-1].epsilon == 0.0

    def test_validation(self):
        with pytest.raises(ValueError, match="accountant"):
            PrivacyLedger(0.1, accountant="zcdp")
        with pytest.raises(ValueError, match="q must be"):
            PrivacyLedger(0.1, q=1.5)
        with pytest.raises(ValueError, match="delta_slack"):
            PrivacyLedger(0.1, delta_slack=0.0)
        with pytest.raises(ValueError, match="accountant"):
            PrivacyLedger(0.1).compose("zcdp")


# ---------------------------------------------------------------------------
# Acceptance: the exact subsampled per-round numbers
# ---------------------------------------------------------------------------


class TestAcceptanceNumbers:
    def test_half_participation_eps_point_one(self):
        """participation=0.5, eps=0.1: per-round eps = ln(1+0.5(e^0.1-1))
        to 1e-12 and strictly below 0.1."""
        led = FLConfig(n_clients=20, participation=0.5, dp_epsilon=0.1).ledger()
        expect = math.log(1.0 + 0.5 * (math.exp(0.1) - 1.0))
        assert abs(led.per_round_epsilon - expect) < 1e-12
        assert led.per_round_epsilon < 0.1

    def test_full_participation_reproduces_conservative(self):
        """participation=1.0 reproduces the pre-ledger numbers exactly."""
        cfg = FLConfig(n_clients=20, participation=1.0, dp_epsilon=0.1, rounds=30)
        led = cfg.ledger()
        led.record_round(cfg.rounds)
        assert led.eps_spent == basic_composition(0.1, 30)

    def test_sampling_rate_uses_realized_cohort(self):
        """q comes from n_active/M (the floor the runtime actually takes),
        not the raw participation fraction."""
        cfg = FLConfig(n_clients=21, participation=0.5, dp_epsilon=0.1)
        assert cfg.n_active == 10
        assert cfg.sampling_rate == pytest.approx(10 / 21)
        assert FLConfig(n_clients=21, participation=1.0).sampling_rate == 1.0

    def test_config_validation(self):
        with pytest.raises(ValueError, match="dp_accountant"):
            FLConfig(dp_accountant="zcdp")
        FLConfig(dp_accountant="renyi")  # first-class since ISSUE 5
        with pytest.raises(ValueError, match="participation"):
            FLConfig(participation=0.0)
        with pytest.raises(ValueError, match="participation"):
            FLConfig(participation=1.5)

    def test_accountant_does_not_split_campaign_groups(self):
        """dp_accountant is host-side bookkeeping — cells differing only
        there must share one compiled program."""
        base = dict(n_clients=6, dp_epsilon=0.1, participation=0.5)
        assert group_signature(FLConfig(**base)) == group_signature(
            FLConfig(**base, dp_accountant="basic")
        )
        assert group_signature(FLConfig(**base)) != group_signature(
            FLConfig(**{**base, "dp_epsilon": 0.2})
        )


# ---------------------------------------------------------------------------
# ISSUE-5 satellite: the Rényi (moments) accountant
# ---------------------------------------------------------------------------


class TestRenyiAccountant:
    """RDP of randomized response, composed in the Rényi domain.

    The load-bearing property: the reported eps DOMINATES (is <=) the
    ``advanced`` DRV eps on every multi-round trajectory — renyi is a
    strict upgrade, never a looser bound — and is also <= ``basic``
    (the alpha -> inf endpoint of the RR curve is pure composition).
    """

    @settings(deadline=None, max_examples=40)
    @given(st.floats(1e-4, 4.0), st.integers(1, 400))
    def test_dominates_advanced_on_every_trajectory(self, eps, rounds):
        led = PrivacyLedger(eps, accountant="renyi")
        renyi = led.trajectory(rounds, "renyi")
        advanced = led.trajectory(rounds, "advanced")
        basic = led.trajectory(rounds, "basic")
        assert np.all(renyi <= advanced + 1e-12)
        assert np.all(renyi <= basic + 1e-12)
        assert np.all(renyi >= 0.0)

    def test_tightens_the_small_eps_multiround_regime(self):
        """The ROADMAP motivation: at eps ~ 0.1 over many rounds, renyi
        beats DRV strictly (and DRV already beats basic there)."""
        led = PrivacyLedger(0.1, accountant="renyi")
        renyi = led.eps_at(100, "renyi")
        advanced = led.eps_at(100, "advanced")
        basic = led.eps_at(100, "basic")
        assert renyi < advanced < basic

    @settings(deadline=None, max_examples=20)
    @given(st.floats(1e-3, 1.0))
    def test_rr_rdp_curve_shape(self, eps):
        from repro.core.ledger import _ALPHA_GRID, rr_renyi_divergence

        rdp = rr_renyi_divergence(eps, _ALPHA_GRID)
        assert np.all(rdp > 0.0) and np.all(np.isfinite(rdp))
        # bounded by the pure-DP level, approached as alpha -> inf
        assert np.all(rdp <= eps + 1e-12)
        assert rdp[-1] == pytest.approx(eps, rel=1e-3)
        assert np.all(np.diff(rdp) >= -1e-15)  # non-decreasing in alpha

    def test_compose_matches_trajectory_and_converts_at_delta_slack(self):
        led = PrivacyLedger(0.1, accountant="renyi")
        led.record_round(50)
        assert led.eps_spent == led.trajectory(50)[-1]
        assert led.delta_spent == led.delta_slack
        assert "renyi" in led.report()
        assert led.report()["renyi"]["eps"] == led.eps_spent

    def test_zero_eps_reports_zero(self):
        led = PrivacyLedger(0.0, accountant="renyi")
        led.record_round(10)
        assert led.eps_spent == 0.0 and led.delta_spent == 0.0
        assert np.all(led.trajectory(10) == 0.0)

    def test_heterogeneous_composition(self):
        """Per-event RDP curves sum; a (0.1, 0.3) log lands between its
        homogeneous brackets and below their basic sum."""
        led = PrivacyLedger(0.1, accountant="renyi")
        led.record(0.1)
        led.record(0.3)
        lo = PrivacyLedger(0.1, accountant="renyi").eps_at(2)
        hi = PrivacyLedger(0.3, accountant="renyi").eps_at(2)
        assert lo <= led.eps_spent <= hi
        assert led.eps_spent <= 0.4 + 1e-12

    def test_config_wires_renyi_through_ledger(self):
        cfg = FLConfig(dp_epsilon=0.1, dp_accountant="renyi", rounds=40)
        traj = cfg.ledger().trajectory(cfg.rounds)
        drv = FLConfig(
            dp_epsilon=0.1, dp_accountant="advanced", rounds=40
        ).ledger().trajectory(40)
        assert traj.shape == (40,)
        assert np.all(traj <= drv + 1e-12)


# ---------------------------------------------------------------------------
# Satellite 4b: end-to-end through FLSimulation and run_campaign
# ---------------------------------------------------------------------------


N, ROUNDS = 4, 3


@pytest.fixture(scope="module")
def tiny_task():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=400, n_test=100)
    parts = partition_label_skew(ytr, N, 2, 40, seed=1)
    return Task(
        init_params=init_mlp(jax.random.PRNGKey(0), hidden=8),
        loss_fn=functools.partial(xent_loss, mlp_logits),
        acc_fn=functools.partial(accuracy, mlp_logits),
        client_x=np.stack([xtr[i] for i in parts]),
        client_y=np.stack([ytr[i] for i in parts]),
        test={"x": xte, "y": yte},
    )


class TestLedgerEndToEnd:
    def test_flsimulation_records_and_reports(self, tiny_task):
        cfg = FLConfig(
            n_clients=N, rounds=ROUNDS, local_epochs=1,
            dp_epsilon=0.1, participation=0.5, b_mode="fixed",
        )
        sim = FLSimulation(
            cfg, tiny_task.init_params, tiny_task.loss_fn, tiny_task.acc_fn,
            tiny_task.client_x, tiny_task.client_y, tiny_task.test,
        )
        sim.run(eval_every=1)
        assert sim.ledger.rounds == ROUNDS
        expect = cfg.ledger().eps_at(ROUNDS)  # closed form
        assert sim.ledger.eps_spent == pytest.approx(expect, rel=1e-12)
        eps_hist = [h["eps_spent"] for h in sim.history]
        assert eps_hist == pytest.approx(
            list(cfg.ledger().trajectory(ROUNDS)), rel=1e-12
        )
        # a second run() keeps accumulating (one event per executed round)
        sim.run(rounds=2, eval_every=2)
        assert sim.ledger.rounds == ROUNDS + 2

    def test_campaign_grid_over_participation_and_eps(self, tiny_task):
        """The (participation x eps) grid carries eps_spent as a first-
        class metric matching the closed-form composition, and the
        cumulative trajectory lands in the campaign JSON."""
        spec = CampaignSpec.from_grid(
            base=dict(n_clients=N, rounds=ROUNDS, local_epochs=1, b_mode="fixed"),
            axes={"participation": (0.5, 1.0), "dp_epsilon": (0.1, 0.5)},
            seeds=(0, 1),
        )
        result = run_campaign(spec, lambda cfg: tiny_task)
        for cell_spec in spec.cells:
            cfg = spec.config(cell_spec)
            cell = result.cell(cell_spec.name)
            eps = cell.metrics["eps_spent"]
            assert eps.shape == (2, ROUNDS)
            assert np.array_equal(eps[0], eps[1])  # seed-independent
            assert np.all(np.diff(eps[0]) > 0)  # monotone in rounds
            np.testing.assert_allclose(
                eps[0], cfg.ledger().trajectory(ROUNDS), rtol=1e-12
            )
            assert cell.eps_spent() == pytest.approx(
                cfg.ledger().eps_at(ROUNDS), rel=1e-12
            )
        # participation=1.0 cells report today's conservative numbers...
        full = result.cell("participation=1.0|dp_epsilon=0.1")
        np.testing.assert_array_equal(
            full.metrics["eps_spent"][0], 0.1 * np.arange(1, ROUNDS + 1)
        )
        # ...and subsampling strictly tightens them at equal eps
        half = result.cell("participation=0.5|dp_epsilon=0.1")
        assert np.all(
            half.metrics["eps_spent"][0] < full.metrics["eps_spent"][0]
        )
        # the trajectory appears in the JSON artifact
        js = result.to_json()
        traj = js["cells"]["participation=0.5|dp_epsilon=0.1"][
            "trajectory_mean"]["eps_spent"]
        np.testing.assert_allclose(
            traj, spec.config(spec.cells[0]).ledger().trajectory(ROUNDS),
            rtol=1e-12,
        )

    def test_non_dp_cells_report_zero(self, tiny_task):
        spec = CampaignSpec.from_grid(
            base=dict(n_clients=N, rounds=2, local_epochs=1, b_mode="fixed"),
            axes={"participation": (0.5,)},
            seeds=(0,),
        )
        result = run_campaign(spec, lambda cfg: tiny_task)
        assert np.all(result.cells[0].metrics["eps_spent"] == 0.0)
        assert result.cells[0].eps_spent() == 0.0


# ---------------------------------------------------------------------------
# Satellite 6: benchmark smoke path (tiny grid, 2 rounds)
# ---------------------------------------------------------------------------


class TestAmplificationFigureSmoke:
    def test_tiny_grid_two_rounds(self, tiny_task, tmp_path):
        spec = fig_privacy_spec(
            rounds=2,
            participations=(0.5, 1.0),
            epsilons=(0.1,),
            aggregators=("probit_plus",),
            n_clients=N,
            seeds=(0,),
        )
        result = run_campaign(spec, lambda cfg: tiny_task)
        assert len(result.cells) == 2
        path = result.save(str(tmp_path / "fig_priv_smoke.json"))
        with open(path) as f:
            js = json.load(f)
        for cell_spec in spec.cells:
            cfg = spec.config(cell_spec)
            traj = js["cells"][cell_spec.name]["trajectory_mean"]["eps_spent"]
            np.testing.assert_allclose(
                traj, cfg.ledger().trajectory(2), rtol=1e-12
            )
        sub = js["cells"]["participation=0.5|dp_epsilon=0.1|aggregator=probit_plus"]
        full = js["cells"]["participation=1.0|dp_epsilon=0.1|aggregator=probit_plus"]
        assert sub["trajectory_mean"]["eps_spent"][-1] < \
            full["trajectory_mean"]["eps_spent"][-1]
