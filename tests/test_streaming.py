"""Streaming client-chunk aggregation: parity + memory-bound tests.

The streaming round (``FLConfig.client_chunk > 0``) must be a pure
execution-strategy change — same estimates, same trajectories, chunk size
invisible. Three layers are pinned here:

* **count protocol** — ``init_counts / accumulate_counts / finalize``
  over arbitrary client splits equals the one-shot ``aggregate`` for
  every registered aggregator (integer-exact for the count schemes,
  including 0/1 active-client masks and fractional staleness-style
  weights; FedAvg's running-sum protocol to f32 reassociation);
* **round parity** — dense vs chunked ``stream_fl_round`` at chunk
  sizes that do and do not divide M, for all five aggregators, under
  partial participation, error feedback, and the Byzantine attacks the
  streaming gate admits (bit-exact in eager, <= 1e-6 under jit; the
  model's d = 450 exercises d % 8 != 0 on the packed wire);
* **memory bound** — a subprocess under a hard ``RLIMIT_AS`` cap runs
  M = 60k clients x d = 4866 chunk-bounded where the dense round
  provably OOMs (the CI ``stream-smoke`` job runs exactly this:
  ``-k "smoke or rss"``).
"""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_pipeline
from repro.core.quantizer import byte_popcount, packed_counts
from repro.data import make_classification, partition_label_skew
from repro.fl import rounds as R
from repro.fl.runtime import FLConfig
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

AGGREGATORS = ("probit_plus", "signsgd_mv", "rsa", "fedavg", "fed_gm")
COUNT_SCHEMES = ("probit_plus", "signsgd_mv", "rsa")
N = 10


# ---------------------------------------------------------------------------
# Count-protocol parity (aggregation layer)
# ---------------------------------------------------------------------------


def _wire(name, m=12, d=13, seed=0):
    """A packed (or dense) cohort wire at d % 8 != 0."""
    pipe = build_pipeline(name, chunk=16)
    key = jax.random.PRNGKey(seed)
    deltas = 0.05 * jax.random.normal(key, (m, d))
    b = jnp.float32(0.1)
    res = jnp.zeros((m, d))
    wire, _ = pipe.compress_wire(jax.random.fold_in(key, 1), deltas, b, res)
    return pipe, wire


@pytest.mark.parametrize("name", COUNT_SCHEMES)
def test_accumulate_finalize_matches_one_shot(name):
    """Chunked count accumulation == one-shot aggregate, any split."""
    pipe, wire = _wire(name)
    one_shot = pipe.server.aggregate(wire)
    for splits in ((4, 4, 4), (5, 4, 3), (12,), (1,) * 12):
        counts = pipe.server.init_counts(wire.packed.shape[1])
        row = 0
        for c in splits:
            counts = pipe.server.accumulate_counts(
                counts, wire.packed[row : row + c]
            )
            row += c
        est = pipe.server.finalize(counts, wire.n_clients, wire.b)
        np.testing.assert_array_equal(np.asarray(est), np.asarray(one_shot))


@pytest.mark.parametrize("name", COUNT_SCHEMES)
@pytest.mark.parametrize(
    "weights",
    [
        np.array([1, 0] * 6, np.float32),  # active-client mask
        (np.arange(12) % 4 + 1).astype(np.float32) / 4,  # staleness-style
    ],
    ids=["mask01", "staleness"],
)
def test_weighted_accumulate_matches_one_shot(name, weights):
    pipe, wire = _wire(name)
    w = jnp.asarray(weights)
    one_shot = pipe.server.aggregate(wire, w)
    counts = pipe.server.init_counts(wire.packed.shape[1], weighted=True)
    for row in range(0, 12, 5):  # 5 does not divide 12
        counts = pipe.server.accumulate_counts(
            counts, wire.packed[row : row + 5], w[row : row + 5]
        )
    wsum = jnp.maximum(jnp.sum(w), 1e-12)
    est = jnp.where(
        jnp.sum(w) > 0, pipe.server.finalize(counts, wsum, wire.b), 0.0
    )
    np.testing.assert_allclose(
        np.asarray(est), np.asarray(one_shot), rtol=1e-6, atol=1e-7
    )


def test_fedavg_stream_sum_matches_dense():
    pipe, wire = _wire("fedavg")
    w = jnp.asarray((np.arange(12) % 3).astype(np.float32))
    one_shot = pipe.server.aggregate(wire, w)
    carry = pipe.server.init_stream_sum(wire.updates.shape[1])
    for row in range(0, 12, 5):
        carry = pipe.server.accumulate_sum(
            carry, wire.updates[row : row + 5], w[row : row + 5]
        )
    np.testing.assert_allclose(
        np.asarray(pipe.server.finalize_sum(carry)),
        np.asarray(one_shot),
        rtol=1e-6,
        atol=1e-7,
    )


def test_popcount_matches_unpack_reduction():
    """population_count path == unpack-and-sum path, and both == numpy."""
    rng = np.random.default_rng(7)
    packed = jnp.asarray(rng.integers(0, 256, (37, 11), dtype=np.uint8))
    pop = packed_counts(packed, chunk=24, use_popcount=True)
    ref = packed_counts(packed, chunk=24, use_popcount=False)
    np.testing.assert_array_equal(np.asarray(pop), np.asarray(ref))
    bits = np.unpackbits(np.asarray(packed), axis=1, bitorder="little")
    np.testing.assert_array_equal(np.asarray(pop), bits.sum(0).astype(np.int32))
    bytes_ = jnp.arange(256, dtype=jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(byte_popcount(bytes_)),
        np.array([bin(v).count("1") for v in range(256)], np.uint8),
    )


# ---------------------------------------------------------------------------
# Round parity (fl layer)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def round_env():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, N, 2, 60, seed=1)
    return dict(
        p0=init_mlp(jax.random.PRNGKey(0), hidden=8),
        loss=functools.partial(xent_loss, mlp_logits),
        acc=functools.partial(accuracy, mlp_logits),
        cx=np.stack([xtr[i] for i in parts]),
        cy=np.stack([ytr[i] for i in parts]),
        test={"x": xte, "y": yte},
    )


def _run(round_env, cfg, rounds=2, eager=True):
    ctx = R.make_context(
        cfg,
        round_env["p0"],
        round_env["loss"],
        round_env["acc"],
        round_env["cx"],
        round_env["cy"],
        round_env["test"],
    )
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(cfg.seed)
    fn = R.round_fn(ctx)
    with jax.disable_jit(eager):
        for _ in range(rounds):
            key, kb, kr = jax.random.split(key, 3)
            state, m = fn(ctx, params, kr, state, R.round_batches(ctx, kb))
    return state, m


@pytest.mark.parametrize("agg", AGGREGATORS)
def test_round_parity_all_aggregators(round_env, agg):
    """Chunked round == dense round; chunk 4 does not divide M = 10."""
    base = dict(n_clients=N, rounds=2, local_epochs=1, aggregator=agg)
    dense, _ = _run(round_env, FLConfig(**base))
    stream, _ = _run(round_env, FLConfig(**base, client_chunk=4))
    wd, ws = np.asarray(dense.w_global), np.asarray(stream.w_global)
    if agg in COUNT_SCHEMES:
        np.testing.assert_array_equal(wd, ws)
        np.testing.assert_array_equal(
            np.asarray(dense.b.b), np.asarray(stream.b.b)
        )
    else:
        np.testing.assert_allclose(wd, ws, atol=1e-6)


@pytest.mark.parametrize(
    "extra",
    [
        dict(participation=0.7),
        dict(error_feedback=True),
        dict(byz_frac=0.2, attack="sign_flip"),
        dict(byz_frac=0.2, attack="bit_flip"),
    ],
    ids=["participation", "error_feedback", "sign_flip", "bit_flip"],
)
def test_round_parity_masks_state_attacks(round_env, extra):
    """Parity extends to the full carried state (w_locals, residuals)."""
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus", **extra
    )
    dense, _ = _run(round_env, FLConfig(**base))
    stream, _ = _run(round_env, FLConfig(**base, client_chunk=4))
    for field in ("w_global", "w_locals", "residuals"):
        np.testing.assert_array_equal(
            np.asarray(getattr(dense, field)), np.asarray(getattr(stream, field))
        )


def test_round_parity_kernel_wire(round_env):
    """use_kernels=True under client_chunk streaming: the kernel wire's
    counter-derived per-client PRNG (``row_offset`` rebasing) makes the
    chunked round bit-exact with the dense one — and, because the dispatch
    policy resolves to the ref engine off-TPU, bit-exact with the pure-JAX
    wire too."""
    from repro.kernels import resolve_engine

    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        use_kernels=True,
    )
    dense, _ = _run(round_env, FLConfig(**base))
    stream, _ = _run(round_env, FLConfig(**base, client_chunk=4))
    for field in ("w_global", "w_locals", "residuals"):
        np.testing.assert_array_equal(
            np.asarray(getattr(dense, field)), np.asarray(getattr(stream, field))
        )
    if resolve_engine() == "ref":
        pure, _ = _run(
            round_env,
            FLConfig(n_clients=N, rounds=2, local_epochs=1,
                     aggregator="probit_plus"),
        )
        np.testing.assert_array_equal(
            np.asarray(dense.w_global), np.asarray(pure.w_global)
        )


def test_gaussian_attack_chunk_invariant(round_env):
    """The gaussian payload draws per cohort row, so the stream round is
    chunk-size invariant (dense parity is not required — the dense round
    draws its noise in one block)."""
    base = dict(
        n_clients=N,
        rounds=2,
        local_epochs=1,
        aggregator="probit_plus",
        byz_frac=0.2,
        attack="gaussian",
    )
    s4, _ = _run(round_env, FLConfig(**base, client_chunk=4))
    s7, _ = _run(round_env, FLConfig(**base, client_chunk=7))
    np.testing.assert_array_equal(
        np.asarray(s4.w_global), np.asarray(s7.w_global)
    )


def test_round_parity_under_jit(round_env):
    base = dict(n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus")
    dense, _ = _run(round_env, FLConfig(**base), eager=False)
    stream, _ = _run(round_env, FLConfig(**base, client_chunk=4), eager=False)
    np.testing.assert_allclose(
        np.asarray(dense.w_global), np.asarray(stream.w_global), atol=1e-6
    )


def test_stateless_clients_smoke(round_env):
    """Cross-device mode: no per-client state, single broadcast row."""
    cfg = FLConfig(
        n_clients=N,
        rounds=2,
        local_epochs=1,
        client_chunk=4,
        stateless_clients=True,
    )
    state, m = _run(round_env, cfg, eager=False)
    assert state.w_locals.shape[0] == 1
    assert np.isfinite(float(m["loss"]))


def test_kbit_stream_round_smoke(round_env):
    """k=2 wire (the CI smoke cell): chunked round == dense round exactly.

    The plane-major k-bit wire streams through the *unchanged* count
    protocol — the flat count carry of a ``bits * P``-byte row is the
    per-plane vote count — so chunk-vs-dense parity holds bit-for-bit
    just as at k=1.
    """
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        wire_bits=2,
    )
    dense, _ = _run(round_env, FLConfig(**base))
    stream, _ = _run(round_env, FLConfig(**base, client_chunk=4))
    for field in ("w_global", "w_locals", "residuals"):
        np.testing.assert_array_equal(
            np.asarray(getattr(dense, field)), np.asarray(getattr(stream, field))
        )


def test_campaign_planner_streams_fused_groups():
    """plan_campaign flips fusable groups past the threshold to streaming,
    with metric parity against the dense plan and peak-bytes stats."""
    from repro.sim import CampaignSpec, CellSpec, Task, run_campaign
    from repro.sim.plan import plan_campaign

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=800, n_test=100)

    def task_fn(cfg, _cache={}):
        m = cfg.n_clients
        if m not in _cache:
            parts = partition_label_skew(ytr, m, 2, 30, seed=1)
            _cache[m] = Task(
                init_params=init_mlp(jax.random.PRNGKey(0), hidden=8),
                loss_fn=functools.partial(xent_loss, mlp_logits),
                acc_fn=functools.partial(accuracy, mlp_logits),
                client_x=np.stack([xtr[i] for i in parts]),
                client_y=np.stack([ytr[i] for i in parts]),
                test={"x": xte, "y": yte},
            )
        return _cache[m]

    spec = CampaignSpec(
        base=dict(rounds=2, local_epochs=1),
        cells=(CellSpec("M=8", dict(n_clients=8)),),
        seeds=(0, 1),
    )
    streamed = plan_campaign(spec, stream_threshold=4, stream_chunk=8)
    assert streamed.groups[0].client_chunk == 8
    assert "stream@8" in streamed.describe()
    dense = plan_campaign(spec, stream_threshold=10**9)
    assert dense.groups[0].client_chunk == 0

    rs = run_campaign(spec, task_fn, plan=streamed)
    rd = run_campaign(spec, task_fn, plan=dense)
    np.testing.assert_allclose(
        rs.cells[0].metrics["theta_mse"],
        rd.cells[0].metrics["theta_mse"],
        atol=1e-9,
    )
    g = rs.groups[0]
    assert g["client_chunk"] == 8
    assert g["peak_bytes_est"] > 0
    # the dense plan's resident estimate must dominate the streamed one
    assert rd.groups[0]["peak_bytes_est"] >= g["peak_bytes_est"]


# ---------------------------------------------------------------------------
# Memory bound (CI stream-smoke target)
# ---------------------------------------------------------------------------

_RSS_CHILD = textwrap.dedent(
    """
    import resource, sys
    # Hard address-space cap, far below the dense working set (the dense
    # leg OOMs even at 4.5 GB) with headroom over the streaming round's
    # ~0.6 GB resident set for XLA thread stacks / allocator arenas.
    cap = 4 << 30
    resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
    import functools
    import jax, numpy as np
    from repro.fl import rounds as R
    from repro.fl.runtime import FLConfig
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

    M, DIM, PER, HID = 60_000, 8, 2, 64
    rng = np.random.default_rng(0)
    w = rng.standard_normal(DIM).astype(np.float32)
    cx = rng.standard_normal((M, PER, DIM), dtype=np.float32)
    cy = (cx @ w > 0).astype(np.int32)
    stream = sys.argv[1] == "stream"
    cfg = FLConfig(
        n_clients=M, rounds=1, local_epochs=1, batch_size=PER, lr=0.01,
        b_mode="fixed", b_init=0.1, pack_chunk=512,
        client_chunk=2048 if stream else 0, stateless_clients=stream,
    )
    ctx = R.make_context(
        cfg, init_mlp(jax.random.PRNGKey(0), in_dim=DIM, hidden=HID, classes=2),
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits), cx, cy,
        {"x": cx[0], "y": cy[0]},
    )
    _, traj = R.run_rounds(
        ctx, R.cell_params(cfg), jax.random.PRNGKey(0),
        R.init_run_state(ctx), with_acc=False,
    )
    jax.block_until_ready(traj)
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss // 1024
    print(f"STREAM_OK maxrss_mb={rss} loss={float(traj['loss'][-1]):.4f}")
    """
)


def _rss_child(mode: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    # Drop any inherited device-count flag (repro.launch.dryrun writes 512
    # into os.environ when another test imports it): 512 virtual devices'
    # thread stacks alone would exhaust the child's address-space cap.
    env["XLA_FLAGS"] = " ".join(
        f
        for f in env.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    )
    return subprocess.run(
        [sys.executable, "-c", _RSS_CHILD, mode],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )


def test_stream_smoke_rss_capped():
    """M = 60k x d = 4866 under a 4 GB RLIMIT_AS: the chunked round must
    complete (resident set ~ chunk * d, ~0.6 GB measured) while the dense
    round — whose (M, d) f32 state alone is ~1.2 GB before training
    intermediates — dies OOM under the same cap. This is the acceptance
    subprocess the CI ``stream-smoke`` job runs."""
    res = _rss_child("stream")
    assert res.returncode == 0, res.stderr[-2000:]
    assert "STREAM_OK" in res.stdout, res.stdout

    dense = _rss_child("dense")
    assert dense.returncode != 0, (
        "dense round unexpectedly fit under the cap:\n" + dense.stdout
    )
