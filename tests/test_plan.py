"""Campaign planner/executor tests.

The load-bearing guarantees:

* a **fused heterogeneous-M group** (one compiled program padded to the
  group-max client count, real M traced through the active-client mask)
  reproduces per-group execution to jit tolerance (<= 1e-6, the PR 3
  convention) for all five aggregators;
* the **AOT compile cache** makes a second run of the same spec trigger
  zero new lowerings;
* the **device-sharded path** (batch axis on a 1-D mesh over
  ``--xla_force_host_platform_device_count=4`` virtual CPU devices)
  reproduces single-device execution — exercised in a subprocess because
  the flag must precede jax platform init (tier-1's shard smoke job runs
  exactly this test).
"""

import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import (
    CampaignSpec,
    CellSpec,
    CompileCache,
    Task,
    fusable,
    plan_campaign,
    run_campaign,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

AGGREGATORS = ("probit_plus", "fedavg", "fed_gm", "signsgd_mv", "rsa")

BASE = dict(rounds=3, local_epochs=1, batch_size=10)


@pytest.fixture(scope="module")
def task_factory():
    """A task provider keyed on n_clients (the benchmark-harness shape):
    shared initial model / loss / test set, per-M client partitions."""
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=600, n_test=150)
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=8)
    test = {"x": xte, "y": yte}
    loss_fn = functools.partial(xent_loss, mlp_logits)
    acc_fn = functools.partial(accuracy, mlp_logits)

    @functools.lru_cache(maxsize=None)
    def data(m, per_client=50):
        parts = partition_label_skew(ytr, m, 2, per_client, seed=1)
        return (
            np.stack([xtr[i] for i in parts]),
            np.stack([ytr[i] for i in parts]),
        )

    def task_fn(cfg):
        cx, cy = data(cfg.n_clients)
        return Task(p0, loss_fn, acc_fn, cx, cy, test)

    task_fn.data = data
    return task_fn


def m_sweep_spec(aggregator: str, seeds=(0, 1)) -> CampaignSpec:
    return CampaignSpec(
        base=dict(aggregator=aggregator, **BASE),
        cells=(
            CellSpec("M4", {"n_clients": 4}),
            CellSpec("M6", {"n_clients": 6}),
            CellSpec("M6lr", {"n_clients": 6, "lr": 0.02}),
        ),
        seeds=seeds,
    )


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

def test_plan_fuses_m_sweep():
    plan = plan_campaign(m_sweep_spec("probit_plus"))
    assert plan.n_programs == 1 and plan.n_fused == 1
    (g,) = plan.groups
    assert g.fused and g.m_pad == 6 and g.n_cells == 3
    assert "fused" in plan.describe()


def test_plan_fuse_m_false_reproduces_per_signature_grouping():
    plan = plan_campaign(m_sweep_spec("probit_plus"), fuse_m=False)
    assert plan.n_programs == 2 and plan.n_fused == 0  # M4 | M6+M6lr


def test_single_m_bucket_stays_unmasked():
    spec = CampaignSpec(
        base=dict(**BASE),
        cells=(CellSpec("a", {"lr": 0.01}), CellSpec("b", {"lr": 0.02})),
    )
    plan = plan_campaign(spec)
    assert plan.n_programs == 1 and plan.n_fused == 0


@pytest.mark.parametrize(
    "overrides",
    [
        dict(async_buffer=10, n_clients=10),
        dict(participation=0.5, n_clients=10),
        dict(byz_frac=0.2, n_clients=10, attack="gaussian"),
        dict(topk_frac=0.5),
        dict(b_mode="oracle"),
    ],
)
def test_not_fusable(overrides):
    assert not fusable(FLConfig(**overrides))
    assert fusable(FLConfig())


# ---------------------------------------------------------------------------
# Fused execution parity — all five aggregators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", AGGREGATORS)
def test_fused_matches_grouped(aggregator, task_factory):
    """Acceptance: fused heterogeneous-M execution equals per-group
    execution <= 1e-6 per cell/seed/round (PR 3's jit convention)."""
    spec = m_sweep_spec(aggregator)
    fused = run_campaign(spec, task_factory, compile_cache=CompileCache())
    grouped = run_campaign(
        spec, task_factory, fuse_m=False, compile_cache=CompileCache()
    )
    assert any(g["fused"] for g in fused.groups)
    assert not any(g["fused"] for g in grouped.groups)
    for cell in spec.cells:
        f, g = fused.cell(cell.name), grouped.cell(cell.name)
        for metric in ("acc", "loss", "b", "theta_mse"):
            np.testing.assert_allclose(
                f.metrics[metric], g.metrics[metric],
                rtol=1e-5, atol=1e-6,
                err_msg=f"{aggregator}/{cell.name}/{metric}",
            )


def test_fused_group_stats_report_padding(task_factory):
    spec = m_sweep_spec("probit_plus")
    res = run_campaign(spec, task_factory, compile_cache=CompileCache())
    (g,) = res.groups
    assert g["fused"] and g["m_pad"] == 6
    assert g["n_elems"] == 3 * 2 and g["n_elems_padded"] == g["n_elems"]
    assert g["n_devices"] == 1
    assert g["cells_per_sec"] > 0
    js = res.to_json()
    assert js["groups"][0]["m_pad"] == 6
    assert js["n_devices"] == 1 and js["cells_per_sec"] > 0


def test_fused_shape_mismatch_demotes_to_per_m(task_factory):
    """Cells whose per-client datasets cannot stack fall back to grouped
    execution (with a warning), not a crash — and match fuse_m=False."""
    def uneven_task(cfg):
        cx, cy = task_factory.data(cfg.n_clients, 30 if cfg.n_clients == 4 else 50)
        t = task_factory(cfg)
        return Task(t.init_params, t.loss_fn, t.acc_fn, cx, cy, t.test)

    spec = m_sweep_spec("probit_plus", seeds=(0,))
    with pytest.warns(RuntimeWarning, match="demoting fused campaign group"):
        res = run_campaign(spec, uneven_task, compile_cache=CompileCache())
    assert not any(g["fused"] for g in res.groups)
    ref = run_campaign(
        spec, uneven_task, fuse_m=False, compile_cache=CompileCache()
    )
    for cell in spec.cells:
        np.testing.assert_allclose(
            res.cell(cell.name).metrics["acc"],
            ref.cell(cell.name).metrics["acc"],
            atol=1e-6,
        )


# ---------------------------------------------------------------------------
# AOT compile cache
# ---------------------------------------------------------------------------

def test_second_run_triggers_zero_new_lowerings(task_factory):
    """Acceptance: repeated benchmarks skip recompiles entirely."""
    spec = CampaignSpec(
        base=dict(**BASE),
        cells=(
            CellSpec("M4", {"n_clients": 4}),
            CellSpec("M6", {"n_clients": 6}),
            # not fusable (oracle b) — exercises the non-fused cache path
            CellSpec("oracle", {"n_clients": 4, "b_mode": "oracle"}),
        ),
        seeds=(0,),
    )
    cache = CompileCache()
    first = run_campaign(spec, task_factory, compile_cache=cache)
    lowerings_after_first = cache.lowerings
    assert lowerings_after_first == len(first.groups) == 2
    second = run_campaign(spec, task_factory, compile_cache=cache)
    assert cache.lowerings == lowerings_after_first, "second run re-lowered"
    assert cache.hits == len(second.groups)
    assert all(g["cache_hit"] for g in second.groups)
    assert not any(g["cache_hit"] for g in first.groups)
    for cell in spec.cells:
        np.testing.assert_array_equal(
            first.cell(cell.name).metrics["acc"],
            second.cell(cell.name).metrics["acc"],
        )


def test_explicit_plan_rejects_conflicting_flags(task_factory):
    """An explicit plan owns shard/fuse_m — a conflicting keyword must
    raise, not silently lose (regression guard for the plan= API)."""
    spec = m_sweep_spec("probit_plus", seeds=(0,))
    plan = plan_campaign(spec)  # shard=False, fuse_m=True
    with pytest.raises(ValueError, match="conflicts with the explicit plan"):
        run_campaign(spec, task_factory, shard=True, plan=plan)
    with pytest.raises(ValueError, match="conflicts with the explicit plan"):
        run_campaign(spec, task_factory, fuse_m=False, plan=plan)
    # matching (or omitted) flags are fine
    run_campaign(
        spec, task_factory, fuse_m=True, plan=plan,
        compile_cache=CompileCache(),
    )


def test_compile_cache_lru_bound(task_factory):
    """The cache evicts least-recently-used entries (and their keepalive
    refs) beyond maxsize instead of growing without bound."""
    spec = m_sweep_spec("probit_plus", seeds=(0,))
    cache = CompileCache(maxsize=1)
    run_campaign(spec, task_factory, compile_cache=cache)
    assert cache.size == 1
    run_campaign(
        spec, task_factory, with_acc=False, compile_cache=cache
    )  # different program, same maxsize -> evicts the first
    assert cache.size == 1
    run_campaign(spec, task_factory, compile_cache=cache)
    assert cache.lowerings == 3 and cache.hits == 0  # thrashing, but bounded


def test_cache_distinguishes_with_acc(task_factory):
    """with_acc changes the program under identical input avals — the
    cache key must split them (stale-hit regression guard)."""
    spec = m_sweep_spec("probit_plus", seeds=(0,))
    cache = CompileCache()
    res_acc = run_campaign(spec, task_factory, compile_cache=cache)
    res_no = run_campaign(
        spec, task_factory, with_acc=False, compile_cache=cache
    )
    assert cache.lowerings == 2 and cache.hits == 0
    assert "acc" in res_acc.cell("M4").metrics
    assert "acc" not in res_no.cell("M4").metrics


# ---------------------------------------------------------------------------
# Sharded execution
# ---------------------------------------------------------------------------

def test_shard_single_device_warns_once(task_factory, monkeypatch):
    from repro.sim import campaign as campaign_mod

    monkeypatch.setattr(campaign_mod, "_WARNED_SINGLE_DEVICE", False)
    spec = m_sweep_spec("probit_plus", seeds=(0,))
    with pytest.warns(RuntimeWarning, match="shard=True.*no-op"):
        res = run_campaign(
            spec, task_factory, shard=True, compile_cache=CompileCache()
        )
    # stats still report the device count and real-vs-padded elements
    assert all(g["n_devices"] == 1 for g in res.groups)
    assert all(g["n_elems_padded"] == g["n_elems"] for g in res.groups)
    # second sharded run: warning already issued, must not fire again
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error", RuntimeWarning)
        run_campaign(
            spec, task_factory, shard=True, compile_cache=CompileCache()
        )


_SHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import functools, json
import jax
import numpy as np
from repro.data import make_classification, partition_label_skew
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import CampaignSpec, CellSpec, Task, run_campaign

(xtr, ytr), (xte, yte) = make_classification(0, n_train=600, n_test=150)
p0 = init_mlp(jax.random.PRNGKey(0), hidden=8)
test = {"x": xte, "y": yte}

@functools.lru_cache(maxsize=None)
def data(m):
    parts = partition_label_skew(ytr, m, 2, 50, seed=1)
    return np.stack([xtr[i] for i in parts]), np.stack([ytr[i] for i in parts])

def task_fn(cfg):
    cx, cy = data(cfg.n_clients)
    return Task(p0, functools.partial(xent_loss, mlp_logits),
                functools.partial(accuracy, mlp_logits), cx, cy, test)

spec = CampaignSpec(
    base=dict(rounds=3, local_epochs=1, batch_size=10),
    cells=(CellSpec("M4", {"n_clients": 4}), CellSpec("M6", {"n_clients": 6}),
           CellSpec("M6lr", {"n_clients": 6, "lr": 0.02})),
    seeds=(0, 1),
)
assert jax.device_count() == 4
res = run_campaign(spec, task_fn, shard=True)
payload = {
    "acc": {c.name: np.asarray(c.metrics["acc"]).tolist() for c in res.cells},
    "groups": [
        {k: g[k] for k in ("n_devices", "n_elems", "n_elems_padded", "fused")}
        for g in res.groups
    ],
}
print(json.dumps(payload))
"""


def test_shard_parity_4_virtual_devices(task_factory):
    """Acceptance: the shard path under 4 virtual CPU devices reproduces
    single-device execution <= 1e-6 (subprocess: the XLA flag must be set
    before jax initializes). Also the 4-device smoke the tier-1 CI shard
    job runs."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SHARD_SCRIPT)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(g["n_devices"] == 4 for g in payload["groups"])
    # 3 cells x 2 seeds = 6 real elements, padded to 8 for 4 devices
    assert payload["groups"][0]["n_elems"] == 6
    assert payload["groups"][0]["n_elems_padded"] == 8
    assert payload["groups"][0]["fused"]

    ref = run_campaign(
        m_sweep_spec("probit_plus"), task_factory, compile_cache=CompileCache()
    )
    for name, acc in payload["acc"].items():
        np.testing.assert_allclose(
            np.asarray(acc), ref.cell(name).metrics["acc"],
            atol=1e-6, err_msg=name,
        )


@pytest.mark.slow
def test_campaign_throughput_benchmark_monotone(tmp_path):
    """Nightly: cells/sec at 4 virtual CPU devices must be >= cells/sec
    at 1 device (the sweep's 1 -> 4 endpoint comparison; reduced rounds —
    the full sweep runs in CI slow). On a single-core host the virtual
    devices time-share one core, so the endpoint ratio is ~1.0 plus
    scheduler noise; there we only bound the sharding overhead instead
    of asserting a speedup that the hardware cannot produce."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import fig_campaign_throughput as bench

    out = bench.main(rounds=5)
    thr = [out["sweep"][k]["cells_per_sec"] for k in sorted(out["sweep"])]
    if (os.cpu_count() or 1) > 1:
        assert out["monotone_1_to_max"], f"throughput regressed with devices: {thr}"
        assert thr[-1] >= thr[0]
    else:
        assert thr[-1] >= 0.8 * thr[0], (
            f"sharding overhead > 20% on a single core: {thr}"
        )
