"""Numerical validation of the paper's Theorems 1–3."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    DPConfig,
    dp_b_floor,
    flip_codes,
    ml_estimate_from_counts,
    privacy_loss,
    probit_plus_aggregate,
    probit_plus_from_updates,
    stochastic_binarize,
)


def _updates(key, m, d, scale=0.01):
    # heterogeneous client means around a common theta (paper Fig. 1 model)
    theta = scale * jax.random.normal(key, (d,))
    noise = scale * 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (m, d))
    return theta + noise


class TestTheorem1:
    def test_unbiased(self):
        """E[theta_hat] == theta over quantization randomness."""
        key = jax.random.PRNGKey(0)
        m, d = 32, 64
        upd = _updates(key, m, d)
        b = jnp.abs(upd).max() + 0.01
        bvec = jnp.full((d,), b)
        reps = 600
        keys = jax.random.split(jax.random.fold_in(key, 7), reps)
        ests = jax.vmap(lambda k: probit_plus_from_updates(k, upd, bvec))(keys)
        mean_est = jnp.mean(ests, axis=0)
        target = jnp.mean(upd, axis=0)  # FedAvg value = theta estimate target
        se = float(b) / np.sqrt(m * reps)
        assert float(jnp.max(jnp.abs(mean_est - target))) < 6 * se

    def test_error_formula(self):
        """E||theta - theta_hat||^2 == sum(b^2 - theta^2)/M for known theta."""
        key = jax.random.PRNGKey(1)
        d, m = 128, 16
        theta = 0.02 * jax.random.normal(key, (d,))
        b = 0.05
        bvec = jnp.full((d,), b)
        # all clients at exactly theta: the only error is quantization
        upd = jnp.tile(theta[None], (m, 1))
        reps = 800
        keys = jax.random.split(key, reps)
        errs = jax.vmap(
            lambda k: jnp.sum((probit_plus_from_updates(k, upd, bvec) - theta) ** 2)
        )(keys)
        expected = float(jnp.sum(b**2 - theta**2) / m)
        measured = float(jnp.mean(errs))
        assert abs(measured - expected) / expected < 0.1

    def test_error_rate_O_1_over_M(self):
        """Doubling M halves the squared error (Thm 1.3 rate)."""
        key = jax.random.PRNGKey(2)
        d = 256
        theta = 0.02 * jax.random.normal(key, (d,))
        b = jnp.full((d,), 0.06)
        errs = {}
        for m in (8, 32, 128):
            upd = jnp.tile(theta[None], (m, 1))
            keys = jax.random.split(jax.random.fold_in(key, m), 300)
            e = jax.vmap(
                lambda k: jnp.sum((probit_plus_from_updates(k, upd, b) - theta) ** 2)
            )(keys)
            errs[m] = float(jnp.mean(e))
        assert errs[32] < errs[8] / 2.5
        assert errs[128] < errs[32] / 2.5


class TestTheorem2:
    @settings(deadline=None, max_examples=10)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([0.1, 0.2, 0.4]))
    def test_byzantine_deviation_bound(self, seed, beta):
        """||E[theta]_R - E[theta]_B|| <= 2 beta ||b|| under ANY bit attack."""
        key = jax.random.PRNGKey(seed)
        m, d = 40, 32
        n_byz = int(m * beta)
        upd = _updates(key, m, d)
        bvec = jnp.full((d,), float(jnp.abs(upd).max()) + 0.01)
        reps = 400
        keys = jax.random.split(jax.random.fold_in(key, 3), reps)

        def est(k, attack):
            ks = jax.random.split(k, m)
            codes = jax.vmap(stochastic_binarize, in_axes=(0, 0, None))(ks, upd, bvec)
            if attack:
                codes = flip_codes(codes, n_byz)  # worst-case bit adversary
            return probit_plus_aggregate(codes, bvec)

        clean = jnp.mean(jax.vmap(lambda k: est(k, False))(keys), axis=0)
        attacked = jnp.mean(jax.vmap(lambda k: est(k, True))(keys), axis=0)
        dev = float(jnp.linalg.norm(clean - attacked))
        bound = 2 * beta * float(jnp.linalg.norm(bvec))
        assert dev <= bound * 1.05  # 5% slack for Monte-Carlo noise

    def test_magnitude_immunity(self):
        """A single Byzantine with unbounded magnitude moves PRoBit+ by at
        most 2b/M per coordinate — while FedAvg diverges arbitrarily."""
        key = jax.random.PRNGKey(3)
        m, d = 20, 16
        upd = _updates(key, m, d)
        evil = upd.at[0].set(1e9)
        bvec = jnp.full((d,), float(jnp.abs(upd[1:]).max()) + 0.01)
        keys = jax.random.split(key, 500)
        clean = jnp.mean(
            jax.vmap(lambda k: probit_plus_from_updates(k, upd, bvec))(keys), axis=0
        )
        attacked = jnp.mean(
            jax.vmap(lambda k: probit_plus_from_updates(k, evil, bvec))(keys), axis=0
        )
        per_coord = jnp.abs(clean - attacked)
        assert float(per_coord.max()) <= 2 * float(bvec[0]) / m * 1.3
        fedavg_dev = jnp.abs(jnp.mean(evil, 0) - jnp.mean(upd, 0)).max()
        assert float(fedavg_dev) > 1e6  # FedAvg is destroyed


class TestTheorem3:
    @settings(deadline=None, max_examples=25)
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from([0.05, 0.1, 0.5, 1.0]),
    )
    def test_privacy_loss_bounded_by_eps(self, seed, eps):
        """Worst-case log-likelihood ratio <= eps when b respects the floor."""
        key = jax.random.PRNGKey(seed)
        d = 64
        delta1 = 2e-4
        cfg = DPConfig(eps, delta1)
        delta_a = 0.01 * jax.random.normal(key, (d,))
        # adjacent update: l1 perturbation of size exactly Delta_1
        v = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        v = v / jnp.sum(jnp.abs(v)) * delta1
        delta_b = delta_a + v
        floor = dp_b_floor(jnp.maximum(jnp.abs(delta_a), jnp.abs(delta_b)).max(), cfg)
        b = jnp.full((d,), floor)
        pl = float(privacy_loss(delta_a, delta_b, b))
        assert pl <= eps * 1.0001

    def test_privacy_loss_finite_at_range_boundary(self):
        """Regression: delta = +-b exactly drives binarize_prob to {0, 1};
        the empirical loss must clamp, not diverge to inf/NaN."""
        b = jnp.full((3,), 0.02)
        pl = privacy_loss(
            jnp.array([0.02, -0.02, 0.02]),
            jnp.array([-0.02, 0.02, 0.01]),
            b,
        )
        assert bool(jnp.isfinite(pl))
        # one-sided: only one update on the boundary
        pl1 = privacy_loss(jnp.array([0.02]), jnp.array([0.0]), b[:1])
        assert bool(jnp.isfinite(pl1))

    def test_smaller_eps_needs_larger_b(self):
        floors = [
            float(dp_b_floor(jnp.float32(0.01), DPConfig(e, 2e-4)))
            for e in (1.0, 0.1, 0.01)
        ]
        assert floors[0] < floors[1] < floors[2]
