"""Per-architecture smoke tests: a REDUCED variant of each assigned
architecture runs one forward/train step (and one decode step where the
family supports it) on CPU; output shapes asserted, no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import (
    build_specs,
    init_cache,
    prefill,
    sample_batch,
    serve_step,
    train_loss,
)
from repro.models.spec import init_params

ARCHS = configs.ARCH_IDS


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.reduced(configs.get_config(arch))
            params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = sample_batch(cfg, 2, 64, "train")
    loss, grads = jax.value_and_grad(lambda p: train_loss(p, batch, cfg))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)), arch
    assert float(gnorm) > 0, f"{arch}: gradients identically zero"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = sample_batch(cfg, 2, 32, "prefill")
    logits = prefill(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_decode_step(arch, arch_setup):
    cfg, params = arch_setup(arch)
    cache = init_cache(cfg, 2, 32)
    logits, cache2 = serve_step(
        params, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)}, jnp.int32(0), cfg
    )
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "starcoder2-3b", "jamba-1.5-large-398b", "qwen3-moe-30b-a3b"])
def test_decode_matches_prefill(arch, arch_setup):
    """Autoregressive decode must reproduce prefill logits position-by-position."""
    import numpy as np

    cfg, params = arch_setup(arch)
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, S), 0, cfg.vocab)
    pl = prefill(params, {"tokens": toks}, cfg)
    cache = init_cache(cfg, 1, S)
    outs = []
    for t in range(S):
        lg, cache = serve_step(
            params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t), cfg
        )
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(pl), atol=0.25)


def test_sliding_window_ring_decode():
    """Ring-buffer decode (window < history) stays finite and matches the
    full-cache decode while history < window."""
    import numpy as np

    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    W = 8
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, 12), 0, cfg.vocab)
    ring = init_cache(cfg, 1, W)
    full = init_cache(cfg, 1, 12)
    for t in range(12):
        lr_, ring = serve_step(params, ring, {"tokens": toks[:, t:t+1]}, jnp.int32(t), cfg, window=W)
        lf_, full = serve_step(params, full, {"tokens": toks[:, t:t+1]}, jnp.int32(t), cfg)
        if t < W:
            np.testing.assert_allclose(np.asarray(lr_), np.asarray(lf_), atol=0.25)
    assert bool(jnp.all(jnp.isfinite(lr_)))


def test_mlstm_chunked_matches_sequential_decode():
    """The chunkwise-parallel mLSTM must agree with the O(1) sequential
    decode cell — validates the stabilized chunk math."""
    import numpy as np
    from repro.models import xlstm as xl

    cfg = configs.reduced(configs.get_config("xlstm-350m"))
    spec = xl.mlstm_specs(cfg)
    from repro.models.spec import init_params as ip

    p = ip(spec, jax.random.PRNGKey(2))
    # full precision for a tight comparison
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg.d_model), jnp.float32)
    y_chunk = xl.mlstm_block(p, x, cfg, chunk=4)
    cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        xl.init_mlstm_cache(cfg, 1),
    )
    ys = []
    for t in range(16):
        y, cache = xl.mlstm_decode_step(p, x[:, t : t + 1], cache, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), atol=2e-3, rtol=1e-2)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced routing, most tokens compute."""
    cfg = configs.reduced(configs.get_config("qwen3-moe-30b-a3b"))
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    batch = sample_batch(cfg, 4, 64, "train")
    loss = train_loss(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
