"""Unit + property tests for the stochastic one-bit compressor (Eq. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    binarize_prob,
    stochastic_binarize,
    pack_bits,
    unpack_bits,
    codes_to_counts,
)


def test_prob_formula_matches_eq5():
    delta = jnp.array([-0.05, 0.0, 0.05])
    b = jnp.array([0.05, 0.05, 0.05])
    p = binarize_prob(delta, b)
    np.testing.assert_allclose(p, [0.0, 0.5, 1.0], atol=1e-7)


def test_prob_clips_out_of_range():
    # Byzantine magnitudes cannot push the probability outside [0, 1]
    delta = jnp.array([-100.0, 100.0])
    b = jnp.array([0.01, 0.01])
    p = binarize_prob(delta, b)
    np.testing.assert_allclose(p, [0.0, 1.0], atol=1e-7)


def test_zero_b_is_fair_coin():
    p = binarize_prob(jnp.zeros(4), jnp.zeros(4))
    np.testing.assert_allclose(p, 0.5)


@settings(deadline=None, max_examples=25)
@given(
    st.integers(0, 2**31 - 1),
    st.floats(0.001, 0.2),
    st.integers(10, 200),
)
def test_unbiasedness_property(seed, scale, n):
    """E[c] * b == delta (Thm 1.2 at the compressor level)."""
    key = jax.random.PRNGKey(seed)
    delta = scale * jax.random.normal(key, (n,))
    b = jnp.abs(delta).max() + scale
    reps = 4000
    keys = jax.random.split(jax.random.fold_in(key, 1), reps)
    codes = jax.vmap(lambda k: stochastic_binarize(k, delta, jnp.full((n,), b)))(keys)
    est = jnp.mean(codes.astype(jnp.float32), axis=0) * b
    se = float(b) / np.sqrt(reps)
    assert float(jnp.max(jnp.abs(est - delta))) < 6 * se


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4000))
def test_pack_unpack_roundtrip(seed, n):
    key = jax.random.PRNGKey(seed)
    codes = jnp.where(
        jax.random.bernoulli(key, 0.5, (n,)), jnp.int8(1), jnp.int8(-1)
    )
    packed = pack_bits(codes)
    assert packed.dtype == jnp.uint8
    assert packed.shape[0] == (n + 7) // 8
    out = unpack_bits(packed, n)
    assert bool(jnp.all(out == codes))


def test_counts():
    codes = jnp.array([[1, -1, 1], [1, 1, -1], [-1, -1, -1]], jnp.int8)
    np.testing.assert_array_equal(codes_to_counts(codes), [2, 1, 1])
