"""Campaign engine tests: grouping, vmapped execution, sequential parity.

The load-bearing test here is the 1e-6 parity between a campaign grid and
the sequential ``FLSimulation`` driver at fixed seeds — the guarantee that
lets the benchmark grids (``benchmarks/table1_byzantine.py`` etc.) run
through the engine without changing their numbers.
"""

import functools

import jax
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import CampaignSpec, CellSpec, Task, group_signature, run_campaign

BASE = dict(n_clients=6, rounds=3, local_epochs=1, byz_frac=0.34, b_mode="fixed")
SEEDS = (0, 1)
CELLS = (
    CellSpec("gaussian", {"attack": "gaussian"}),
    CellSpec("alie", {"attack": "alie"}),
    CellSpec("bit_flip", {"attack": "bit_flip"}),
    CellSpec("fedavg_gauss", {"attack": "gaussian", "aggregator": "fedavg"}),
)


@pytest.fixture(scope="module")
def task():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=600, n_test=150)
    parts = partition_label_skew(ytr, 6, 2, 50, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=8)
    return Task(
        init_params=p0,
        loss_fn=functools.partial(xent_loss, mlp_logits),
        acc_fn=functools.partial(accuracy, mlp_logits),
        client_x=cx,
        client_y=cy,
        test={"x": xte, "y": yte},
    )


@pytest.fixture(scope="module")
def result(task):
    spec = CampaignSpec(base=BASE, cells=CELLS, seeds=SEEDS)
    return run_campaign(spec, lambda cfg: task)


def test_attack_axis_shares_one_group(result):
    """Cells differing only in the attack (incl. the bit_flip wire
    adversary) ride one vmapped program; the fedavg cell is its own."""
    groups = sorted([sorted(g["cells"]) for g in result.groups])
    assert groups == [["alie", "bit_flip", "gaussian"], ["fedavg_gauss"]]


def test_group_signature_splits_static_fields():
    sig = lambda **kw: group_signature(FLConfig(**{**BASE, **kw}))
    assert sig(attack="gaussian") == sig(attack="bit_flip", lr=0.05, seed=3)
    assert sig() != sig(aggregator="fedavg")
    assert sig() != sig(n_clients=8)
    assert sig() != sig(dp_epsilon=0.1)


def test_campaign_matches_sequential_driver(task, result):
    """Acceptance: per-cell, per-seed, per-round accuracies from the
    vmapped grid equal the sequential FLSimulation loop within 1e-6."""
    for cell in CELLS:
        for si, seed in enumerate(SEEDS):
            cfg = FLConfig(seed=seed, **{**BASE, **cell.overrides})
            sim = FLSimulation(
                cfg, task.init_params, task.loss_fn, task.acc_fn,
                task.client_x, task.client_y, task.test,
            )
            sim.run(eval_every=1)
            seq_acc = np.asarray([h["acc"] for h in sim.history])
            seq_loss = np.asarray([h["loss"] for h in sim.history])
            cam = result.cell(cell.name)
            np.testing.assert_allclose(
                cam.metrics["acc"][si], seq_acc, atol=1e-6, err_msg=cell.name
            )
            np.testing.assert_allclose(
                cam.metrics["loss"][si], seq_loss, rtol=1e-6, err_msg=cell.name
            )


def test_theta_mse_metric_recorded(result):
    """theta_mse (aggregation error vs the uploaded updates' true mean) is
    finite for every cell and exactly zero for exact-mean FedAvg."""
    for cell in result.cells:
        mse = cell.metrics["theta_mse"]
        assert np.all(np.isfinite(mse)), cell.name
    assert np.all(result.cell("fedavg_gauss").metrics["theta_mse"] == 0.0)
    assert np.all(result.cell("gaussian").metrics["theta_mse"] > 0.0)


def test_summary_statistics_shapes(result):
    cell = result.cell("gaussian")
    assert cell.metrics["acc"].shape == (len(SEEDS), BASE["rounds"])
    mean, half = cell.trajectory("acc")
    assert mean.shape == (BASE["rounds"],) and half.shape == (BASE["rounds"],)
    final_mean, final_half = cell.final("acc")
    assert 0.0 <= final_mean <= 1.0 and final_half >= 0.0
    js = result.to_json()
    assert set(js["cells"]) == {c.name for c in CELLS}


def test_from_grid_cartesian():
    spec = CampaignSpec.from_grid(
        BASE, {"attack": ["gaussian", "alie"], "lr": [0.01, 0.02]}, seeds=(0,)
    )
    assert [c.name for c in spec.cells] == [
        "attack=gaussian|lr=0.01",
        "attack=gaussian|lr=0.02",
        "attack=alie|lr=0.01",
        "attack=alie|lr=0.02",
    ]
    # lr rides the vmap axis: one signature for all four cells
    assert len({group_signature(c) for c in spec.configs()}) == 1


def test_sharded_execution_runs(task):
    """shard=True is a no-op on one device but must execute end-to-end."""
    spec = CampaignSpec(
        base=BASE, cells=(CellSpec("g", {"attack": "gaussian"}),), seeds=(0,)
    )
    res = run_campaign(spec, lambda cfg: task, shard=True)
    assert res.cell("g").metrics["acc"].shape == (1, BASE["rounds"])
