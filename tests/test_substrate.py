"""Substrate tests: data pipeline, partitioners, optimizer, checkpointing,
aggregation baselines."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep; see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core import (
    fedavg_aggregate,
    geometric_median,
    get_attack,
    rsa_aggregate,
    signsgd_mv_aggregate,
)
from repro.data import (
    make_classification,
    make_image_classification,
    make_lm_streams,
    partition_dirichlet,
    partition_label_skew,
)
from repro.models.vision import (
    accuracy,
    cnn_logits,
    init_cnn,
    init_resnet,
    resnet_logits,
    xent_loss,
)
from repro.optim import local_prox_train
from jax.flatten_util import ravel_pytree


class TestData:
    def test_label_skew_respects_class_budget(self):
        (_, y), _ = make_classification(0, n_train=2000)
        parts = partition_label_skew(y, 8, 2, 50)
        for idx in parts:
            assert len(idx) == 50
            assert len(np.unique(y[idx])) <= 2

    def test_dirichlet_partition(self):
        (_, y), _ = make_classification(0, n_train=2000)
        parts = partition_dirichlet(y, 8, 50, alpha=0.3)
        assert all(len(i) == 50 for i in parts)

    def test_lm_streams_skewed(self):
        streams = make_lm_streams(0, 4, 1000, 32, 10)
        assert len(streams) == 4
        assert streams[0].shape == (10, 32)
        assert streams[0].max() < 1000
        # different clients should have different unigram histograms
        h0 = np.bincount(streams[0].ravel(), minlength=1000)
        h1 = np.bincount(streams[1].ravel(), minlength=1000)
        assert not np.array_equal(h0, h1)


class TestVisionModels:
    def test_cnn_forward_backward(self):
        p = init_cnn(jax.random.PRNGKey(0), width=8)
        x = jnp.ones((2, 28, 28, 1))
        logits = cnn_logits(p, x)
        assert logits.shape == (2, 10)
        g = jax.grad(lambda q: xent_loss(cnn_logits, q, {"x": x, "y": jnp.zeros(2, jnp.int32)}))(p)
        assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))

    def test_resnet_forward(self):
        p = init_resnet(jax.random.PRNGKey(0), width=8, blocks=(1, 1, 1, 1))
        x = jnp.ones((2, 32, 32, 3))
        logits = resnet_logits(p, x, blocks=(1, 1, 1, 1))
        assert logits.shape == (2, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestOptim:
    def test_prox_pull_toward_global(self):
        """With zero data gradient, the prox term pulls w to w_global."""
        w0 = jnp.zeros(16)
        w_init = jnp.ones(16)
        batches = {"x": jnp.zeros((50, 1))}

        def loss_fn(params, batch):
            return 0.0 * jnp.sum(params)  # no data signal

        from jax.flatten_util import ravel_pytree

        flat, unravel = ravel_pytree(w_init)
        w, l0, l1 = local_prox_train(
            lambda p, b: loss_fn(p, b), w0, flat, unravel, batches,
            lr=0.1, mu=0.0, lam=1.0,
        )
        assert float(jnp.max(jnp.abs(w))) < float(jnp.max(jnp.abs(w_init)))


class TestAggregators:
    def test_fedavg_is_mean(self):
        u = jnp.arange(12.0).reshape(3, 4)
        np.testing.assert_allclose(fedavg_aggregate(u), u.mean(0))

    def test_geometric_median_resists_outlier(self):
        key = jax.random.PRNGKey(0)
        u = 0.01 * jax.random.normal(key, (20, 8))
        evil = u.at[0].set(1e6)
        gm = geometric_median(evil)
        assert float(jnp.linalg.norm(gm)) < 1.0
        assert float(jnp.linalg.norm(fedavg_aggregate(evil))) > 1e4

    def test_signsgd_mv_magnitude(self):
        codes = jnp.ones((5, 7), jnp.int8)
        out = signsgd_mv_aggregate(codes, step=0.01)
        np.testing.assert_allclose(out, 0.01)

    def test_rsa_accumulates(self):
        codes = jnp.ones((5, 7), jnp.int8)
        np.testing.assert_allclose(rsa_aggregate(codes, 0.01), 0.05)

    @settings(deadline=None, max_examples=10)
    @given(st.integers(0, 1000))
    def test_attacks_preserve_honest_rows(self, seed):
        key = jax.random.PRNGKey(seed)
        u = jax.random.normal(key, (10, 6))
        for name in ("gaussian", "sign_flip", "zero_gradient", "sample_duplicate"):
            out = get_attack(name)(key, u, 3)
            np.testing.assert_array_equal(np.asarray(out[3:]), np.asarray(u[3:]))

    def test_zero_gradient_sums_to_zero(self):
        key = jax.random.PRNGKey(1)
        u = jax.random.normal(key, (10, 6))
        out = get_attack("zero_gradient")(key, u, 4)
        np.testing.assert_allclose(np.asarray(out.sum(0)), 0.0, atol=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 3, tree, {"note": "x"})
        assert latest_step(str(tmp_path)) == 3
        out = load_checkpoint(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(5.0))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_shape_mismatch_raises(self, tmp_path):
        tree = {"a": jnp.arange(5.0)}
        save_checkpoint(str(tmp_path), 1, tree)
        bad = {"a": jnp.arange(6.0)}
        with pytest.raises(AssertionError):
            load_checkpoint(str(tmp_path), 1, bad)
