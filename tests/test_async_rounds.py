"""Buffered-asynchronous round tests: parity, buffer semantics, straggler.

The load-bearing test is the degenerate-parity one: ``async_fl_round``
with a full buffer (``async_buffer == n_active``), zero latency, and
staleness decay 0 must reproduce the synchronous ``fl_round`` trajectory
*bit for bit* over 5 rounds for every registered aggregator — that is
what licenses threading one async code path through the campaign engine
without re-validating the paper's synchronous claims.

The straggler regression pins the composite timing adversary
(``straggler+sign_flip``) below the Theorem-2 breakdown point and asserts
the async aggregation error stays within 2x of the synchronous run — the
guard against staleness weighting *amplifying* withheld Byzantine votes.
"""

import functools

import jax
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig
from repro.fl import rounds as R
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import CampaignSpec, CellSpec, Task, run_campaign

N_CLIENTS = 10
AGGREGATORS = ("probit_plus", "fedavg", "fed_gm", "signsgd_mv", "rsa")


@pytest.fixture(scope="module")
def task():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, N_CLIENTS, 2, 60, seed=1)
    return Task(
        init_params=init_mlp(jax.random.PRNGKey(0), hidden=8),
        loss_fn=functools.partial(xent_loss, mlp_logits),
        acc_fn=functools.partial(accuracy, mlp_logits),
        client_x=np.stack([xtr[i] for i in parts]),
        client_y=np.stack([ytr[i] for i in parts]),
        test={"x": xte, "y": yte},
    )


def _ctx(task, cfg):
    return R.make_context(
        cfg, task.init_params, task.loss_fn, task.acc_fn,
        task.client_x, task.client_y, task.test,
    )


def _degenerate_pair(aggregator, rounds=5):
    base = dict(
        n_clients=N_CLIENTS, rounds=rounds, local_epochs=1,
        aggregator=aggregator,
    )
    return FLConfig(**base), FLConfig(
        **base, async_buffer=N_CLIENTS, async_latency=0.0, staleness_decay=0.0
    )


@pytest.mark.parametrize("aggregator", AGGREGATORS)
def test_async_zero_latency_is_bit_exact_with_sync(task, aggregator):
    """Acceptance: buffer=M, latency=0, decay=0 => bit-exact RoundState
    trajectory (and metrics) over 5 rounds, for all five aggregators.

    Run eagerly: the two variants execute the *identical op schedule* in
    the degenerate case (unit weights make the weighted count/mean paths
    value-identical op by op), which eager dispatch compares exactly.
    Under jit, XLA fuses the weight multiplies into the reductions with
    different tiling per program, reassociating sums at the ~1e-12
    relative level — the jitted scan path is covered at tight tolerance
    by ``test_async_zero_latency_scan_matches_sync_jitted`` below.
    """
    cfg_s, cfg_a = _degenerate_pair(aggregator)
    ctx_s, ctx_a = _ctx(task, cfg_s), _ctx(task, cfg_a)
    ps, pa = R.cell_params(cfg_s), R.cell_params(cfg_a)
    with jax.disable_jit():
        ss, sa = R.init_run_state(ctx_s), R.init_run_state(ctx_a)
        key = jax.random.PRNGKey(cfg_s.seed)
        for _ in range(5):
            key, kb, kr = jax.random.split(key, 3)
            batches = R.round_batches(ctx_s, kb)
            ss, ms = R.fl_round(ctx_s, ps, kr, ss, batches)
            sa, ma = R.async_fl_round(ctx_a, pa, kr, sa, batches)
            np.testing.assert_array_equal(
                np.asarray(ss.w_global), np.asarray(sa.w_global)
            )
            np.testing.assert_array_equal(
                np.asarray(ss.w_locals), np.asarray(sa.w_locals)
            )
            np.testing.assert_array_equal(
                np.asarray(ss.residuals), np.asarray(sa.residuals)
            )
            assert float(ss.b.b) == float(sa.b.b)
            for k in ("loss", "b", "theta_mse"):
                assert float(ms[k]) == float(ma[k]), k
            # degenerate buffer is fully fresh every round
            assert float(ma["buf_fill"]) == 1.0
            assert float(ma["mean_age"]) == 0.0


@pytest.mark.parametrize("aggregator", ("probit_plus", "fedavg"))
def test_async_zero_latency_scan_matches_sync_jitted(task, aggregator):
    """The jitted/scanned execution of the degenerate async config tracks
    the sync scan within float tolerance (XLA fusion may reassociate the
    weighted reductions; see the eager bit-exact test above)."""
    cfg_s, cfg_a = _degenerate_pair(aggregator)
    ctx_s, ctx_a = _ctx(task, cfg_s), _ctx(task, cfg_a)
    key = jax.random.PRNGKey(0)
    fs, traj_s = jax.jit(
        lambda k: R.run_rounds(ctx_s, R.cell_params(cfg_s), k,
                               R.init_run_state(ctx_s), 5)
    )(key)
    fa, traj_a = jax.jit(
        lambda k: R.run_rounds(ctx_a, R.cell_params(cfg_a), k,
                               R.init_run_state(ctx_a), 5)
    )(key)
    np.testing.assert_allclose(
        np.asarray(fs.w_global), np.asarray(fa.w_global), rtol=0, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(traj_s["acc"]), np.asarray(traj_a["acc"]), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(traj_s["loss"]), np.asarray(traj_a["loss"]), rtol=1e-5
    )


def test_empty_buffer_estimates_zero(task):
    """Under extreme latency nothing arrives: every slot stays invalid,
    the weighted estimate is exactly zero, and the global model does not
    move — the async server never steps on an empty buffer."""
    cfg = FLConfig(
        n_clients=N_CLIENTS, rounds=2, local_epochs=1,
        async_buffer=N_CLIENTS, async_latency=1e9,
    )
    ctx = _ctx(task, cfg)
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    w0 = np.asarray(state.w_global)
    key = jax.random.PRNGKey(0)
    for _ in range(2):
        key, kb, kr = jax.random.split(key, 3)
        state, m = R.async_fl_round(
            ctx, params, kr, state, R.round_batches(ctx, kb)
        )
        assert float(m["buf_fill"]) == 0.0
        np.testing.assert_array_equal(np.asarray(state.w_global), w0)
    assert not bool(np.any(np.asarray(state.buf_valid)))


def test_straggler_delivers_once_then_withholds(task):
    """The straggler timing adversary fills its slot on round 1 and never
    refreshes: its upload's age grows by one per round while (here, under
    extreme honest latency) honest slots stay empty."""
    byz_frac = 0.2
    n_byz = int(N_CLIENTS * byz_frac)
    cfg = FLConfig(
        n_clients=N_CLIENTS, rounds=4, local_epochs=1, byz_frac=byz_frac,
        attack="straggler+sign_flip", async_buffer=N_CLIENTS,
        async_latency=1e9,
    )
    ctx = _ctx(task, cfg)
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(0)
    for t in range(4):
        key, kb, kr = jax.random.split(key, 3)
        state, m = R.async_fl_round(
            ctx, params, kr, state, R.round_batches(ctx, kb)
        )
        valid = np.asarray(state.buf_valid)
        assert valid[:n_byz].all() and not valid[n_byz:].any()
        np.testing.assert_array_equal(np.asarray(state.buf_age)[:n_byz], t)
        assert float(m["buf_fill"]) == pytest.approx(n_byz / N_CLIENTS)
        assert float(m["mean_age"]) == t


def test_buffer_contention_smaller_than_cohort(task):
    """B < M: clients share slots mod B; at zero latency every slot is
    overwritten by its highest-index sharer each round (ages stay 0)."""
    cfg = FLConfig(
        n_clients=N_CLIENTS, rounds=3, local_epochs=1,
        async_buffer=3, async_latency=0.0,
    )
    ctx = _ctx(task, cfg)
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(0)
    for _ in range(3):
        key, kb, kr = jax.random.split(key, 3)
        state, m = R.async_fl_round(
            ctx, params, kr, state, R.round_batches(ctx, kb)
        )
        assert float(m["buf_fill"]) == 1.0
        assert float(m["mean_age"]) == 0.0
    assert state.buf_rows.shape[0] == 3


def test_straggler_repoisons_contended_slot(task):
    """Under slot contention (B < M) an honest slot-sharer can evict the
    withheld Byzantine upload; the straggler must then *re-deliver* to
    re-poison the slot rather than stay locked out (its gate is keyed to
    slot ownership, not slot occupancy). Over a few rounds at pinned seed
    both states must occur: the Byzantine client owning its slot at
    growing age, and the honest sharer owning it after an eviction."""
    n_buf, byz_frac = 5, 0.2
    n_byz = int(N_CLIENTS * byz_frac)
    cfg = FLConfig(
        n_clients=N_CLIENTS, rounds=8, local_epochs=1, byz_frac=byz_frac,
        attack="straggler+sign_flip", async_buffer=n_buf, async_latency=1.0,
    )
    ctx = _ctx(task, cfg)
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(0)
    byz_owned = honest_owned = 0
    for _ in range(8):
        key, kb, kr = jax.random.split(key, 3)
        state, _ = R.async_fl_round(
            ctx, params, kr, state, R.round_batches(ctx, kb)
        )
        owner = np.asarray(state.buf_owner)[:n_byz]
        byz_owned += int(np.any((owner >= 0) & (owner < n_byz)))
        honest_owned += int(np.any(owner >= n_byz))
    assert byz_owned > 0, "straggler never re-poisoned its slot"
    assert honest_owned > 0, "honest sharer never evicted the straggler"


def test_colluding_stragglers_share_slot_without_evicting_each_other(task):
    """Two Byzantine stragglers mapped to one slot (B < n_byz span) must
    not ping-pong evict each other — the withhold gate is keyed to 'any
    Byzantine upload resident', so the first delivery sticks and its
    staleness grows exactly as for a lone straggler."""
    byz_frac = 0.3  # byz clients 0,1,2; with B=2: clients 0 and 2 share slot 0
    cfg = FLConfig(
        n_clients=N_CLIENTS, rounds=5, local_epochs=1, byz_frac=byz_frac,
        attack="straggler+sign_flip", async_buffer=2, async_latency=1e9,
    )
    ctx = _ctx(task, cfg)
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(0)
    owners = []
    for t in range(5):
        key, kb, kr = jax.random.split(key, 3)
        state, _ = R.async_fl_round(
            ctx, params, kr, state, R.round_batches(ctx, kb)
        )
        owners.append(np.asarray(state.buf_owner).copy())
        # honest clients never arrive (extreme latency), so after round 0
        # both slots hold Byzantine uploads aging one round per round
        np.testing.assert_array_equal(np.asarray(state.buf_age), t)
    # ownership settled in round 0 and never churned between colluders
    for o in owners[1:]:
        np.testing.assert_array_equal(o, owners[0])
    assert all(0 <= o < 3 for o in owners[0])


def test_straggler_theta_mse_within_2x_of_sync(task):
    """Regression (satellite): at byz_frac 0.2 — below the Theorem-2
    breakdown point beta < 1/2 — the straggler+sign_flip adversary must
    not blow up the async aggregation error: per-run mean theta-MSE stays
    within 2x of the synchronous sign_flip run at the pinned seeds.

    Calibration (this exact grid, seeds 0-2): async/sync mean-theta-MSE
    ratio 1.08 +/- 0.01 at decay 0.5 and 0.97 +/- 0.02 at decay 0, so the
    2x bound has ~2x headroom against MC noise. A violation means the
    staleness weighting started *amplifying* withheld Byzantine votes.
    """
    spec = CampaignSpec(
        base=dict(
            n_clients=N_CLIENTS, rounds=20, local_epochs=1,
            byz_frac=0.2, b_mode="fixed",
        ),
        cells=(
            CellSpec("sync", {"attack": "sign_flip"}),
            CellSpec(
                "async_strag",
                {
                    "attack": "straggler+sign_flip",
                    "async_buffer": N_CLIENTS,
                    "async_latency": 1.0,
                    "staleness_decay": 0.5,
                },
            ),
        ),
        seeds=(0, 1, 2),
    )
    res = run_campaign(spec, lambda cfg: task, with_acc=False)
    sync = res.cell("sync").metrics["theta_mse"].mean(axis=1)
    strag = res.cell("async_strag").metrics["theta_mse"].mean(axis=1)
    ratio = strag / sync
    assert np.all(ratio < 2.0), ratio


def test_mixed_sync_async_campaign_single_call(task, tmp_path):
    """Acceptance: one run_campaign call executes a grid mixing sync and
    async cells — async cells (including a straggler timing cell) share
    one vmapped program, sync cells another — and the result serializes
    to the campaign JSON artifact."""
    spec = CampaignSpec(
        base=dict(
            n_clients=N_CLIENTS, rounds=3, local_epochs=1,
            byz_frac=0.2, b_mode="fixed",
        ),
        cells=(
            CellSpec("sync_gauss", {"attack": "gaussian"}),
            CellSpec(
                "async_gauss",
                {"attack": "gaussian", "async_buffer": N_CLIENTS,
                 "async_latency": 1.0, "staleness_decay": 0.5},
            ),
            CellSpec(
                "async_strag",
                {"attack": "straggler+sign_flip", "async_buffer": N_CLIENTS,
                 "async_latency": 1.0, "staleness_decay": 0.5},
            ),
        ),
        seeds=(0, 1),
    )
    res = run_campaign(spec, lambda cfg: task)
    groups = sorted(sorted(g["cells"]) for g in res.groups)
    assert groups == [["async_gauss", "async_strag"], ["sync_gauss"]]
    for name in ("async_gauss", "async_strag"):
        cell = res.cell(name)
        assert cell.metrics["acc"].shape == (2, 3)
        assert {"buf_fill", "mean_age"} <= set(cell.metrics)
    assert "buf_fill" not in res.cell("sync_gauss").metrics
    path = res.save(str(tmp_path / "mixed_campaign.json"))
    import json

    with open(path) as f:
        js = json.load(f)
    assert set(js["cells"]) == {"sync_gauss", "async_gauss", "async_strag"}


def test_async_config_validation():
    """FLConfig rejects inconsistent async settings with precise errors."""
    ok = dict(n_clients=4, rounds=1)
    with pytest.raises(ValueError, match="async_buffer"):
        FLConfig(**ok, async_buffer=-1)
    with pytest.raises(ValueError, match="exceeds the cohort"):
        FLConfig(**ok, async_buffer=5)
    with pytest.raises(ValueError, match="async_latency"):
        FLConfig(**ok, async_buffer=4, async_latency=-0.5)
    with pytest.raises(ValueError, match="staleness_decay"):
        FLConfig(**ok, async_buffer=4, staleness_decay=-1.0)
    with pytest.raises(ValueError, match="require buffered-async"):
        FLConfig(**ok, async_latency=1.0)
    with pytest.raises(ValueError, match="require buffered-async"):
        FLConfig(**ok, staleness_decay=0.5)
    with pytest.raises(ValueError, match="timing attack"):
        FLConfig(**ok, attack="straggler")
    with pytest.raises(ValueError, match="timing attack"):
        FLConfig(**ok, attack="straggler+alie")
    with pytest.raises(ValueError, match="unknown straggler payload"):
        FLConfig(**ok, attack="straggler+nope", async_buffer=4)
    with pytest.raises(ValueError, match="use 'straggler'"):
        FLConfig(**ok, attack="straggler+none", async_buffer=4)
    with pytest.raises(ValueError, match="unknown attack"):
        FLConfig(**ok, attack="nope")
    with pytest.raises(ValueError, match="SparseWire"):
        FLConfig(**ok, async_buffer=4, topk_frac=0.1)
    # buffer slots are keyed to client identity; a resampled cohort breaks
    # that, so async + partial participation is rejected (model partial
    # availability with async_latency instead)
    with pytest.raises(ValueError, match="participation == 1.0"):
        FLConfig(**ok, async_buffer=2, participation=0.5)
    # valid compositions construct fine
    FLConfig(**ok, attack="straggler", async_buffer=4)
    FLConfig(**ok, attack="straggler+bit_flip", async_buffer=2, byz_frac=0.25)
