"""Hierarchical count-tree aggregation: parity, robustness, shard, memory.

The tree round (``FLConfig.tree_edges > 0``) must be a pure *execution
topology* change for honest synchronous runs — clients -> edges -> root
produces the same estimates as the flat streaming round. Four layers are
pinned here:

* **bit-exact parity** — tree == flat streaming round for every
  count-streaming scheme, edge counts that do and do not divide M, under
  participation sampling, error feedback, and client-level Byzantine
  attacks (full carried state, eager); <= 1e-6 under jit; the buffered
  tree at zero latency / zero decay degenerates to the same bits;
* **Byzantine edges** — the naive additive root merge inherits a
  minority-edge corruption that the rate-space median merge survives;
* **device mapping** — ``tree_shard`` under 4 virtual CPU devices
  reproduces the host-loop edge sweep (subprocess: the XLA flag must
  precede jax platform init; the CI ``tree-smoke`` job runs this);
* **memory bound** — a 60k-client tree round completes under the same
  hard ``RLIMIT_AS`` cap as the flat streaming round (the donated round
  state reuses its buffers instead of reallocating per round).
"""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import EDGE_ATTACK_IDS, apply_edge_attack, edge_attack_id
from repro.data import make_classification, partition_label_skew
from repro.fl import rounds as R
from repro.fl.hierarchy import TreeRoundState, edge_slices
from repro.fl.runtime import FLConfig
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

COUNT_SCHEMES = ("probit_plus", "signsgd_mv", "rsa")
N = 10
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Edge slicing (unit)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,n_edges", [(10, 1), (10, 2), (10, 3), (10, 10), (7, 4)])
def test_edge_slices_partition(n, n_edges):
    """Slices are contiguous, disjoint, cover [0, n), balanced to +-1."""
    slices = edge_slices(n, n_edges)
    assert len(slices) == n_edges
    row = 0
    sizes = []
    for row0, n_e in slices:
        assert row0 == row
        assert n_e >= 1
        sizes.append(n_e)
        row += n_e
    assert row == n
    assert max(sizes) - min(sizes) <= 1


# ---------------------------------------------------------------------------
# Round parity: tree == flat streaming round
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def round_env():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, N, 2, 60, seed=1)
    return dict(
        p0=init_mlp_cached(),
        loss=functools.partial(xent_loss, mlp_logits),
        acc=functools.partial(accuracy, mlp_logits),
        cx=np.stack([xtr[i] for i in parts]),
        cy=np.stack([ytr[i] for i in parts]),
        test={"x": xte, "y": yte},
    )


def init_mlp_cached():
    return init_mlp(jax.random.PRNGKey(0), hidden=8)


def _run(round_env, cfg, rounds=2, eager=True):
    ctx = R.make_context(
        cfg,
        round_env["p0"],
        round_env["loss"],
        round_env["acc"],
        round_env["cx"],
        round_env["cy"],
        round_env["test"],
    )
    params = R.cell_params(cfg)
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(cfg.seed)
    fn = R.round_fn(ctx)
    with jax.disable_jit(eager):
        for _ in range(rounds):
            key, kb, kr = jax.random.split(key, 3)
            state, m = fn(ctx, params, kr, state, R.round_batches(ctx, kb))
    return state, m


@pytest.mark.parametrize("agg", COUNT_SCHEMES)
@pytest.mark.parametrize("edges", [2, 3])
def test_tree_parity_count_schemes(round_env, agg, edges):
    """Tree == flat, bit-exact, for every count scheme; 3 does not divide
    M = 10, so uneven edge slices are on the asserted path."""
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator=agg, client_chunk=4
    )
    flat, _ = _run(round_env, FLConfig(**base))
    tree, _ = _run(round_env, FLConfig(**base, tree_edges=edges))
    np.testing.assert_array_equal(
        np.asarray(flat.w_global), np.asarray(tree.w_global)
    )
    np.testing.assert_array_equal(np.asarray(flat.b.b), np.asarray(tree.b.b))


@pytest.mark.parametrize(
    "extra",
    [
        dict(participation=0.7),
        dict(error_feedback=True),
        dict(byz_frac=0.2, attack="sign_flip"),
    ],
    ids=["participation", "error_feedback", "sign_flip"],
)
def test_tree_parity_masks_state_attacks(round_env, extra):
    """Parity extends to the full carried state (w_locals, residuals)
    under cohort sampling, EF, and client-level Byzantine attacks."""
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        client_chunk=4, **extra,
    )
    flat, _ = _run(round_env, FLConfig(**base))
    tree, _ = _run(round_env, FLConfig(**base, tree_edges=3))
    for field in ("w_global", "w_locals", "residuals"):
        np.testing.assert_array_equal(
            np.asarray(getattr(flat, field)), np.asarray(getattr(tree, field))
        )


@pytest.mark.parametrize("agg", COUNT_SCHEMES)
def test_tree_buffered_zero_staleness_parity(round_env, agg):
    """edge_buffer == tree_edges at zero latency / zero decay refreshes
    every slot every round with weight exactly 1.0 — bit-identical to the
    unbuffered tree (and hence to the flat round)."""
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator=agg, client_chunk=4
    )
    flat, _ = _run(round_env, FLConfig(**base))
    buf, _ = _run(round_env, FLConfig(**base, tree_edges=3, edge_buffer=3))
    assert isinstance(buf, TreeRoundState)
    np.testing.assert_array_equal(
        np.asarray(flat.w_global), np.asarray(buf.w_global)
    )
    np.testing.assert_array_equal(np.asarray(flat.b.b), np.asarray(buf.b.b))
    assert bool(np.all(np.asarray(buf.edge_valid)))
    assert np.all(np.asarray(buf.edge_age) == 0)


def test_tree_parity_under_jit(round_env):
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        client_chunk=4,
    )
    flat, _ = _run(round_env, FLConfig(**base), eager=False)
    tree, _ = _run(round_env, FLConfig(**base, tree_edges=3), eager=False)
    np.testing.assert_allclose(
        np.asarray(flat.w_global), np.asarray(tree.w_global), atol=1e-6
    )


def test_tree_smoke_metrics(round_env):
    """The tree round's extra health metrics exist and are finite."""
    cfg = FLConfig(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        client_chunk=4, tree_edges=3, edge_buffer=2, async_latency=1.0,
        staleness_decay=0.5,
    )
    state, m = _run(round_env, cfg, eager=False)
    assert isinstance(state, TreeRoundState)
    for k in ("loss", "theta_mse", "edge_mass_min", "buf_fill", "mean_age"):
        assert np.isfinite(float(m[k])), k
    assert 0.0 <= float(m["buf_fill"]) <= 1.0


# ---------------------------------------------------------------------------
# Byzantine edge aggregators
# ---------------------------------------------------------------------------


def test_apply_edge_attack_semantics():
    """Unit semantics of each edge corruption; honest edges untouched and
    the 0 <= N <= mass invariant (range-check undetectability) holds."""
    counts = jnp.asarray([[1.0, 2.0], [3.0, 0.0], [2.0, 2.0]])
    mass = jnp.asarray([4.0, 4.0, 4.0])
    prev_c = jnp.asarray([[9.0, 9.0]] * 3)
    prev_m = jnp.asarray([7.0, 7.0, 7.0])
    prev_v = jnp.asarray([True, True, False])
    byz = jnp.asarray([True, False, True])

    c, m = apply_edge_attack(
        edge_attack_id("edge_sign_flip"), counts, mass, prev_c, prev_m, prev_v, byz
    )
    np.testing.assert_array_equal(
        np.asarray(c), [[3.0, 2.0], [3.0, 0.0], [2.0, 2.0]]
    )
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mass))

    c, m = apply_edge_attack(
        edge_attack_id("edge_inflate"), counts, mass, prev_c, prev_m, prev_v, byz
    )
    np.testing.assert_array_equal(
        np.asarray(c), [[4.0, 4.0], [3.0, 0.0], [4.0, 4.0]]
    )

    c, m = apply_edge_attack(
        edge_attack_id("edge_replay"), counts, mass, prev_c, prev_m, prev_v, byz
    )
    # byz edge 0 replays its valid slot; byz edge 2's slot is invalid
    # (nothing buffered yet) so it falls through to the fresh tensor.
    np.testing.assert_array_equal(
        np.asarray(c), [[9.0, 9.0], [3.0, 0.0], [2.0, 2.0]]
    )
    np.testing.assert_array_equal(np.asarray(m), [7.0, 4.0, 4.0])

    # invariant: every attacked tensor stays inside [0, mass]
    for name in EDGE_ATTACK_IDS[1:-1]:
        c, m = apply_edge_attack(
            edge_attack_id(name), counts, mass, prev_c, prev_m, prev_v, byz
        )
        assert bool(jnp.all((c >= 0) & (c <= m[:, None])))

    with pytest.raises(ValueError, match="unknown edge attack"):
        edge_attack_id("edge_nonsense")


@pytest.mark.parametrize("attack", ["edge_inflate", "edge_sign_flip"])
@pytest.mark.parametrize(
    "merge,trim", [("median", 0), ("trimmed", 3)], ids=["median", "trimmed"]
)
def test_byzantine_edges_breakdown(attack, merge, trim):
    """floor(E/2) - 1 corrupted edges at realistic edge mass: the naive
    additive merge inherits the corruption; the rate-space robust merges
    stay within the honest edges' sampling noise (>= 4x tighter).

    Asserted at the root-merge layer — a full training endpoint conflates
    merge quality with chaotic trajectory divergence, and the tiny test
    fixture's 1-2-client edges quantize vote rates too coarsely for any
    order-statistic merge to be meaningful. Here each edge carries 200
    clients' binomial vote counts over a spread of per-coordinate rates
    (sign-flip is self-cancelling at rate 1/2, so the spread matters).
    """
    from types import SimpleNamespace

    from repro.fl.hierarchy import _root_merge

    rng = np.random.default_rng(0)
    E, D, MASS = 8, 64, 200.0
    p = rng.uniform(0.1, 0.9, D)
    counts = jnp.asarray(rng.binomial(int(MASS), p, (E, D)), jnp.float32)
    mass = jnp.full((E,), MASS, jnp.float32)
    # honest reference: the exact-sum estimate in rate space ((2N - M)/M)
    honest = 2 * np.asarray(counts).sum(0) / (E * MASS) - 1

    zeros = jnp.zeros_like(counts), jnp.zeros_like(mass), jnp.zeros((E,), bool)
    c_a, m_a = apply_edge_attack(
        edge_attack_id(attack), counts, mass, *zeros, jnp.arange(E) < 3
    )

    def err(merge_name, t):
        cfg = SimpleNamespace(edge_merge=merge_name, edge_trim=t)
        cm, mm = _root_merge(cfg, c_a, m_a, None)
        return float(np.abs(2 * np.asarray(cm) / np.asarray(mm) - 1 - honest).max())

    err_naive, err_robust = err("sum", 0), err(merge, trim)
    assert err_naive > 4 * err_robust, (attack, merge, err_naive, err_robust)
    assert err_robust < 0.15, err_robust  # within honest sampling noise


def test_trimmed_merge_clean_parity(round_env):
    """With zero Byzantine edges the trimmed merge is a consistent
    estimator of the same update (not bit-exact — rate-space mean over a
    trimmed edge subset), and stays close to the exact sum."""
    base = dict(
        n_clients=N, rounds=2, local_epochs=1, aggregator="probit_plus",
        client_chunk=4, tree_edges=5,
    )
    exact, _ = _run(round_env, FLConfig(**base), eager=False)
    trimmed, _ = _run(
        round_env, FLConfig(**base, edge_merge="trimmed", edge_trim=1),
        eager=False,
    )
    # same order of magnitude as the update itself: a sanity bound, the
    # robustness-vs-exactness tradeoff is quantified in the breakdown test
    err = np.linalg.norm(np.asarray(trimmed.w_global) - np.asarray(exact.w_global))
    assert err < 1.0, err


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

_TREE = dict(
    n_clients=N, rounds=1, aggregator="probit_plus", client_chunk=4, tree_edges=2
)


@pytest.mark.parametrize(
    "overrides,match",
    [
        (dict(n_clients=N, rounds=1, edge_buffer=2), "requires a hierarchical"),
        (dict(n_clients=N, rounds=1, edge_merge="median"), "requires a hierarchical"),
        (dict(_TREE, aggregator="fedavg"), "count-streaming"),
        (dict(_TREE, client_chunk=0), "client_chunk"),
        (dict(_TREE, tree_edges=N + 1), "exceeds the cohort"),
        # the earlier chunk-vs-async-server gate fires first; either way a
        # buffered-async client round cannot coexist with a tree
        (dict(_TREE, async_buffer=2), "async_buffer"),
        (dict(_TREE, stream_shard=True, stateless_clients=True), "tree_shard"),
        (dict(_TREE, edge_buffer=3), "exceeds tree_edges"),
        (dict(_TREE, edge_attack="flip_codes"), "unknown edge_attack"),
        (dict(_TREE, byz_edges=3), "byz_edges must be in"),
        (dict(_TREE, byz_edges=1), "needs an edge_attack"),
        (dict(_TREE, byz_edges=1, edge_attack="edge_replay"), "edge_replay"),
        (dict(_TREE, edge_merge="krum"), "unknown edge_merge"),
        (
            dict(_TREE, edge_merge="median", edge_buffer=2),
            "robust edge merges",
        ),
        (dict(_TREE, edge_trim=1), "edge_trim only applies"),
        (
            dict(_TREE, edge_merge="trimmed", edge_trim=1),
            "trims away all",
        ),
        (dict(_TREE, tree_shard=True), "stateless_clients"),
        (
            dict(_TREE, tree_shard=True, stateless_clients=True,
                 participation=0.5),
            "participation",
        ),
        (
            dict(_TREE, tree_shard=True, stateless_clients=True,
                 tree_edges=3),
            "equal edge slices",
        ),
    ],
)
def test_config_validation(overrides, match):
    with pytest.raises(ValueError, match=match):
        FLConfig(**overrides)


# ---------------------------------------------------------------------------
# Campaign integration
# ---------------------------------------------------------------------------


def test_campaign_tree_cells():
    """Tree cells run through the campaign engine: never fused (static
    edge slices cannot pad to a traced boundary), tagged in describe(),
    tree_edges in the group stats, and metric parity with the flat cell."""
    from repro.sim import CampaignSpec, CellSpec, Task, run_campaign
    from repro.sim.plan import fusable, plan_campaign

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=800, n_test=100)

    def task_fn(cfg, _cache={}):
        m = cfg.n_clients
        if m not in _cache:
            parts = partition_label_skew(ytr, m, 2, 30, seed=1)
            _cache[m] = Task(
                init_params=init_mlp_cached(),
                loss_fn=functools.partial(xent_loss, mlp_logits),
                acc_fn=functools.partial(accuracy, mlp_logits),
                client_x=np.stack([xtr[i] for i in parts]),
                client_y=np.stack([ytr[i] for i in parts]),
                test={"x": xte, "y": yte},
            )
        return _cache[m]

    base = dict(rounds=2, local_epochs=1, client_chunk=4)
    spec = CampaignSpec(
        base=base,
        cells=(
            CellSpec("flat", dict(n_clients=8)),
            CellSpec("tree", dict(n_clients=8, tree_edges=2)),
            CellSpec("tree_buf", dict(n_clients=8, tree_edges=2, edge_buffer=1)),
        ),
        seeds=(0,),
    )
    assert not fusable(spec.config(spec.cells[1]))
    plan = plan_campaign(spec)
    desc = plan.describe()
    assert "tree@2" in desc and "buf1" in desc

    res = run_campaign(spec, task_fn, plan=plan)
    tree_groups = [g for g in res.groups if g["tree_edges"]]
    assert tree_groups and all(not g["fused"] for g in tree_groups)
    # synchronous tree == flat through the whole campaign path
    np.testing.assert_allclose(
        res.cell("tree").metrics["theta_mse"],
        res.cell("flat").metrics["theta_mse"],
        atol=1e-9,
    )


def test_trajectory_ci_json_roundtrip():
    """Campaign JSON artifacts carry trajectory_ci; plots._cell_series
    recovers nonzero bands from the serialized dict (satellite of the
    tree-throughput figure: its PNG renders from the JSON on disk)."""
    from benchmarks.plots import _cell_series
    from repro.sim.metrics import CellResult, CampaignResult

    rng = np.random.default_rng(0)
    cell = CellResult(
        name="c0", overrides={}, metrics={"loss": rng.random((3, 4))}
    )
    res = CampaignResult(cells=[cell], seeds=(0, 1, 2), groups=[], wall_s=1.0)
    payload = res.to_json()
    assert "trajectory_ci" in payload["cells"]["c0"]
    series = _cell_series(payload, "loss")
    mean, half = series["c0"]
    np.testing.assert_allclose(mean, cell.trajectory("loss")[0])
    assert np.all(half > 0)  # 3 distinct seeds -> nonzero CI everywhere
    # older artifacts without the key degrade to a band-less line
    del payload["cells"]["c0"]["trajectory_ci"]
    _, half0 = _cell_series(payload, "loss")["c0"]
    assert np.all(half0 == 0)


# ---------------------------------------------------------------------------
# Device-sharded edges + memory bound (CI tree-smoke targets)
# ---------------------------------------------------------------------------

_SHARD_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import jax, numpy as np
    from repro.data import make_classification, partition_label_skew
    from repro.fl import rounds as R
    from repro.fl.hierarchy import tree_shard_devices
    from repro.fl.runtime import FLConfig
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

    M = 16
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=1000, n_test=200)
    parts = partition_label_skew(ytr, M, 2, 40, seed=1)
    env = dict(
        p0=init_mlp(jax.random.PRNGKey(0), hidden=8),
        loss=functools.partial(xent_loss, mlp_logits),
        acc=functools.partial(accuracy, mlp_logits),
        cx=np.stack([xtr[i] for i in parts]),
        cy=np.stack([ytr[i] for i in parts]),
        test={"x": xte, "y": yte},
    )

    def run(shard):
        cfg = FLConfig(
            n_clients=M, rounds=2, local_epochs=1, aggregator="probit_plus",
            client_chunk=4, stateless_clients=True, tree_edges=4,
            tree_shard=shard,
        )
        ctx = R.make_context(cfg, env["p0"], env["loss"], env["acc"],
                             env["cx"], env["cy"], env["test"])
        if shard:
            assert tree_shard_devices(ctx) == 4, jax.devices()
        params = R.cell_params(cfg)
        state = R.init_run_state(ctx)
        key = jax.random.PRNGKey(0)
        fn = R.round_fn(ctx)
        for _ in range(2):
            key, kb, kr = jax.random.split(key, 3)
            state, _ = fn(ctx, params, kr, state, R.round_batches(ctx, kb))
        return np.asarray(state.w_global)

    assert jax.device_count() == 4
    w_host, w_shard = run(False), run(True)
    np.testing.assert_allclose(w_shard, w_host, atol=1e-6)
    print("TREE_SHARD_OK maxdiff=%.2e" % np.abs(w_shard - w_host).max())
    """
)

_RSS_CHILD = textwrap.dedent(
    """
    import resource, sys
    # Same hard cap as the flat streaming RSS test: the tree adds only
    # O(E * d/8) stacked edge tensors on top of the chunk-bounded scan,
    # and the donated round state reuses its buffers across rounds.
    cap = 4 << 30
    resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
    import functools
    import jax, numpy as np
    from repro.fl import rounds as R
    from repro.fl.runtime import FLConfig
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss

    M, DIM, PER, HID = 60_000, 8, 2, 64
    rng = np.random.default_rng(0)
    w = rng.standard_normal(DIM).astype(np.float32)
    cx = rng.standard_normal((M, PER, DIM), dtype=np.float32)
    cy = (cx @ w > 0).astype(np.int32)
    cfg = FLConfig(
        n_clients=M, rounds=2, local_epochs=1, batch_size=PER, lr=0.01,
        b_mode="fixed", b_init=0.1, pack_chunk=512,
        client_chunk=2048, stateless_clients=True,
        tree_edges=4, edge_buffer=4,
    )
    ctx = R.make_context(
        cfg, init_mlp(jax.random.PRNGKey(0), in_dim=DIM, hidden=HID, classes=2),
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits), cx, cy,
        {"x": cx[0], "y": cy[0]},
    )
    _, traj = R.run_rounds(
        ctx, R.cell_params(cfg), jax.random.PRNGKey(0),
        R.init_run_state(ctx), with_acc=False,
    )
    jax.block_until_ready(traj)
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss // 1024
    print(f"TREE_OK maxrss_mb={rss} loss={float(traj['loss'][-1]):.4f}")
    """
)


def _child(script: str, *argv: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # Drop any inherited device-count flag (repro.launch.dryrun writes 512
    # into os.environ when another test imports it).
    env["XLA_FLAGS"] = " ".join(
        f
        for f in env.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    )
    return subprocess.run(
        [sys.executable, "-c", script, *argv],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )


def test_tree_shard_parity_4_virtual_devices():
    """Acceptance: tree_shard under 4 virtual CPU devices reproduces the
    host-loop edge sweep <= 1e-6 (subprocess: the XLA flag must be set
    before jax initializes). The CI tree-smoke job runs this."""
    res = _child(_SHARD_CHILD)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TREE_SHARD_OK" in res.stdout, res.stdout


def test_tree_smoke_rss_capped():
    """M = 60k through a 4-edge buffered tree under the flat round's 4 GB
    RLIMIT_AS cap: resident memory stays chunk-bounded plus O(E * d/8)
    edge tensors — the donation-backed memory acceptance for the tree."""
    res = _child(_RSS_CHILD)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "TREE_OK" in res.stdout, res.stdout
