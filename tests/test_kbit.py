"""k-bit wire tests (PR 9): pack/unpack, L-level MLE, cross-path parity,
randomized-response DP, heterogeneous groups — and the pinned k=1 golden
regression that freezes the paper's one-bit wire byte-for-byte.

Golden vectors: ``tests/data/k1_golden.npz`` was captured at the
pre-refactor HEAD by ``tools/capture_k1_golden.py``. Packed bytes and
integer counts must match *exactly*; theta / EF residuals match to the
jit-reassociation tolerance (1e-6, the PR-3 precedent).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DPConfig,
    HeteroWire,
    build_pipeline,
    hetero_client_groups,
    kbit_estimate_from_counts,
    privacy_loss,
    rr_gamma,
)
from repro.core.quantizer import (
    WIRE_BITS,
    dequantize_levels,
    pack_levels,
    packed_counts,
    packed_quantize_batch,
    quantize_levels,
    unpack_levels,
    wire_bytes,
)
from repro.fl.runtime import FLConfig
from repro.kernels import ops as kops

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "k1_golden.npz")

# The golden capture's exact scenario (tools/capture_k1_golden.py).
M, D, CHUNK, CLIENT_CHUNK = 12, 50, 64, 4
B_SCALAR = 0.4
SEED = 7


def _golden_deltas():
    k = jax.random.PRNGKey(1234)
    return 0.1 * jax.random.normal(k, (M, D), jnp.float32)


# ---------------------------------------------------------------------------
# k-bit primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", WIRE_BITS)
@pytest.mark.parametrize("n", [8, 16, 13, 1, 37])  # incl. n % 8 != 0 tails
def test_pack_unpack_roundtrip(bits, n):
    key = jax.random.PRNGKey(bits * 100 + n)
    levels = jax.random.randint(key, (3, n), 0, 1 << bits).astype(jnp.uint8)
    packed = pack_levels(levels, bits)
    assert packed.shape[-1] == bits * ((n + 7) // 8)
    out = unpack_levels(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(levels))


@pytest.mark.parametrize("bits", WIRE_BITS)
def test_quantize_levels_valid_and_unbiased(bits):
    """Levels are in [0, L-1]; stochastic rounding is unbiased in the
    uniforms (empirical mean of dequantized levels -> delta)."""
    d = 64
    delta = jnp.linspace(-0.29, 0.29, d)
    b = jnp.full((d,), 0.3)
    reps = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), reps)
    us = jax.vmap(lambda k: jax.random.uniform(k, (d,)))(keys)
    lvls = jax.vmap(lambda u: quantize_levels(u, delta, b, bits))(us)
    assert int(jnp.min(lvls)) >= 0 and int(jnp.max(lvls)) <= (1 << bits) - 1
    vals = jax.vmap(lambda l: dequantize_levels(l, b, bits))(lvls)
    # std of the mean ~ step / (2 sqrt(reps)); 5 sigma margin
    step = 2 * 0.3 / ((1 << bits) - 1)
    tol = 5 * step / (2 * np.sqrt(reps)) + 1e-6
    np.testing.assert_allclose(
        np.asarray(jnp.mean(vals, axis=0)), np.asarray(delta), atol=tol
    )


def test_kbit_wire_bytes_helper():
    """wire_bytes is the one source of byte accounting for every caller."""
    assert wire_bytes(64, 1) == 8
    assert wire_bytes(64, 2) == 16
    assert wire_bytes(64, 4) == 32
    assert wire_bytes(50, 1) == 7  # ceil(50/8)
    assert wire_bytes(50, 2) == 14  # 2 planes of 7
    assert wire_bytes(50, 1, d_pad=64) == 8  # padded wire row
    assert wire_bytes(100, 1, topk_frac=0.1) == 4 * 10 + 2  # idx + codes
    with pytest.raises(ValueError):
        wire_bytes(64, 3)


@pytest.mark.parametrize("bits", WIRE_BITS)
def test_kbit_estimate_bounded_and_monotone(bits):
    """The L-level MLE stays inside [-b, b] and is non-decreasing in
    every plane count (all plane weights are positive)."""
    d = 9
    m = 20
    b = jnp.full((d,), 0.5)
    key = jax.random.PRNGKey(1)
    counts = jax.random.randint(key, (bits, d), 0, m + 1)
    est = kbit_estimate_from_counts(counts, m, b, bits)
    assert bool(jnp.all(jnp.abs(est) <= 0.5 + 1e-6))
    for p in range(bits):
        bumped = counts.at[p, 0].add(1)
        est2 = kbit_estimate_from_counts(bumped, m, b, bits)
        assert float(est2[0]) >= float(est[0]) - 1e-7
        np.testing.assert_array_equal(
            np.asarray(est2[1:]), np.asarray(est[1:])
        )


def test_kbit_estimate_reduces_to_eq13_at_k1():
    m = 16
    b = jnp.full((5,), 0.3)
    counts = jnp.array([[0, 4, 8, 12, 16]], jnp.int32)
    from repro.core import ml_estimate_from_counts

    np.testing.assert_allclose(
        np.asarray(kbit_estimate_from_counts(counts, m, b, 1)),
        np.asarray(ml_estimate_from_counts(counts[0], m, b)),
        atol=1e-7,
    )


# ---------------------------------------------------------------------------
# Cross-path bit-exactness at k in {2, 4}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4])
def test_kbit_chunked_equals_dense_equals_kernel(bits):
    """dense == chunked-streaming == kernel-ref at k > 1: same plane
    bytes, same counts, same theta — the counter-derived uniform schedule
    depends only on absolute cohort position."""
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)

    pipe = build_pipeline("probit_plus", wire_bits=bits, chunk=CHUNK)
    wire, _ = pipe.compress_wire(key, deltas, B_SCALAR, res0)
    assert wire.bits == bits
    assert wire.packed.shape == (M, wire_bytes(D, bits, d_pad=64))
    theta_dense = pipe.estimate(wire)

    # chunked-streaming (uneven split exercises row_offset rebasing)
    comp, server = pipe.compressor, pipe.server
    counts = server.init_counts(comp.wire_bytes(D))
    packed_rows = []
    for g0 in range(0, M, CLIENT_CHUNK):
        w_ch, _ = comp.compress(
            key, deltas[g0 : g0 + CLIENT_CHUNK], B_SCALAR,
            res0[g0 : g0 + CLIENT_CHUNK], row_offset=g0,
        )
        packed_rows.append(np.asarray(w_ch.packed))
        counts = server.accumulate_counts(counts, w_ch.packed)
    np.testing.assert_array_equal(
        np.concatenate(packed_rows, axis=0), np.asarray(wire.packed)
    )
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(packed_counts(wire.packed))
    )
    theta_stream = server.finalize(counts, M, comp.b_vector(D, B_SCALAR))
    np.testing.assert_array_equal(
        np.asarray(theta_stream), np.asarray(theta_dense)
    )

    # kernel-ref engine: same planes modulo lane realignment
    kpipe = build_pipeline(
        "probit_plus", wire_bits=bits, use_kernels=True, chunk=CHUNK
    )
    kwire, _ = kpipe.compress_wire(key, deltas, B_SCALAR, res0)
    src = wire.packed.shape[1] // bits
    tgt = kwire.packed.shape[1] // bits
    keep = min(src, tgt)
    np.testing.assert_array_equal(
        np.asarray(wire.packed).reshape(M, bits, src)[:, :, :keep],
        np.asarray(kwire.packed).reshape(M, bits, tgt)[:, :, :keep],
    )
    np.testing.assert_allclose(
        np.asarray(kpipe.estimate(kwire)), np.asarray(theta_dense), atol=1e-6
    )


@pytest.mark.parametrize("bits", [2, 4])
def test_kbit_ref_engine_functions(bits):
    """kernels.ref k-bit engine == the quantizer primitives, one client."""
    from repro.kernels.ref import kbit_aggregate_ref, kbit_quant_compress_ref

    n = 32
    key = jax.random.PRNGKey(5)
    delta = 0.1 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.2)
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    packed, res = kbit_quant_compress_ref(
        delta, b, u, bits=bits, want_residual=True
    )
    lvls = quantize_levels(u, delta, b, bits)
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(pack_levels(lvls, bits))
    )
    np.testing.assert_allclose(
        np.asarray(res),
        np.asarray(delta - dequantize_levels(lvls, b, bits)),
        atol=1e-7,
    )
    theta = kbit_aggregate_ref(packed[None, :], b, bits)
    np.testing.assert_allclose(
        np.asarray(theta),
        np.asarray(dequantize_levels(lvls, b, bits)),
        atol=1e-6,
    )


def test_kbit_interpret_engine_rejected():
    key = jax.random.PRNGKey(0)
    deltas = jnp.zeros((2, 16))
    with pytest.raises(NotImplementedError):
        kops.stoch_quant_compress_batch(
            key, deltas, jnp.float32(0.1), bits=2, engine="interpret"
        )


# ---------------------------------------------------------------------------
# Pinned k=1 regression vs pre-refactor golden vectors
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def test_k1_golden_dense(golden):
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)
    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    wire, res = pipe.compress_wire(key, deltas, B_SCALAR, res0)
    np.testing.assert_array_equal(
        np.asarray(wire.packed), golden["dense_packed"]
    )
    np.testing.assert_array_equal(
        np.asarray(packed_counts(wire.packed)), golden["dense_counts"]
    )
    np.testing.assert_allclose(
        np.asarray(wire.b), golden["dense_b"], atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(pipe.estimate(wire)), golden["dense_theta"], atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(res), golden["dense_residuals"], atol=1e-6
    )


def test_k1_golden_chunked_streaming(golden):
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)
    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    comp, server = pipe.compressor, pipe.server
    b_vec = comp.b_vector(D, B_SCALAR)
    counts = server.init_counts(comp.wire_bytes(D))
    res_stream = np.zeros((M, D), np.float32)
    for g0 in range(0, M, CLIENT_CHUNK):
        w_ch, r_ch = comp.compress(
            key, deltas[g0 : g0 + CLIENT_CHUNK], B_SCALAR,
            res0[g0 : g0 + CLIENT_CHUNK], row_offset=g0,
        )
        counts = server.accumulate_counts(counts, w_ch.packed)
        res_stream[g0 : g0 + CLIENT_CHUNK] = np.asarray(r_ch)
    np.testing.assert_array_equal(np.asarray(counts), golden["stream_counts"])
    np.testing.assert_allclose(
        np.asarray(server.finalize(counts, M, b_vec)),
        golden["stream_theta"],
        atol=1e-6,
    )
    np.testing.assert_allclose(
        res_stream, golden["stream_residuals"], atol=1e-6
    )


def test_k1_golden_kernel_ref(golden):
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)
    kpipe = build_pipeline("probit_plus", use_kernels=True, chunk=CHUNK)
    kwire, _ = kpipe.compress_wire(key, deltas, B_SCALAR, res0)
    np.testing.assert_array_equal(
        np.asarray(kwire.packed), golden["kernel_packed"]
    )
    np.testing.assert_allclose(
        np.asarray(kpipe.estimate(kwire)), golden["kernel_theta"], atol=1e-6
    )


def test_k1_golden_pytree(golden):
    from repro.fl.pytree_wire import (
        aggregate_pytree,
        compress_pytree,
        init_wire_state,
        stream_aggregate_pytree,
    )

    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    params = {
        "w": jnp.zeros((3, 17), jnp.float32),
        "b0": jnp.zeros((5,), jnp.float32),
    }
    tkey = jax.random.PRNGKey(SEED + 1)
    tree_deltas = {
        "w": 0.1
        * jax.random.normal(jax.random.PRNGKey(55), (M, 3, 17), jnp.float32),
        "b0": 0.1
        * jax.random.normal(jax.random.PRNGKey(56), (M, 5), jnp.float32),
    }
    state = init_wire_state(params, M)
    wires, _ = compress_pytree(pipe, tkey, tree_deltas, B_SCALAR, state)
    for i, w in enumerate(wires):
        np.testing.assert_array_equal(
            np.asarray(w.packed), golden[f"pytree_packed_{i}"]
        )
    theta_tree, st2 = aggregate_pytree(
        pipe, tkey, tree_deltas, B_SCALAR, state
    )
    np.testing.assert_allclose(
        np.asarray(theta_tree["w"]), golden["pytree_theta_w"], atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(theta_tree["b0"]), golden["pytree_theta_b0"], atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(st2.residuals["w"]), golden["pytree_res_w"], atol=1e-6
    )
    theta_s, _ = stream_aggregate_pytree(
        pipe, tkey, tree_deltas, B_SCALAR, state, client_chunk=CLIENT_CHUNK
    )
    np.testing.assert_allclose(
        np.asarray(theta_s["w"]), golden["pytree_stream_theta_w"], atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(theta_s["b0"]), golden["pytree_stream_theta_b0"], atol=1e-6
    )


# ---------------------------------------------------------------------------
# Randomized-response DP at k > 1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("eps", [0.1, 0.5, 2.0])
def test_rr_privacy_loss_within_eps(bits, eps):
    """Empirical worst-case LLR of the gamma-mixed L-level wire <= eps for
    adjacent updates at the l1-sensitivity budget."""
    sens = 2e-4
    d = 24
    b = jnp.full((d,), 0.3)
    da = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (d,))
    gam = rr_gamma(eps, sens, b, bits)
    # concentrated (one coordinate) and spread adjacency both bounded
    db_one = da.at[3].add(sens)
    db_spread = da + sens / d
    for db in (db_one, db_spread):
        loss = float(privacy_loss(da, db, b, bits=bits, gamma=gam))
        assert loss <= eps + 1e-5


def test_rr_gamma_monotone_and_debias():
    """gamma shrinks with eps (weaker privacy -> less mixing) and grows
    with bits (finer grid -> smaller step -> more mixing needed); the
    server's 1/(1-gamma) debias keeps the DP estimate near-unbiased."""
    b = jnp.float32(0.3)
    g_eps = [float(rr_gamma(e, 2e-4, b, 2)) for e in (0.1, 0.5, 2.0)]
    assert g_eps == sorted(g_eps, reverse=True)
    g_bits = [float(rr_gamma(0.5, 2e-4, b, k)) for k in (2, 4)]
    assert g_bits[0] < g_bits[1]

    key = jax.random.PRNGKey(11)
    m, d = 400, 32
    deltas = jnp.tile(
        0.05 * jax.random.normal(jax.random.PRNGKey(4), (1, d)), (m, 1)
    )
    pipe = build_pipeline(
        "probit_plus", wire_bits=2, dp=DPConfig(1.0), chunk=64
    )
    wire, _ = pipe.compress_wire(key, deltas, 0.3, jnp.zeros((m, d)))
    theta = pipe.estimate(wire)
    err = float(jnp.max(jnp.abs(theta - deltas[0])))
    # step/sqrt(M) sampling noise dominates; debiased mean stays close
    assert err < 0.05


def test_k1_dp_path_unchanged():
    """At wire_bits=1 the DP mechanism is the paper's b-floor margin —
    rr mixing must NOT engage (gamma is None; b carries the margin)."""
    pipe = build_pipeline("probit_plus", dp=DPConfig(0.5), chunk=64)
    comp = pipe.compressor
    assert comp._gamma(jnp.full((4,), 0.3)) is None
    b_vec = comp.b_vector(8, 0.3)
    margin = (1.0 + 1.0 / 0.5) * 2e-4
    np.testing.assert_allclose(np.asarray(b_vec), 0.3 + margin, atol=1e-7)
    # k>1: margin off, rr gamma on
    pipe2 = build_pipeline(
        "probit_plus", wire_bits=2, dp=DPConfig(0.5), chunk=64
    )
    np.testing.assert_allclose(
        np.asarray(pipe2.compressor.b_vector(8, 0.3)), 0.3, atol=1e-7
    )
    assert pipe2.compressor._gamma(jnp.full((4,), 0.3)) is not None


# ---------------------------------------------------------------------------
# Heterogeneous per-client bit-widths
# ---------------------------------------------------------------------------


def test_hetero_client_groups_rle():
    assert hetero_client_groups((1, 1, 2, 2, 4)) == (
        (0, 2, 1), (2, 4, 2), (4, 5, 4),
    )
    assert hetero_client_groups((2,) * 3) == ((0, 3, 2),)
    assert hetero_client_groups((1, 2, 1)) == ((0, 1, 1), (1, 2, 2), (2, 3, 1))
    with pytest.raises(ValueError):
        hetero_client_groups((1, 3))


def test_hetero_wire_matches_per_group_homogeneous():
    """Each HeteroWire group is byte-identical to a homogeneous compress
    of the same rows at the same cohort offset."""
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)
    cb = (1,) * 4 + (2,) * 4 + (4,) * 4
    ph = build_pipeline("probit_plus", client_bits=cb, chunk=CHUNK)
    wh, _ = ph.compress_wire(key, deltas, B_SCALAR, res0)
    assert isinstance(wh, HeteroWire)
    assert [w.bits for w in wh.wires] == [1, 2, 4]
    for (start, stop, gbits), w in zip(hetero_client_groups(cb), wh.wires):
        pg = build_pipeline("probit_plus", wire_bits=gbits, chunk=CHUNK)
        ref, _ = pg.compressor.compress(
            key, deltas[start:stop], B_SCALAR, res0[start:stop],
            row_offset=start,
        )
        np.testing.assert_array_equal(
            np.asarray(w.packed), np.asarray(ref.packed)
        )
    theta = ph.estimate(wh)
    assert bool(jnp.all(jnp.isfinite(theta)))
    assert bool(jnp.all(jnp.abs(theta) <= B_SCALAR + 1e-6))


def test_hetero_uniform_bits_matches_homogeneous():
    """All-equal client_bits reduces to the homogeneous estimate exactly
    (one group, merge weight cancels)."""
    key = jax.random.PRNGKey(SEED)
    deltas = _golden_deltas()
    res0 = jnp.zeros((M, D), jnp.float32)
    ph = build_pipeline("probit_plus", client_bits=(2,) * M, chunk=CHUNK)
    p2 = build_pipeline("probit_plus", wire_bits=2, chunk=CHUNK)
    th_h = ph(key, deltas, B_SCALAR, res0)[0]
    th_2 = p2(key, deltas, B_SCALAR, res0)[0]
    np.testing.assert_allclose(np.asarray(th_h), np.asarray(th_2), atol=1e-6)


# ---------------------------------------------------------------------------
# FLConfig validation
# ---------------------------------------------------------------------------


def test_flconfig_rejects_bad_wire_bits():
    with pytest.raises(ValueError, match="wire_bits"):
        FLConfig(wire_bits=3)
    with pytest.raises(ValueError, match="probit_plus"):
        FLConfig(aggregator="signsgd_mv", wire_bits=2)
    with pytest.raises(ValueError, match="top-k"):
        FLConfig(wire_bits=2, topk_frac=0.1)


def test_flconfig_rejects_bad_client_bits():
    with pytest.raises(ValueError, match="client_bits"):
        FLConfig(n_clients=4, client_bits=(1, 2))  # wrong length
    with pytest.raises(ValueError, match="client_bits"):
        FLConfig(n_clients=4, client_bits=(1, 2, 3, 4))  # bad entry
    with pytest.raises(ValueError, match="kernel"):
        FLConfig(n_clients=4, client_bits=(1, 2, 2, 4), use_kernels=True)
    with pytest.raises(ValueError, match="stream"):
        FLConfig(n_clients=4, client_bits=(1, 2, 2, 4), client_chunk=2)
    with pytest.raises(ValueError, match="async"):
        FLConfig(n_clients=4, client_bits=(1, 2, 2, 4), async_buffer=2)
    # valid config threads through to the pipeline
    cfg = FLConfig(n_clients=4, client_bits=[1, 2, 2, 4])
    assert cfg.client_bits == (1, 2, 2, 4)
    assert cfg.pipeline().compressor.client_bits == (1, 2, 2, 4)


def test_flconfig_wire_bits_round_smoke():
    """A tiny end-to-end k=2 FL round through the runtime config path."""
    from repro.data import make_classification, partition_label_skew
    from repro.fl import rounds as R
    from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
    import functools

    (xtr, ytr), (xte, yte) = make_classification(0, n_train=200, n_test=50)
    parts = partition_label_skew(ytr, 4, 2, 30, seed=1)
    cfg = FLConfig(
        n_clients=4, rounds=1, local_epochs=1, wire_bits=2, batch_size=10
    )
    ctx = R.make_context(
        cfg,
        init_mlp(jax.random.PRNGKey(0), hidden=4),
        functools.partial(xent_loss, mlp_logits),
        functools.partial(accuracy, mlp_logits),
        np.stack([xtr[i] for i in parts]),
        np.stack([ytr[i] for i in parts]),
        {"x": xte, "y": yte},
    )
    state = R.init_run_state(ctx)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    state, m = R.round_fn(ctx)(
        ctx, R.cell_params(cfg), k2, state, R.round_batches(ctx, k1)
    )
    assert np.isfinite(float(m["loss"]))
    assert bool(jnp.all(jnp.isfinite(state.w_global)))
