"""End-to-end behaviour tests for the PRoBit+ system.

The scenario mirrors the paper's deployment story: heterogeneous clients,
some Byzantine, one-bit uplink, a DP requirement — and the global model
must still learn.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss


@pytest.fixture(scope="module")
def system():
    (xtr, ytr), (xte, yte) = make_classification(7, n_train=2500, n_test=500)
    m = 10
    parts = partition_label_skew(ytr, m, 2, 80, seed=3)
    return {
        "cx": np.stack([xtr[i] for i in parts]),
        "cy": np.stack([ytr[i] for i in parts]),
        "test": {"x": xte, "y": yte},
        "m": m,
        "p0": init_mlp(jax.random.PRNGKey(1), hidden=32),
        "loss": functools.partial(xent_loss, mlp_logits),
        "acc": functools.partial(accuracy, mlp_logits),
    }


def test_full_stack_one_bit_dp_byzantine(system):
    """The headline scenario: 20% Byzantine + (0.1, 0)-DP + 1-bit uplink.

    The system must (a) run end to end, (b) produce a finite global model,
    (c) clearly beat the FedAvg-under-attack baseline.
    """
    common = dict(
        n_clients=system["m"], rounds=50, local_epochs=2,
        byz_frac=0.2, attack="gaussian",
    )
    probit = FLSimulation(
        FLConfig(aggregator="probit_plus", dp_epsilon=0.1, b_mode="fixed", **common),
        system["p0"], system["loss"], system["acc"],
        system["cx"], system["cy"], system["test"],
    )
    probit.run(eval_every=50)
    fedavg = FLSimulation(
        FLConfig(aggregator="fedavg", **common),
        system["p0"], system["loss"], system["acc"],
        system["cx"], system["cy"], system["test"],
    )
    fedavg.run(eval_every=50)

    assert np.isfinite(probit.history[-1]["loss"])
    assert probit.history[-1]["acc"] > fedavg.history[-1]["acc"] + 0.05


def test_uplink_is_one_bit_per_param(system):
    """The wire format really is 1 bit/param: pack the codes and compare
    against the fp32 payload."""
    from repro.core import stochastic_binarize, pack_bits
    from jax.flatten_util import ravel_pytree

    flat, _ = ravel_pytree(system["p0"])
    d = flat.shape[0]
    codes = stochastic_binarize(jax.random.PRNGKey(0), flat * 0.001, jnp.full((d,), 0.01))
    packed = pack_bits(codes)
    assert packed.size == (d + 7) // 8
    fp32_bytes = d * 4
    assert fp32_bytes / packed.size >= 31.9  # the paper's 32x claim


def test_history_metrics_complete(system):
    sim = FLSimulation(
        FLConfig(n_clients=system["m"], rounds=4, local_epochs=1),
        system["p0"], system["loss"], system["acc"],
        system["cx"], system["cy"], system["test"],
    )
    sim.run(eval_every=2)
    assert {"round", "acc", "loss", "b"} <= set(sim.history[0])
