"""Statistical verification of the paper's headline claims (slow suite).

Runs whole scenario grids through the campaign engine (``repro.sim``) at
pinned seeds and checks the *statistics* the paper proves, not just
qualitative behavior:

* **O(1/M) aggregation error** (abstract / Theorem 1): the MSE of
  theta_hat against the true mean of the uploaded updates decays with the
  number of uploading clients at a log-log slope ~ -1, with and without
  the DP margin.
* **Byzantine graceful degradation** (Theorem 2 / Figs. 5-8): under the
  worst-case ``bit_flip`` wire adversary at up to 40% malicious clients,
  PRoBit+ training accuracy stays close to the clean run.
* **Straggler-adversary robustness** (beyond paper; the synchronous
  analysis cannot express timing attacks): the buffered-async round under
  the ``straggler+sign_flip`` composite adversary degrades gracefully in
  byz_frac, and the staleness discount does not amplify withheld votes.

Everything is deterministic at the pinned seeds. The campaign JSON
artifacts are written to ``reports/`` — the CI ``slow`` job uploads them.

Run with: ``PYTHONPATH=src python -m pytest -m slow tests/test_statistical.py``
"""

import functools

import jax
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss
from repro.sim import CampaignSpec, Task, run_campaign

pytestmark = pytest.mark.slow

M_GRID = (8, 16, 32, 64)
SEEDS = (0, 1, 2)
SLOPE_WINDOW = (-1.35, -0.65)


@pytest.fixture(scope="module")
def task_fn():
    """Task provider keyed on the cell's n_clients (data cached per M)."""
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=4000, n_test=400)
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=16)
    cache = {}

    def fn(cfg):
        m = cfg.n_clients
        if m not in cache:
            parts = partition_label_skew(ytr, m, 2, 50, seed=1)
            cache[m] = Task(
                init_params=p0,
                loss_fn=functools.partial(xent_loss, mlp_logits),
                acc_fn=functools.partial(accuracy, mlp_logits),
                client_x=np.stack([xtr[i] for i in parts]),
                client_y=np.stack([ytr[i] for i in parts]),
                test={"x": xte, "y": yte},
            )
        return cache[m]

    return fn


@pytest.mark.parametrize("dp_epsilon", [0.0, 0.1], ids=["no_dp", "dp_eps0.1"])
def test_theta_mse_decays_as_one_over_m(task_fn, dp_epsilon):
    """Abstract claim: transmission/privacy error vanishes at O(1/M).

    ``theta_mse`` is the per-round MSE of the Eq.-13 estimate against the
    true mean of the uploaded updates — pure aggregation error. With b
    fixed generously above the update range (no clipping, so the
    compressor stays unbiased), Theorem 1 gives variance ~ b^2 / M per
    coordinate; the measured log-log slope across M in {8,...,64} must
    sit in a window around -1.
    """
    spec = CampaignSpec.from_grid(
        dict(
            rounds=8,
            local_epochs=1,
            b_mode="fixed",
            b_init=0.1,
            dp_epsilon=dp_epsilon,
        ),
        {"n_clients": M_GRID},
        seeds=SEEDS,
    )
    result = run_campaign(spec, task_fn)
    result.save(f"reports/statistical_one_over_m_eps{dp_epsilon}.json")
    mses = [
        result.cell(f"n_clients={m}").mean_over_rounds("theta_mse") for m in M_GRID
    ]
    slope = float(np.polyfit(np.log(M_GRID), np.log(mses), 1)[0])
    lo, hi = SLOPE_WINDOW
    assert lo <= slope <= hi, (slope, mses)
    # every doubling of M must strictly reduce the error
    assert all(a > b for a, b in zip(mses, mses[1:])), mses


def test_probit_graceful_under_bit_flip_campaign(task_fn):
    """Theorem-2 consequence at the FL level: PRoBit+ keeps training under
    the worst-case bit adversary; accuracy at 40% flipped clients stays
    within a small margin of the clean run (paper Figs. 5-8 behaviour)."""
    spec = CampaignSpec.from_grid(
        dict(n_clients=16, rounds=30, local_epochs=2, attack="bit_flip"),
        {"byz_frac": [0.0, 0.2, 0.4]},
        seeds=(0, 1),
    )
    result = run_campaign(spec, task_fn)
    result.save("reports/statistical_bit_flip.json")
    acc = {
        f: result.cell(f"byz_frac={f}").metrics["acc"][:, -5:].mean()
        for f in (0.0, 0.2, 0.4)
    }
    assert acc[0.2] >= acc[0.0] - 0.1, acc
    assert acc[0.4] >= acc[0.0] - 0.12, acc


def test_straggler_campaign_grid(task_fn):
    """Nightly straggler sweep: buffered-async PRoBit+ under the
    ``straggler+sign_flip`` timing adversary across byz_frac x
    staleness_decay (decay and the timing gate are traced axes, so the
    engine compiles one program per byz_frac, each vmapped over the
    decay x seed batch). Asserts graceful degradation below the Theorem-2
    breakdown point — the clean-async and attacked-async runs stay within
    a training-accuracy margin — and writes the campaign JSON artifact
    the CI ``slow`` job uploads next to the statistical-suite ones."""
    m = 16
    spec = CampaignSpec.from_grid(
        dict(
            n_clients=m,
            rounds=30,
            local_epochs=2,
            attack="straggler+sign_flip",
            async_buffer=m,
            async_latency=1.0,
        ),
        {"byz_frac": [0.0, 0.125, 0.25], "staleness_decay": [0.0, 0.5]},
        seeds=(0, 1),
    )
    result = run_campaign(spec, task_fn)
    result.save("reports/statistical_async_straggler.json")
    acc = {
        (f, d): result.cell(
            f"byz_frac={f}|staleness_decay={d}"
        ).metrics["acc"][:, -5:].mean()
        for f in (0.0, 0.125, 0.25)
        for d in (0.0, 0.5)
    }
    for d in (0.0, 0.5):
        assert acc[(0.125, d)] >= acc[(0.0, d)] - 0.1, acc
        assert acc[(0.25, d)] >= acc[(0.0, d)] - 0.15, acc
    # every cell keeps a filled buffer and finite staleness
    for f in (0.0, 0.125, 0.25):
        for d in (0.0, 0.5):
            cell = result.cell(f"byz_frac={f}|staleness_decay={d}")
            assert np.all(cell.metrics["buf_fill"][:, -1] > 0.5)
            assert np.all(np.isfinite(cell.metrics["mean_age"]))
