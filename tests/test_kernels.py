"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ops import _pad_to_rows
from repro.kernels.stoch_quant import stoch_quant_pack_2d
from repro.kernels.bit_aggregate import bit_aggregate_2d

SHAPES = [1024, 2048, 8192, 1000, 4097, 65536]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_stoch_quant_pack_matches_ref(n, dtype):
    key = jax.random.PRNGKey(n)
    delta = (0.01 * jax.random.normal(key, (n,))).astype(dtype)
    b = jnp.full((n,), 0.05, dtype)
    d2 = _pad_to_rows(delta, 0.0)
    b2 = _pad_to_rows(b, 0.0)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    got = stoch_quant_pack_2d(d2, b2, u2, interpret=True).reshape(-1)
    want = ref.stoch_quant_pack_ref(d2.reshape(-1), b2.reshape(-1), u2.reshape(-1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_rows", [1, 4, 8])
def test_stoch_quant_block_shape_invariance(block_rows):
    """Output must not depend on the BlockSpec tiling."""
    key = jax.random.PRNGKey(0)
    d2 = _pad_to_rows(0.01 * jax.random.normal(key, (8192,)), 0.0)
    b2 = jnp.full_like(d2, 0.05)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    base = stoch_quant_pack_2d(d2, b2, u2, block_rows=8, interpret=True)
    other = stoch_quant_pack_2d(d2, b2, u2, block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(other))


@pytest.mark.parametrize("m", [1, 3, 16, 64])
@pytest.mark.parametrize("n", [1024, 4096, 5000])
def test_bit_aggregate_matches_ref(m, n):
    key = jax.random.PRNGKey(m * 7 + n)
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.04)
    packed = jnp.stack(
        [ops.stoch_quant_pack(jax.random.fold_in(key, i), delta, b) for i in range(m)]
    )
    got = ops.bit_aggregate(packed, b, n)
    b_pad = _pad_to_rows(b, 0.0).reshape(-1)
    want = ref.bit_aggregate_ref(packed, b_pad)[:n]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_bit_aggregate_equals_core_ml_estimate():
    """Kernel pipeline == reference core pipeline end to end."""
    from repro.core import stochastic_binarize, probit_plus_aggregate

    key = jax.random.PRNGKey(5)
    n, m = 3000, 8
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.03)
    keys = jax.random.split(key, m)
    # the kernel and core paths consume randomness differently, so compare
    # statistically: mean over many reps
    reps = 200
    kk = jax.random.split(jax.random.fold_in(key, 1), reps)

    def kernel_est(k):
        ks = jax.random.split(k, m)
        packed = jnp.stack([ops.stoch_quant_pack(ki, delta, b) for ki in ks])
        return ops.bit_aggregate(packed, b, n)

    est = jnp.mean(jax.vmap(kernel_est)(kk[:50]), axis=0)
    se = float(b[0]) / np.sqrt(m * 50)
    assert float(jnp.max(jnp.abs(est - delta))) < 6 * se


@pytest.mark.parametrize("n", [1024, 4096, 3333])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_prox_sgd_matches_ref(n, dtype):
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (n,), dtype)
    w0 = w * 0.9
    g = jax.random.normal(ks[1], (n,), dtype)
    m = 0.1 * jax.random.normal(ks[2], (n,), dtype)
    got_w, got_m = ops.prox_sgd(w, w0, g, m, 0.01, 0.2, 0.5)
    want_w, want_m = ref.prox_sgd_ref(w, w0, g, m, 0.01, 0.2, 0.5)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m), rtol=2e-5, atol=1e-7)
