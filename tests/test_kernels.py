"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus the dispatch policy itself.

Interpret-mode Pallas appears here *only* — it validates the kernel
lowering on CPU and is never auto-selected (see
``test_dispatch_policy``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ops import _pad_to_rows
from repro.kernels.stoch_quant import stoch_quant_ef_2d, stoch_quant_pack_2d
from repro.kernels.bit_aggregate import bit_aggregate_2d

SHAPES = [1024, 2048, 8192, 1000, 4097, 65536]
DTYPES = [jnp.float32, jnp.bfloat16]


# ---------------------------------------------------------------------------
# Dispatch policy
# ---------------------------------------------------------------------------

def test_dispatch_policy():
    """CPU (and anything non-TPU) resolves to the ref engine; interpret is
    never auto-selected but stays reachable explicitly."""
    assert ops.resolve_engine(backend="cpu") == "ref"
    assert ops.resolve_engine(backend="gpu") == "ref"
    assert ops.resolve_engine(backend="tpu") == "pallas"
    assert ops.resolve_engine() in ("ref", "pallas")
    assert ops.resolve_engine() != "interpret"
    for explicit in ops.ENGINES:
        assert ops.resolve_engine(explicit, backend="cpu") == explicit
    with pytest.raises(ValueError):
        ops.resolve_engine("jitted")


def test_interpret_kwarg_is_explicit_interpret():
    """Back-compat: interpret=True selects the interpret engine; passing
    both engine= and interpret= is an error."""
    assert ops._engine_arg(None, True) == "interpret"
    assert ops._engine_arg(None, False) == "pallas"
    assert ops._engine_arg("ref", None) == "ref"
    with pytest.raises(ValueError):
        ops._engine_arg("ref", True)


# ---------------------------------------------------------------------------
# stoch_quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_stoch_quant_pack_matches_ref(n, dtype):
    key = jax.random.PRNGKey(n)
    delta = (0.01 * jax.random.normal(key, (n,))).astype(dtype)
    b = jnp.full((n,), 0.05, dtype)
    d2 = _pad_to_rows(delta, 0.0)
    b2 = _pad_to_rows(b, 0.0)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    got = stoch_quant_pack_2d(d2, b2, u2, interpret=True).reshape(-1)
    want = ref.stoch_quant_pack_ref(d2.reshape(-1), b2.reshape(-1), u2.reshape(-1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_rows", [1, 4, 8])
def test_stoch_quant_block_shape_invariance(block_rows):
    """Output must not depend on the BlockSpec tiling."""
    key = jax.random.PRNGKey(0)
    d2 = _pad_to_rows(0.01 * jax.random.normal(key, (8192,)), 0.0)
    b2 = jnp.full_like(d2, 0.05)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    base = stoch_quant_pack_2d(d2, b2, u2, block_rows=8, interpret=True)
    other = stoch_quant_pack_2d(d2, b2, u2, block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(other))


@pytest.mark.parametrize("n", [1024, 8192, 4097])
def test_stoch_quant_ef_matches_ref(n):
    """Fused EF kernel (eff-add + binarize + pack + residual) vs oracle."""
    key = jax.random.PRNGKey(n + 1)
    delta = 0.01 * jax.random.normal(key, (n,))
    res = 0.001 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b = jnp.full((n,), 0.05)
    d2 = _pad_to_rows(delta, 0.0)
    r2 = _pad_to_rows(res, 0.0)
    b2 = _pad_to_rows(b, 1.0)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    got_p, got_r = stoch_quant_ef_2d(d2, r2, b2, u2, interpret=True)
    want_p, want_r = ref.stoch_quant_compress_ref(
        d2.reshape(-1), b2.reshape(-1), u2.reshape(-1), r2.reshape(-1),
        want_residual=True,
    )
    np.testing.assert_array_equal(np.asarray(got_p.reshape(-1)), np.asarray(want_p))
    np.testing.assert_array_equal(np.asarray(got_r.reshape(-1)), np.asarray(want_r))


@pytest.mark.parametrize("n", [1000, 4097, 8192])
@pytest.mark.parametrize("want_residual", [False, True])
def test_stoch_quant_compress_engines_agree(n, want_residual):
    """Explicit interpret-mode Pallas == ref engine, bit for bit, for the
    counter-derived-uniforms compress (with and without EF)."""
    key = jax.random.fold_in(jax.random.PRNGKey(9), n)
    delta = 0.01 * jax.random.normal(key, (n,))
    res = 0.001 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b = jnp.float32(0.05)
    p_ref, r_ref = ops.stoch_quant_compress(
        key, delta, b, res, want_residual=want_residual, engine="ref"
    )
    p_itp, r_itp = ops.stoch_quant_compress(
        key, delta, b, res, want_residual=want_residual, engine="interpret"
    )
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_itp))
    if want_residual:
        np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_itp))
    else:
        assert r_ref is None and r_itp is None


def test_quant_pack_u_matches_pack_bits():
    """Explicit-uniforms pack (the top-k path) reproduces the pure
    ``pack_bits``-of-codes bytes exactly on both engines."""
    from repro.core.quantizer import binarize_prob, pack_bits

    k = 123
    key = jax.random.PRNGKey(3)
    d_sel = 0.02 * jax.random.normal(key, (k,))
    b_sel = jnp.abs(0.05 * jax.random.normal(jax.random.fold_in(key, 1), (k,)))
    u = jax.random.uniform(jax.random.fold_in(key, 2), (k,), dtype=jnp.float32)
    codes = jnp.where(u < binarize_prob(d_sel, b_sel), jnp.int8(1), jnp.int8(-1))
    want = pack_bits(codes)
    nbytes = (k + 7) // 8
    for engine in ("ref", "interpret"):
        got = ops.quant_pack_u(d_sel, b_sel, u, engine=engine)
        np.testing.assert_array_equal(np.asarray(got[:nbytes]), np.asarray(want))
        assert not np.any(np.asarray(got[nbytes:]))


# ---------------------------------------------------------------------------
# bit_aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 3, 16, 64])
@pytest.mark.parametrize("n", [1024, 4096, 5000])
def test_bit_aggregate_matches_ref(m, n):
    key = jax.random.PRNGKey(m * 7 + n)
    delta = 0.01 * jax.random.normal(key, (n,))
    b = jnp.full((n,), 0.04)
    packed = jnp.stack(
        [ops.stoch_quant_pack(jax.random.fold_in(key, i), delta, b) for i in range(m)]
    )
    got = ops.bit_aggregate(packed, b, n, engine="interpret")
    b_pad = _pad_to_rows(b, 0.0).reshape(-1)
    want = ref.bit_aggregate_ref(packed, b_pad)[:n]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m_block", [8, 16, 256])
def test_bit_aggregate_m_block_invariance(m_block):
    """The client-axis grid accumulation must not depend on the tile size
    (zero-padded rows add zero votes; f32 partial sums are exact)."""
    m, c = 37, 256
    packed = jax.random.randint(
        jax.random.PRNGKey(1), (m, c), 0, 256, dtype=jnp.int32
    ).astype(jnp.uint8)
    b2d = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (c // 128, 1024)))
    base = bit_aggregate_2d(packed, b2d, m_block=256, interpret=True)
    other = bit_aggregate_2d(packed, b2d, m_block=m_block, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(other))


@pytest.mark.parametrize("engine", ["ref", "interpret"])
def test_bit_aggregate_counts_match_packed_counts(engine):
    """The in-kernel popcount vote count is bit-exact with the production
    ``packed_counts`` reduction: feeding b=1 makes bit_aggregate return
    (2N - M)/M, from which N is recovered exactly."""
    from repro.core.quantizer import packed_counts

    m, n = 21, 2048
    packed = jax.random.randint(
        jax.random.PRNGKey(7), (m, n // 8), 0, 256, dtype=jnp.int32
    ).astype(jnp.uint8)
    ones = jnp.ones((n,), jnp.float32)
    theta = ops.bit_aggregate(packed, ones, n, engine=engine)
    counts = np.round((np.asarray(theta, np.float64) * m + m) / 2.0)
    want = np.asarray(packed_counts(packed)[:n])
    np.testing.assert_array_equal(counts, want.astype(np.float64))


def test_bit_aggregate_equals_core_ml_estimate():
    """Kernel wire + kernel aggregate == core chunked wire + Eq.-13
    estimate, exactly — the engines share the counter-derived uniform
    schedule and the popcount reduction end to end."""
    from repro.core import ml_estimate_from_counts
    from repro.core.quantizer import packed_binarize_batch, packed_counts

    key = jax.random.PRNGKey(5)
    n, m = 3000, 8
    deltas = 0.01 * jax.random.normal(key, (m, n))
    b = jnp.full((n,), 0.03)
    packed_core, _ = packed_binarize_batch(key, deltas, b)
    want = ml_estimate_from_counts(packed_counts(packed_core)[:n], m, b)
    client_keys = [jax.random.fold_in(key, i) for i in range(m)]
    packed_k = jnp.stack(
        [ops.stoch_quant_pack(ck, deltas[i], b) for i, ck in enumerate(client_keys)]
    )
    got = ops.bit_aggregate(packed_k, b, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("engine", ["ref", "interpret"])
def test_bit_aggregate_padded_tail_never_leaks(engine):
    """n % 1024 != 0 and M % 8 != 0: adversarial all-ones pad lanes (both
    the in-byte tail bits and the whole pad bytes) must not perturb
    estimate[:n] on any engine."""
    n, m = 997, 5  # n % 8 != 0 -> the last in-range byte has 3 pad bits
    pbytes = ops.padded_len(n) // 8
    key = jax.random.PRNGKey(11)
    packed = jax.random.randint(
        key, (m, pbytes), 0, 256, dtype=jnp.int32
    ).astype(jnp.uint8)
    b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
    base = ops.bit_aggregate(packed, b, n, engine=engine)

    # poison every pad position with 1-bits: whole bytes beyond ceil(n/8)
    # and the high bits of the straddling byte
    poisoned = np.asarray(packed).copy()
    full = n // 8  # bytes fully in range
    in_byte_pad = 8 * (full + 1) - n  # pad bits inside the straddling byte
    poisoned[:, full] |= (0xFF << (8 - in_byte_pad)) & 0xFF
    poisoned[:, full + 1:] = 0xFF
    got = ops.bit_aggregate(jnp.asarray(poisoned), b, n, engine=engine)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


# ---------------------------------------------------------------------------
# prox_sgd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1024, 4096, 3333])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_prox_sgd_matches_ref(n, dtype):
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (n,), dtype)
    w0 = w * 0.9
    g = jax.random.normal(ks[1], (n,), dtype)
    m = 0.1 * jax.random.normal(ks[2], (n,), dtype)
    got_w, got_m = ops.prox_sgd(w, w0, g, m, 0.01, 0.2, 0.5, engine="interpret")
    want_w, want_m = ref.prox_sgd_ref(w, w0, g, m, 0.01, 0.2, 0.5)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m), rtol=2e-5, atol=1e-7)
