"""Adaptive-adversary unit tests: the ALIE breakdown-point quantile.

Regression-pins the ``z`` values the ALIE attack derives from
``(cohort size, Byzantine count)`` per Baruch et al. (2019):
``s = floor(n/2 + 1) - m`` supporters are needed to hide inside the
majority, and ``z = Phi^{-1}((n - m - s)/(n - m))`` — clamped to 0 when
the quantile falls at or below 1/2 (the Byzantine cohort cannot recruit a
majority at any non-negative z).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alie_z, apply_attack, attack_id

# (n, n_byz) -> z, from the closed form above (values pinned to 1e-6).
PINNED_Z = {
    (20, 4): 0.157311,
    (24, 5): 0.199201,
    (50, 12): 0.336038,
    (100, 20): 0.285841,
    (100, 45): 1.231377,
    (10, 3): 0.180012,
}


@pytest.mark.parametrize("nm,expected", sorted(PINNED_Z.items()))
def test_alie_z_pinned_quantiles(nm, expected):
    n, m = nm
    assert alie_z(n, m) == pytest.approx(expected, abs=1e-6)


@pytest.mark.parametrize(
    "n,m",
    [(10, 0), (10, 1), (6, 2), (4, 2), (5, 5), (3, 4)],
)
def test_alie_z_degenerate_cases_clamp_to_zero(n, m):
    """No Byzantines, sub-breakdown fractions (quantile <= 1/2), and
    honest-free cohorts all degrade to z = 0 (upload the honest mean)."""
    assert alie_z(n, m) == 0.0


def test_alie_z_monotone_in_byzantine_fraction():
    """More colluders -> more supporters available -> larger z."""
    zs = [alie_z(100, m) for m in (20, 30, 40, 45, 49)]
    assert all(b >= a for a, b in zip(zs, zs[1:]))
    assert zs[-1] > 1.0  # near-half collusion hides > 1 std away


def test_alie_attack_uses_breakdown_z():
    """The delta-stage attack writes mean - z*std with the derived z."""
    n, n_byz = 20, 4
    key = jax.random.PRNGKey(0)
    updates = jax.random.normal(key, (n, 7))
    out = apply_attack(
        jnp.asarray(attack_id("alie")), key, updates, n_byz
    )
    honest = np.asarray(updates)[n_byz:]
    expected = honest.mean(0) - alie_z(n, n_byz) * honest.std(0)
    np.testing.assert_allclose(
        np.asarray(out)[:n_byz], np.tile(expected, (n_byz, 1)), rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(out)[n_byz:], honest)
