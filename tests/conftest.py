import os
import sys

# Smoke tests and benches must see the REAL device count (1 CPU device) —
# the 512-device XLA flag is set ONLY inside repro.launch.dryrun.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
