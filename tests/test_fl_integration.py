"""Integration tests: the full FL protocol on a small synthetic task.

These validate the paper's *qualitative* claims end-to-end:
  - PRoBit+ tracks FedAvg closely in a Byzantine-free system;
  - FedAvg collapses under a single large-magnitude Byzantine, PRoBit+
    survives;
  - the dynamic-b controller moves b upward while training progresses.
"""

import functools

import jax
import numpy as np
import pytest

from repro.data import make_classification, partition_label_skew
from repro.fl import FLConfig, FLSimulation
from repro.models.vision import accuracy, init_mlp, mlp_logits, xent_loss


@pytest.fixture(scope="module")
def task():
    (xtr, ytr), (xte, yte) = make_classification(0, n_train=3000, n_test=600)
    m = 10
    parts = partition_label_skew(ytr, m, 2, 80, seed=1)
    cx = np.stack([xtr[i] for i in parts])
    cy = np.stack([ytr[i] for i in parts])
    p0 = init_mlp(jax.random.PRNGKey(0), hidden=32)
    return {
        "m": m,
        "cx": cx,
        "cy": cy,
        "test": {"x": xte, "y": yte},
        "p0": p0,
        "loss": functools.partial(xent_loss, mlp_logits),
        "acc": functools.partial(accuracy, mlp_logits),
    }


def _run(task, rounds=60, **kw):
    cfg = FLConfig(n_clients=task["m"], rounds=rounds, local_epochs=2, **kw)
    sim = FLSimulation(
        cfg, task["p0"], task["loss"], task["acc"], task["cx"], task["cy"], task["test"]
    )
    sim.run(eval_every=rounds)
    return sim


def test_probit_tracks_fedavg(task):
    """PRoBit+ tracks FedAvg closely without Byzantines (paper Fig. 5).

    Thresholds calibrated over seeds 0-19 (campaign engine, this exact
    task/config — the campaign reproduces FLSimulation bit for bit):
    FedAvg final acc 0.2515 +/- 0.0031 (min 0.2467), PRoBit+ - FedAvg
    gap -0.0487 +/- 0.0033 (min -0.0567). Bounds sit ~8 sigma outside the
    observed range, so the pinned seed 0 (FedAvg 0.2533, gap -0.0567)
    passes deterministically with headroom against numeric-environment
    drift (which perturbs a chaotic FL trajectory like a seed redraw).
    """
    fa = _run(task, aggregator="fedavg", seed=0)
    pb = _run(task, aggregator="probit_plus", seed=0)
    acc_fa = fa.history[-1]["acc"]
    acc_pb = pb.history[-1]["acc"]
    assert acc_fa > 0.22, f"FedAvg failed to learn ({acc_fa})"
    assert acc_pb > acc_fa - 0.08, (acc_pb, acc_fa)


def test_byzantine_gaussian_attack(task):
    """30% Gaussian attackers: FedAvg accuracy collapses (sigma=10 noise in
    the mean), PRoBit+ keeps learning (paper Fig. 5/6 behaviour)."""
    fa = _run(task, aggregator="fedavg", byz_frac=0.3, attack="gaussian")
    pb = _run(task, aggregator="probit_plus", byz_frac=0.3, attack="gaussian")
    assert pb.history[-1]["acc"] > fa.history[-1]["acc"] + 0.1, (
        pb.history[-1],
        fa.history[-1],
    )


def test_dynamic_b_rises_during_progress(task):
    pb = _run(task, aggregator="probit_plus", b_mode="dynamic", rounds=30)
    assert pb.history[-1]["b"] > 0.01  # grew from init while loss fell


def test_dp_variant_still_learns(task):
    """DP-PRoBit+ at eps=0.1 learns about as well as the non-DP variant.

    Calibrated over seeds 0-19 (campaign engine, this exact config):
    final acc 0.2047 +/- 0.0037 (min 0.1967) — statistically
    indistinguishable from non-DP PRoBit+ (0.2028 +/- 0.0025), i.e. the
    DP margin costs nothing at this scale, matching the paper's Fig. 4
    story. The 0.17 bound is ~7 sigma below the observed minimum; seed 0
    lands at 0.2000 and passes deterministically.
    """
    pb = _run(task, aggregator="probit_plus", dp_epsilon=0.1, rounds=60, seed=0)
    assert pb.history[-1]["acc"] > 0.17, pb.history[-1]


def test_fixed_b_underperforms_dynamic(task):
    """Paper Fig. 3: dynamic b >= fixed b (allow small MC slack)."""
    dyn = _run(task, aggregator="probit_plus", b_mode="dynamic", rounds=80)
    fix = _run(task, aggregator="probit_plus", b_mode="fixed", rounds=80)
    assert dyn.history[-1]["acc"] >= fix.history[-1]["acc"] - 0.08


def test_kernel_path_matches_reference_path(task):
    """use_kernels=True (Pallas interpret prox-SGD) must land at a similar
    point as the pure-jnp path (bit-exactness not required: fused fma
    ordering differs)."""
    a = _run(task, aggregator="probit_plus", use_kernels=False, rounds=20)
    b = _run(task, aggregator="probit_plus", use_kernels=True, rounds=20)
    assert abs(a.history[-1]["acc"] - b.history[-1]["acc"]) < 0.15
