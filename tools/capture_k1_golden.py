"""Capture the pre-refactor k=1 golden wire vectors (PR-9 regression pin).

Run ONCE at the pre-refactor HEAD; the emitted ``tests/data/k1_golden.npz``
pins the one-bit wire byte-for-byte. ``tests/test_kbit.py`` recomputes the
same four paths (dense, chunked-streaming, kernel-ref, pytree) after the
k-bit refactor and asserts packed bytes / counts exactly and theta / EF
residuals to the jit-reassociation tolerance — so ``wire_bits=1`` can
never drift from the paper's wire.

  PYTHONPATH=src python tools/capture_k1_golden.py
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import build_pipeline
from repro.core.quantizer import packed_counts
from repro.fl.pytree_wire import (
    aggregate_pytree,
    compress_pytree,
    init_wire_state,
    stream_aggregate_pytree,
)

M, D, CHUNK, CLIENT_CHUNK = 12, 50, 64, 4
B_SCALAR = 0.4
SEED = 7


def client_deltas(m, d):
    k = jax.random.PRNGKey(1234)
    return 0.1 * jax.random.normal(k, (m, d), jnp.float32)


def main() -> None:
    out = {}
    key = jax.random.PRNGKey(SEED)
    deltas = client_deltas(M, D)
    res0 = jnp.zeros((M, D), jnp.float32)

    # -- dense path (EF on) ------------------------------------------------
    pipe = build_pipeline("probit_plus", error_feedback=True, chunk=CHUNK)
    wire, res = pipe.compress_wire(key, deltas, B_SCALAR, res0)
    out["dense_packed"] = np.asarray(wire.packed)
    out["dense_counts"] = np.asarray(packed_counts(wire.packed))
    out["dense_theta"] = np.asarray(pipe.estimate(wire))
    out["dense_residuals"] = np.asarray(res)
    out["dense_b"] = np.asarray(wire.b)

    # -- chunked-streaming path (count protocol, row_offset rebasing) ------
    comp, server = pipe.compressor, pipe.server
    p_bytes = comp.wire_bytes(D)
    b_vec = comp.b_vector(D, B_SCALAR)
    counts = server.init_counts(p_bytes)
    res_stream = np.zeros((M, D), np.float32)
    for g0 in range(0, M, CLIENT_CHUNK):
        w_ch, r_ch = comp.compress(
            key,
            deltas[g0 : g0 + CLIENT_CHUNK],
            B_SCALAR,
            res0[g0 : g0 + CLIENT_CHUNK],
            row_offset=g0,
        )
        counts = server.accumulate_counts(counts, w_ch.packed)
        res_stream[g0 : g0 + CLIENT_CHUNK] = np.asarray(r_ch)
    out["stream_counts"] = np.asarray(counts)
    out["stream_theta"] = np.asarray(server.finalize(counts, M, b_vec))
    out["stream_residuals"] = res_stream

    # -- kernel-ref path (use_kernels=True routes to the ref engine on CPU)
    kpipe = build_pipeline("probit_plus", use_kernels=True, chunk=CHUNK)
    kwire, _ = kpipe.compress_wire(key, deltas, B_SCALAR, res0)
    out["kernel_packed"] = np.asarray(kwire.packed)
    out["kernel_theta"] = np.asarray(kpipe.estimate(kwire))

    # -- pytree path (two leaves, one with size % 8 != 0) ------------------
    params = {
        "w": jnp.zeros((3, 17), jnp.float32),
        "b0": jnp.zeros((5,), jnp.float32),
    }
    tkey = jax.random.PRNGKey(SEED + 1)
    tree_deltas = {
        "w": 0.1
        * jax.random.normal(jax.random.PRNGKey(55), (M, 3, 17), jnp.float32),
        "b0": 0.1
        * jax.random.normal(jax.random.PRNGKey(56), (M, 5), jnp.float32),
    }
    state = init_wire_state(params, M)
    wires, _ = compress_pytree(pipe, tkey, tree_deltas, B_SCALAR, state)
    for i, w in enumerate(wires):
        out[f"pytree_packed_{i}"] = np.asarray(w.packed)
    theta_tree, st2 = aggregate_pytree(pipe, tkey, tree_deltas, B_SCALAR, state)
    out["pytree_theta_w"] = np.asarray(theta_tree["w"])
    out["pytree_theta_b0"] = np.asarray(theta_tree["b0"])
    out["pytree_res_w"] = np.asarray(st2.residuals["w"])
    theta_s, _ = stream_aggregate_pytree(
        pipe, tkey, tree_deltas, B_SCALAR, state, client_chunk=CLIENT_CHUNK
    )
    out["pytree_stream_theta_w"] = np.asarray(theta_s["w"])
    out["pytree_stream_theta_b0"] = np.asarray(theta_s["b0"])

    path = os.path.join(os.path.dirname(__file__), "..", "tests", "data")
    os.makedirs(path, exist_ok=True)
    dest = os.path.join(path, "k1_golden.npz")
    np.savez_compressed(dest, **out)
    print(f"wrote {dest}:")
    for k, v in sorted(out.items()):
        print(f"  {k}: shape={v.shape} dtype={v.dtype}")


if __name__ == "__main__":
    main()
