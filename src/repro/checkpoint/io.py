"""Simple, dependency-free checkpointing for pytrees.

Arrays are gathered to host (fully addressable on the simulation runtime;
on a real multi-host mesh this becomes a per-host shard dump — the layout
key encodes the flattened tree path so restore is structure-checked).
"""

from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot serialize bf16
            arr = arr.astype(np.float32)  # lossless widening
        out[key] = arr
    return out


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    np.savez_compressed(path, **flat)
    meta = {"step": step, "keys": sorted(flat), **(metadata or {})}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like):
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves = jax.tree_util.tree_leaves_with_path(like)
    restored = []
    for p, leaf in leaves:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        restored.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, restored)
