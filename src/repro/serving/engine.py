"""Batched decode engine over the model zoo's ``serve_step``.

Serves the FL-aggregated global model: fixed-batch continuous decoding
with per-slot request state (prompt feeding → generation → done), greedy
or temperature sampling. One jit-compiled step serves the whole batch;
finished slots are refilled from the queue between steps — the standard
static-batch serving loop, deployable under the production mesh
(``jax.set_mesh``) with the same sharding rules as the dry-run.

Prompt feeding reuses the decode path (one token at a time) so the engine
works identically for attention KV caches, ring-buffer windows, and
SSM/xLSTM recurrent state.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import init_cache, serve_step
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    max_len: int = 256  # cache length
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    window: int = 0  # >0: ring-buffer sliding window
    eos_token: int = -1  # -1: disabled


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig):
        assert not cfg.encoder_only, "encoder-only models have no decode path"
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.cache = init_cache(cfg, serve.batch_size, serve.max_len)

        def step(params, cache, tokens, pos, key):
            logits, cache = serve_step(
                params, cache, {"tokens": tokens}, pos, cfg, serve.window
            )
            if serve.temperature > 0:
                nxt = jax.random.categorical(key, logits / serve.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt.astype(jnp.int32), cache

        self._step = jax.jit(step)

    def generate(
        self, prompts: Iterable[list[int]], seed: int = 0
    ) -> list[list[int]]:
        """Decode a list of prompts (static batch; queue-refill between
        generations). Returns generated token lists (prompt excluded)."""
        prompts = [list(p) for p in prompts]
        s = self.serve
        results: list[list[int]] = [[] for _ in prompts]
        key = jax.random.PRNGKey(seed)
        queue = list(range(len(prompts)))

        while queue:
            wave = queue[: s.batch_size]
            queue = queue[s.batch_size :]
            # left-align this wave into the batch
            self.cache = init_cache(self.cfg, s.batch_size, s.max_len)
            maxp = max(len(prompts[i]) for i in wave)
            gen_mask = np.zeros(s.batch_size, bool)
            gen_mask[: len(wave)] = True
            done = ~gen_mask
            cur = np.zeros((s.batch_size, 1), np.int32)
            for t in range(maxp + s.max_new_tokens - 1):
                for bi, ri in enumerate(wave):
                    p = prompts[ri]
                    if t < len(p):
                        cur[bi, 0] = p[t]
                key, ks = jax.random.split(key)
                nxt, self.cache = self._step(
                    self.params, self.cache, jnp.asarray(cur), jnp.int32(t), ks
                )
                nxt = np.asarray(nxt)
                for bi, ri in enumerate(wave):
                    p = prompts[ri]
                    if t >= len(p) - 1 and not done[bi]:
                        tok = int(nxt[bi])
                        results[ri].append(tok)
                        if (
                            tok == s.eos_token
                            or len(results[ri]) >= s.max_new_tokens
                        ):
                            done[bi] = True
                        else:
                            cur[bi, 0] = tok
                if done[: len(wave)].all():
                    break
        return results
