"""Batched serving of the aggregated global model."""

from .engine import ServeConfig, ServingEngine

__all__ = ["ServeConfig", "ServingEngine"]
