"""PRoBit+ reproduction package.

Sets ``jax_threefry_partitionable`` once, at import, for every consumer:
partitionable threefry makes each random draw a pure function of
``(key, element index)`` — independent of the array's total shape — which
two subsystems rely on:

* the campaign planner's **fused heterogeneous-M groups**
  (:mod:`repro.sim.plan`): the client axis is padded to the group max, and
  a cell's real clients must draw exactly the batches/quantizer bits they
  would in an unpadded program (prefix-stable ``split`` / ``randint`` /
  ``uniform``), so fused and per-group execution agree;
* **device sharding** of campaign batches: random ops lower to
  per-element counter hashes with no cross-device layout dependence.

This is also the default stream in jax >= 0.5, so pinning it keeps seeds
stable across the jax versions the compat shims in ``repro.distributed``
support. (Trajectories differ from the legacy stream; every
seed-calibrated test threshold was re-verified green on the new stream
when this landed — the PR-3 20-seed calibrations held without retuning.)
"""

import jax as _jax

_jax.config.update("jax_threefry_partitionable", True)
