"""Synthetic datasets standing in for FMNIST / CIFAR-10 / LM corpora.

The container has no dataset downloads, so the paper's experiments run on
*statistically equivalent* synthetic tasks: Gaussian class-prototype images
(learnable, with controllable class separation) and per-client skewed token
streams for LM architectures. The FL *protocol* (partitioning, local
epochs, attacks, aggregation) is exactly the paper's.
"""

from __future__ import annotations

import numpy as np


def make_classification(
    seed: int,
    n_classes: int = 10,
    dim: int = 784,
    n_train: int = 10_000,
    n_test: int = 2_000,
    noise: float = 0.6,
):
    """Flat-vector task (MLP). Class prototypes on a sphere + Gaussian noise
    + a shared random nonlinear distractor subspace (so it is not linearly
    trivial)."""
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((n_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    def draw(n):
        y = rng.integers(0, n_classes, n)
        x = protos[y] + noise * rng.standard_normal((n, dim)).astype(np.float32) / np.sqrt(dim) * 8.0
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = draw(n_train)
    xte, yte = draw(n_test)
    return (xtr, ytr), (xte, yte)


def make_image_classification(
    seed: int,
    n_classes: int = 10,
    img: int = 28,
    channels: int = 1,
    n_train: int = 10_000,
    n_test: int = 2_000,
    noise: float = 0.5,
):
    """Image-shaped task (CNN / ResNet): smooth class-prototype images."""
    rng = np.random.default_rng(seed)
    freq = rng.standard_normal((n_classes, 4, 4, channels)).astype(np.float32)
    # upsample 4x4 prototype spectra to full images (smooth structure)
    protos = np.repeat(np.repeat(freq, img // 4, axis=1), img // 4, axis=2)[:, :img, :img]

    def draw(n):
        y = rng.integers(0, n_classes, n)
        x = protos[y] + noise * rng.standard_normal((n, img, img, channels)).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = draw(n_train)
    xte, yte = draw(n_test)
    return (xtr, ytr), (xte, yte)


def make_lm_streams(
    seed: int,
    n_clients: int,
    vocab: int,
    seq_len: int,
    seqs_per_client: int,
    alpha: float = 0.3,
):
    """Per-client token streams from client-specific bigram models whose
    unigram marginals are Dirichlet(alpha)-skewed — the LM analogue of
    label-skew partitioning."""
    rng = np.random.default_rng(seed)
    out = []
    base = rng.dirichlet(np.full(min(vocab, 4096), 10.0))
    for c in range(n_clients):
        skew = rng.dirichlet(np.full(min(vocab, 4096), alpha))
        p = 0.5 * base + 0.5 * skew
        toks = rng.choice(len(p), size=(seqs_per_client, seq_len), p=p)
        out.append(toks.astype(np.int32) % vocab)
    return out
