"""Heterogeneous FL partitioners.

``partition_label_skew`` reproduces the paper's §VI-A protocol: each client
draws samples from at most ``classes_per_client`` labels (2 for FMNIST,
6 for CIFAR-10). ``partition_dirichlet`` is the common Dir(alpha)
alternative used in ablations.

Every client receives exactly ``per_client`` samples (the paper assumes
equal-size local datasets).
"""

from __future__ import annotations

import numpy as np


def partition_label_skew(
    y: np.ndarray,
    n_clients: int,
    classes_per_client: int,
    per_client: int,
    seed: int = 0,
) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    by_class = {c: np.where(y == c)[0] for c in classes}
    out = []
    for _ in range(n_clients):
        cs = rng.choice(classes, size=classes_per_client, replace=False)
        pool = np.concatenate([by_class[c] for c in cs])
        idx = rng.choice(pool, size=per_client, replace=pool.size < per_client)
        out.append(np.sort(idx))
    return out


def partition_dirichlet(
    y: np.ndarray,
    n_clients: int,
    per_client: int,
    alpha: float = 0.3,
    seed: int = 0,
) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    by_class = {c: np.where(y == c)[0] for c in classes}
    out = []
    for _ in range(n_clients):
        p = rng.dirichlet(np.full(len(classes), alpha))
        counts = rng.multinomial(per_client, p)
        idx = np.concatenate(
            [
                rng.choice(by_class[c], size=k, replace=k > by_class[c].size)
                for c, k in zip(classes, counts)
                if k > 0
            ]
        )
        out.append(np.sort(idx))
    return out
