"""Data pipeline: synthetic datasets + heterogeneous FL partitioners."""

from .synthetic import (
    make_classification,
    make_image_classification,
    make_lm_streams,
)
from .partition import partition_label_skew, partition_dirichlet

__all__ = [
    "make_classification",
    "make_image_classification",
    "make_lm_streams",
    "partition_label_skew",
    "partition_dirichlet",
]
