"""SGD + momentum and the prox-regularized local solver (paper Eq. 4).

Clients minimize ``h_m(w; w_g) = f_m(w) + (lam/2) ||w - w_g||^2`` with
momentum SGD (paper: momentum 0.5, lr 0.01, 5 local epochs, batch 10).
``local_prox_train`` works on *flat* parameter vectors so the result feeds
straight into the PRoBit+ quantizer; ``use_kernel=True`` routes the step
through ``repro.kernels.prox_sgd``, whose dispatch policy picks the fused
Pallas kernel on TPU and the arithmetically identical pure-JAX reference
elsewhere (interpret-mode Pallas is test-only).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..kernels import ops as kops


def sgd_momentum_init(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd_momentum_step(params, moms, grads, lr: float, mu: float):
    new_moms = jax.tree.map(lambda m, g: mu * m + g, moms, grads)
    new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_moms)
    return new_params, new_moms


def local_prox_train(
    loss_fn: Callable,
    w0_flat: jax.Array,
    w_init_flat: jax.Array,
    unravel: Callable,
    batches: dict,
    *,
    lr: float,
    mu: float,
    lam: float,
    use_kernel: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run local steps over pre-batched data.

    batches: pytree with leading (n_steps, batch, ...) dims.
    Returns (w_final_flat, loss_first, loss_last) — the two losses feed the
    dynamic-b controller's one-bit training signal.
    """

    def data_loss(w_flat, batch):
        return loss_fn(unravel(w_flat), batch)

    grad_fn = jax.grad(data_loss)

    def step(carry, batch):
        w, m = carry
        g = grad_fn(w, batch)
        if use_kernel:
            w, m = kops.prox_sgd(w, w0_flat, g, m, lr, lam, mu)
        else:
            g = g + lam * (w - w0_flat)
            m = mu * m + g
            w = w - lr * m
        return (w, m), None

    n_steps = jax.tree.leaves(batches)[0].shape[0]
    first = jax.tree.map(lambda a: a[0], batches)
    last = jax.tree.map(lambda a: a[-1], batches)
    loss_before = data_loss(w_init_flat, first)
    (w, _), _ = jax.lax.scan(step, (w_init_flat, jnp.zeros_like(w_init_flat)), batches)
    loss_after = data_loss(w, last)
    return w, loss_before, loss_after
