"""Optimizers (pure JAX, pytree- and flat-vector-based)."""

from .sgd import sgd_momentum_init, sgd_momentum_step, local_prox_train

__all__ = ["sgd_momentum_init", "sgd_momentum_step", "local_prox_train"]
