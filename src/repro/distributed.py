"""Logical-axis sharding policy.

Model code annotates tensors with *logical* axis names; this module maps
them onto whatever mesh is active (``jax.set_mesh``). On a bare CPU (smoke
tests) there is no mesh and every annotation is a no-op, so the exact same
model code runs single-device and on the 512-chip production mesh.

Logical → mesh-axis rules (the baseline layout; §Perf iterates on this):

  batch    → ("pod", "data") if a pod axis exists else ("data",)
  seq      → "model"   (KV-cache sequence sharding for decode / flash-decode)
  heads    → "model"   (query heads, TP)
  kv       → "model"   (KV heads, TP)
  ff       → "model"   (MLP hidden / mamba d_inner, TP)
  vocab    → "model"   (embedding / LM head, TP)
  experts  → "model"   (MoE expert parallelism)
  d / hd / conv / state / None → replicated

An annotation is silently dropped when the tensor dim is not divisible by
the mesh axis size (e.g. 24 query heads on a 16-way model axis) — the
tensor is replicated on that axis instead. This "best divisible effort"
rule is what lets one config system drive 10 heterogeneous architectures.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Which mesh axes carry the (token) batch. FL training multiplexes clients
# over "pod", so batch spans only "data" there; serving spans both.
_BATCH_AXES: contextvars.ContextVar[tuple[str, ...]] = contextvars.ContextVar(
    "repro_batch_axes", default=("data",)
)


def batch_axes() -> tuple[str, ...]:
    return _BATCH_AXES.get()


# Per-context overrides of the logical->mesh rules. Used by the 2D
# weight-stationary serving layout (§Perf hillclimb B): decode re-gathers
# FSDP-sharded weights for every token, so serving instead keeps weights
# sharded over BOTH ("model", "data") and psums the (tiny) activations.
_RULE_OVERRIDES: contextvars.ContextVar[dict] = contextvars.ContextVar(
    "repro_rule_overrides", default={}
)


@contextlib.contextmanager
def use_rules(**overrides: tuple[str, ...]):
    tok = _RULE_OVERRIDES.set(dict(overrides))
    try:
        yield
    finally:
        _RULE_OVERRIDES.reset(tok)


@contextlib.contextmanager
def use_batch_axes(*axes: str):
    tok = _BATCH_AXES.set(tuple(axes))
    try:
        yield
    finally:
        _BATCH_AXES.reset(tok)

# logical name -> candidate mesh axes (first whose size divides the dim wins
# entirely; mesh axes are not split across logical dims)
_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("data",),
    "batch_pod": ("pod", "data"),  # batch big enough for both axes
    "clients": ("pod",),
    "seq": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
}


def set_mesh(mesh):
    """Compat context: ``jax.set_mesh`` on new JAX; on jax<=0.4 the Mesh
    object is itself the (thread-resources) context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def current_mesh():
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:  # new global-mesh API
        am = get_am()
        return None if am.empty else am
    from jax._src.mesh import thread_resources  # jax<=0.4 fallback

    pm = thread_resources.env.physical_mesh
    return None if pm.empty else pm


def _axis_entry(mesh, name: str | None, dim: int, used: set[str] | None = None):
    if name is None or name not in _RULES:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    over = _RULE_OVERRIDES.get()
    if name in over:
        cand: tuple[str, ...] = over[name]
    elif name == "batch":
        cand = batch_axes()
    else:
        cand = _RULES[name]
    axes = [a for a in cand if a in sizes and (used is None or a not in used)]
    if not axes:
        return None
    prod = 1
    for a in axes:
        prod *= sizes[a]
    if dim % prod != 0:
        # try single axes in order
        for a in axes:
            if dim % sizes[a] == 0:
                return a
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def spec_for(logical: tuple[str | None, ...], shape: tuple[int, ...]) -> P:
    """PartitionSpec for a tensor given its logical axes and concrete shape."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    entries = []
    used: set[str] = set()
    for name, dim in zip(logical, shape):
        e = _axis_entry(mesh, name, dim, used)
        if e is None:
            entries.append(None)
            continue
        flat = e if isinstance(e, tuple) else (e,)
        used.update(flat)
        entries.append(e)
    return P(*entries)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x`` to the logical layout (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(x, spec_for(tuple(logical), x.shape))


def named_sharding(mesh: Mesh, logical: tuple[str | None, ...], shape) -> NamedSharding:
    """Concrete NamedSharding for placing inputs / params on a real mesh."""
    # spec_for needs the mesh context; compute via a temporary set_mesh
    with set_mesh(mesh):
        spec = spec_for(logical, tuple(shape))
    return NamedSharding(mesh, spec)
