"""Pallas TPU kernel: fused EF-add + stochastic one-bit quantize + bit pack.

This is the client-side hot loop of PRoBit+: every parameter of the model
difference is binarized (Eq. 5) and packed 8/byte before upload. Fusing
the steps keeps the f32 delta in VMEM and writes only N/8 bytes back to
HBM — a 4x reduction in HBM write traffic vs. materializing int8 codes.
The EF variant (:func:`stoch_quant_ef_2d`) additionally folds the
error-feedback carry in and emits the next residual ``eff - c * b`` from
the same VMEM-resident block, so a sparsified/EF client touches HBM once
per parameter instead of three times (quantize, re-unpack, subtract).

Layout: the flat parameter vector is viewed as ``(rows, 1024)`` — the last
dim is 8 x 128 (sublane x lane) aligned; packing reduces 1024 lanes of f32
to 128 lanes of uint8, both hardware-tile-aligned. The in-kernel
``reshape(br, 128, 8)`` is a VREG relayout the Mosaic compiler handles.

Dispatch policy (see :mod:`repro.kernels.ops`): compiled Pallas on TPU,
the pure-JAX wire in :mod:`repro.kernels.ref` elsewhere; ``interpret=True``
is for kernel-correctness tests only and never auto-selected.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024  # f32 elements per row; packs to 128 uint8 lanes


def _binarize(d, b, u):
    """Eq.-5 bits for one VMEM block; identical arithmetic to
    ``repro.core.quantizer.binarize_prob`` (clip, zero-b guard) so kernel
    and pure wires agree bit-for-bit given the same uniforms."""
    safe_b = jnp.where(b > 0, b, 1.0)
    p = jnp.where(b > 0, 0.5 + 0.5 * jnp.clip(d, -b, b) / safe_b, 0.5)
    return u < p


def _pack(bits):
    br = bits.shape[0]
    b8 = bits.astype(jnp.uint8).reshape(br, LANES // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(b8 << shifts, axis=-1).astype(jnp.uint8)


def _kernel(delta_ref, b_ref, u_ref, out_ref):
    d = delta_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    out_ref[...] = _pack(_binarize(d, b, u_ref[...]))


def _ef_kernel(delta_ref, res_ref, b_ref, u_ref, out_ref, new_res_ref):
    eff = delta_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    bits = _binarize(eff, b, u_ref[...])
    out_ref[...] = _pack(bits)
    new_res_ref[...] = eff - jnp.where(bits, b, -b)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stoch_quant_pack_2d(
    delta: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """delta/b/uniforms: (rows, 1024); returns packed (rows, 128) uint8."""
    rows = delta.shape[0]
    assert delta.shape == (rows, LANES) == b.shape == uniforms.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES // 8), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES // 8), jnp.uint8),
        interpret=interpret,
    )(delta, b, uniforms)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stoch_quant_ef_2d(
    delta: jax.Array,
    residual: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused EF compress: eff = delta + residual, pack Eq.-5 bits of eff,
    and emit the next carry ``eff - c * b`` in one pass.

    All inputs (rows, 1024) f32; returns (packed (rows, 128) uint8,
    new_residual (rows, 1024) f32).
    """
    rows = delta.shape[0]
    assert (
        delta.shape == (rows, LANES) == residual.shape == b.shape == uniforms.shape
    )
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    spec_in = pl.BlockSpec((block_rows, LANES), lambda r: (r, 0))
    return pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[spec_in] * 4,
        out_specs=[
            pl.BlockSpec((block_rows, LANES // 8), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES // 8), jnp.uint8),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(delta, residual, b, uniforms)
