"""Pallas TPU kernel: fused stochastic one-bit quantize (Eq. 5) + bit pack.

This is the client-side hot loop of PRoBit+: every parameter of the model
difference is binarized and packed 8/byte before upload. Fusing the two
steps keeps the f32 delta in VMEM and writes only N/8 bytes back to HBM —
a 4x reduction in HBM write traffic vs. materializing int8 codes.

Layout: the flat parameter vector is viewed as ``(rows, 1024)`` — the last
dim is 8 x 128 (sublane x lane) aligned; packing reduces 1024 lanes of f32
to 128 lanes of uint8, both hardware-tile-aligned. The in-kernel
``reshape(br, 128, 8)`` is a VREG relayout the Mosaic compiler handles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024  # f32 elements per row; packs to 128 uint8 lanes


def _kernel(delta_ref, b_ref, u_ref, out_ref):
    d = delta_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    u = u_ref[...]
    safe_b = jnp.where(b > 0, b, 1.0)
    p = jnp.where(b > 0, 0.5 + 0.5 * jnp.clip(d, -b, b) / safe_b, 0.5)
    bits = (u < p).astype(jnp.uint8)
    br = bits.shape[0]
    bits = bits.reshape(br, LANES // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stoch_quant_pack_2d(
    delta: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """delta/b/uniforms: (rows, 1024); returns packed (rows, 128) uint8."""
    rows = delta.shape[0]
    assert delta.shape == (rows, LANES) == b.shape == uniforms.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, LANES), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES // 8), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES // 8), jnp.uint8),
        interpret=interpret,
    )(delta, b, uniforms)
