"""Pallas TPU kernels for PRoBit+'s compute hot spots.

Kernels (each: <name>.py kernel, ops.py jit wrapper, ref.py jnp oracle):
  * stoch_quant   -- fused Eq.-5 stochastic binarize + 8:1 bit pack
  * bit_aggregate -- unpack + vote count + Eq.-13 ML estimate
  * prox_sgd      -- fused prox-regularized SGD+momentum local update
"""

from .ops import stoch_quant_pack, bit_aggregate, prox_sgd, padded_len

__all__ = ["stoch_quant_pack", "bit_aggregate", "prox_sgd", "padded_len"]
