"""Pallas TPU kernels for PRoBit+'s compute hot spots, with engine dispatch.

Kernels (each: <name>.py kernel, ops.py jit wrapper, ref.py jnp oracle):
  * stoch_quant   -- fused EF-add + Eq.-5 stochastic binarize + 8:1 bit pack
  * bit_aggregate -- popcount vote count + Eq.-13 ML estimate
  * prox_sgd      -- fused prox-regularized SGD+momentum local update

Dispatch policy (``ops.resolve_engine``): compiled Pallas on TPU, the
bit-identical pure-JAX reference wire (``ref.py``) on every other backend;
interpret-mode Pallas is test-only and never auto-selected.
"""

from .ops import (
    ENGINES,
    resolve_engine,
    stoch_quant_pack,
    stoch_quant_compress,
    stoch_quant_compress_batch,
    quant_pack_u,
    bit_aggregate,
    prox_sgd,
    padded_len,
)

__all__ = [
    "ENGINES",
    "resolve_engine",
    "stoch_quant_pack",
    "stoch_quant_compress",
    "stoch_quant_compress_batch",
    "quant_pack_u",
    "bit_aggregate",
    "prox_sgd",
    "padded_len",
]
