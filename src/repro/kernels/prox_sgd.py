"""Pallas TPU kernel: fused prox-regularized SGD+momentum update (Eq. 4).

The inner loop of every PRoBit+ client performs, per parameter,

    g      = grad + lam * (w - w_global)
    m'     = mu * m + g
    w'     = w - eta * m'

Unfused this is 4 HBM-bound elementwise passes; the fused kernel streams
each operand exactly once (4 reads, 2 writes per element).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024


def _kernel(w_ref, w0_ref, g_ref, m_ref, eta_lam_mu_ref, w_out_ref, m_out_ref):
    eta = eta_lam_mu_ref[0]
    lam = eta_lam_mu_ref[1]
    mu = eta_lam_mu_ref[2]
    w = w_ref[...]
    g = g_ref[...] + lam * (w - w0_ref[...])
    new_m = mu * m_ref[...] + g
    m_out_ref[...] = new_m
    w_out_ref[...] = w - eta * new_m


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def prox_sgd_2d(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta_lam_mu: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """All tensor args (rows, 1024) f32; eta_lam_mu (3,) f32 in SMEM."""
    rows = w.shape[0]
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, LANES), lambda r: (r, 0))
    w_new, m_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            spec,
            spec,
            spec,
            spec,
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(w, w0, grad, momentum, eta_lam_mu)
    return w_new, m_new
