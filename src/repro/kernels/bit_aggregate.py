"""Pallas TPU kernel: server-side unpack + vote-count + ML estimate (Eq. 13).

Reads the (M, N/8) packed uint8 code matrix column-block by column-block,
unpacks each client's bits in VMEM, accumulates the +1 vote count N_i on
the VPU (integer adds over the client axis), and emits
``theta_hat = (2 N_i - M) / M * b_i`` directly — the f32 codes are never
materialized in HBM. HBM read traffic is M * N/8 bytes (vs 4 * M * N for a
full-precision FedAvg reduce), which is the paper's 32x claim realized at
the memory-system level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BYTE_BLOCK = 128  # uint8 lanes per grid step -> 1024 output elements
LANES = BYTE_BLOCK * 8


def _kernel(packed_ref, b_ref, out_ref):
    packed = packed_ref[...]  # (M, 128) uint8
    m = packed.shape[0]
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)  # (M, 128, 8)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0)  # (128, 8)
    theta_scaled = (2.0 * counts.astype(jnp.float32) - m) / m  # in [-1, 1]
    out_ref[...] = theta_scaled.reshape(1, LANES) * b_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bit_aggregate_2d(
    packed: jax.Array, b2d: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """packed: (M, C) uint8 with C % 128 == 0; b2d: (C/128, 1024) f32.

    Returns theta_hat as (C/8r...) — shaped (C // 128, 1024) f32, the 2D view
    of the flat N = 8 * C estimate.
    """
    m, c = packed.shape
    assert c % BYTE_BLOCK == 0
    rows = c // BYTE_BLOCK
    assert b2d.shape == (rows, LANES)
    grid = (rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, BYTE_BLOCK), lambda r: (0, r)),
            pl.BlockSpec((1, LANES), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(packed, b2d)
