"""Pallas TPU kernel: server-side popcount vote-count + ML estimate (Eq. 13).

Reads the (M, N/8) packed uint8 code matrix in (client-block, column-block)
tiles and counts the +1 votes N_i with ``jax.lax.population_count`` after
an octet bit-transpose: 8 clients' bit-k's re-pack into one client-major
byte whose popcount counts 8 votes at once (the same reduction as the
pure-JAX ``repro.core.quantizer._popcount_colsums``). The client reduction
shortens 8x and the widest in-register intermediate stays uint8. Partial
counts accumulate in f32 in the output block across the client-block grid
axis (exact below 2**24 clients); the last step applies
``theta_hat = (2 N_i - M) / M * b_i`` in place, so the f32 codes are never
materialized in HBM.

The grid is (column-rows, client-steps) with the client axis innermost:
each output block is revisited ``m_steps`` times while Pallas's grid
pipelining double-buffers the next packed tile's HBM->VMEM copy behind the
current popcount. HBM read traffic is M * N/8 bytes (vs 4 * M * N for a
full-precision FedAvg reduce) — the paper's 32x wire claim realized at the
memory-system level.

Dispatch policy (see :mod:`repro.kernels.ops`): compiled Pallas on TPU,
the pure-JAX wire in :mod:`repro.kernels.ref` elsewhere; ``interpret=True``
is for kernel-correctness tests only and never auto-selected.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BYTE_BLOCK = 128  # uint8 lanes per grid step -> 1024 output elements
LANES = BYTE_BLOCK * 8
M_BLOCK = 256  # clients per grid step (multiple of 8)


def _kernel(packed_ref, b_ref, out_ref, *, m, m_steps):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = packed_ref[...]  # (mb, 128) uint8, mb % 8 == 0
    mb = x.shape[0]
    xr = x.reshape(mb // 8, 8, BYTE_BLOCK)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    # Octet bit-transpose: bit k of 8 consecutive clients' byte j becomes
    # one client-major byte; its popcount is 8 clients' votes for coord 8j+k.
    bit_k = (xr[:, :, :, None] >> shifts) & jnp.uint8(1)  # (G, 8, 128, 8)
    octet = jnp.sum(bit_k << shifts[None, :, None, None], axis=1, dtype=jnp.uint8)
    votes = jax.lax.population_count(octet)  # (G, 128, 8)
    partial = jnp.sum(votes.astype(jnp.float32), axis=0)  # (128, 8)
    out_ref[...] += partial.reshape(1, LANES)

    @pl.when(i == m_steps - 1)
    def _finalize():
        counts = out_ref[...]
        out_ref[...] = (2.0 * counts - m) / m * b_ref[...]


@functools.partial(jax.jit, static_argnames=("m_block", "interpret"))
def bit_aggregate_2d(
    packed: jax.Array,
    b2d: jax.Array,
    *,
    m_block: int = M_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """packed: (M, C) uint8 with C % 128 == 0; b2d: (C/128, 1024) f32.

    Returns theta_hat shaped (C // 128, 1024) f32 — the 2D view of the
    flat N = 8 * C estimate. M may be any positive count (client rows are
    zero-padded to a whole number of ``m_block`` tiles; zero bytes add
    zero votes, and the Eq.-13 normalizer uses the true M).
    """
    m, c = packed.shape
    assert c % BYTE_BLOCK == 0
    rows = c // BYTE_BLOCK
    assert b2d.shape == (rows, LANES)
    assert m_block % 8 == 0
    mb = min(m_block, ((m + 7) // 8) * 8)
    m_pad = ((m + mb - 1) // mb) * mb
    packed = jnp.pad(packed, ((0, m_pad - m), (0, 0)))
    m_steps = m_pad // mb
    grid = (rows, m_steps)
    return pl.pallas_call(
        functools.partial(_kernel, m=m, m_steps=m_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((mb, BYTE_BLOCK), lambda r, i: (i, r)),
            pl.BlockSpec((1, LANES), lambda r, i: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda r, i: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(packed, b2d)
