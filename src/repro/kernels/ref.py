"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel semantics; tests sweep
shapes/dtypes and ``assert_allclose`` the Pallas outputs against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stoch_quant_pack_ref(
    delta: jax.Array, b: jax.Array, uniforms: jax.Array
) -> jax.Array:
    """Fused Eq.-5 binarize + LSB-first 8:1 bit pack.

    Args:
      delta: (N,) float — model difference (N divisible by 8).
      b: (N,) float — public quantization range (>= 0).
      uniforms: (N,) float32 in [0, 1).
    Returns:
      (N // 8,) uint8 packed codes; bit=1 encodes c=+1.
    """
    b = b.astype(jnp.float32)
    d = jnp.clip(delta.astype(jnp.float32), -b, b)
    safe_b = jnp.where(b > 0, b, 1.0)
    p = jnp.where(b > 0, 0.5 + 0.5 * d / safe_b, 0.5)
    bits = (uniforms < p).astype(jnp.uint8).reshape(-1, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def bit_aggregate_ref(packed: jax.Array, b: jax.Array) -> jax.Array:
    """Popcount-sum M clients' packed codes, then ML-estimate (Eq. 13).

    The vote count is a per-coordinate *column* sum of the bit matrix, so
    ``population_count`` (which sums a byte's 8 bits, i.e. across 8
    coordinates) applies after an octet bit-transpose: 8 clients' bit-k's
    re-pack into one client-major byte whose popcount counts 8 votes at
    once (uint8 LUT fallback via
    :func:`repro.core.quantizer.byte_popcount`). Integer counts are
    identical to the unpack-and-sum reduction.

    Args:
      packed: (M, N // 8) uint8.
      b: (N,) float32.
    Returns:
      (N,) float32 — theta_hat = (2 N_i - M) / M * b_i.
    """
    from ..core.quantizer import byte_popcount

    m, pbytes = packed.shape
    pad = (-m) % 8
    x = jnp.pad(packed, ((0, pad), (0, 0))).reshape(-1, 8, pbytes)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bit_k = (x[:, :, :, None] >> shifts) & jnp.uint8(1)  # (G, 8, N//8, 8)
    octet = jnp.sum(bit_k << shifts[None, :, None, None], axis=1, dtype=jnp.uint8)
    counts = jnp.sum(byte_popcount(octet).astype(jnp.int32), axis=0).reshape(-1)
    return (2.0 * counts - m) / m * b.astype(jnp.float32)


def prox_sgd_ref(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta: float,
    lam: float,
    mu: float,
) -> tuple[jax.Array, jax.Array]:
    """Fused prox-regularized SGD+momentum step (paper Eq. 4 local solver).

    g_total = grad + lam * (w - w0)
    momentum' = mu * momentum + g_total
    w' = w - eta * momentum'
    """
    g = grad + lam * (w - w0)
    new_m = mu * momentum + g
    return w - eta * new_m, new_m
