"""Pure-JAX reference wire: oracles for every Pallas kernel in this package.

Two jobs, one implementation:

1. **Semantics oracle.** Tests sweep shapes/dtypes and assert the Pallas
   outputs (run in ``interpret`` mode on CPU) match these bit-for-bit.
2. **Dispatch target.** On any backend without a Mosaic compiler (CPU,
   GPU today), :mod:`repro.kernels.ops` routes ``use_kernels=True`` here
   instead of at interpret-mode Pallas — interpret mode emulates the
   kernel lane-by-lane and is orders of magnitude slower than compiled
   XLA, so it is reserved for explicit kernel-correctness tests.

To guarantee the oracle can never drift from the production pure-JAX wire,
these functions are thin compositions of the :mod:`repro.core.quantizer`
primitives (``binarize_prob``, ``_pack_bool_lastdim``, ``byte_popcount``)
rather than re-implementations. Imports are deferred to call time to keep
``repro.kernels`` importable without ``repro.core`` (and vice versa).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stoch_quant_compress_ref(
    delta: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    residual: jax.Array | None = None,
    *,
    want_residual: bool = False,
) -> tuple[jax.Array, jax.Array | None]:
    """Fused EF-add + Eq.-5 binarize + LSB-first 8:1 bit pack.

    Args:
      delta: (N,) float — model difference (N divisible by 8).
      b: (N,) float — public quantization range (>= 0).
      uniforms: (N,) float32 in [0, 1).
      residual: optional (N,) float error-feedback carry, added to delta
        before binarization (eff = delta + residual).
      want_residual: also return the next EF carry ``eff - c * b``.
    Returns:
      ((N // 8,) uint8 packed codes, (N,) f32 residual or None);
      bit=1 encodes c=+1.
    """
    from ..core.quantizer import _pack_bool_lastdim, binarize_prob

    eff = delta.astype(jnp.float32)
    if residual is not None:
        eff = eff + residual.astype(jnp.float32)
    b = jnp.broadcast_to(b, eff.shape).astype(jnp.float32)
    bits = uniforms < binarize_prob(eff, b)
    packed = _pack_bool_lastdim(bits)
    if not want_residual:
        return packed, None
    return packed, eff - jnp.where(bits, b, -b)


def stoch_quant_pack_ref(
    delta: jax.Array, b: jax.Array, uniforms: jax.Array
) -> jax.Array:
    """Eq.-5 binarize + pack without error feedback (kept for kernel tests)."""
    packed, _ = stoch_quant_compress_ref(delta, b, uniforms)
    return packed


def bit_aggregate_ref(packed: jax.Array, b: jax.Array) -> jax.Array:
    """Popcount-sum M clients' packed codes, then ML-estimate (Eq. 13).

    The vote count is a per-coordinate *column* sum of the bit matrix, so
    ``population_count`` (which sums a byte's 8 bits, i.e. across 8
    coordinates) applies after an octet bit-transpose: 8 clients' bit-k's
    re-pack into one client-major byte whose popcount counts 8 votes at
    once. Delegates to :func:`repro.core.quantizer.packed_counts`, the
    d-chunked production reduction, so ref and pure-JAX counts are the
    same code path by construction.

    Args:
      packed: (M, N // 8) uint8.
      b: (N,) float32.
    Returns:
      (N,) float32 — theta_hat = (2 N_i - M) / M * b_i.
    """
    from ..core.quantizer import packed_counts

    m = packed.shape[0]
    counts = packed_counts(packed)[: b.shape[0]]
    return (2.0 * counts.astype(jnp.float32) - m) / m * b.astype(jnp.float32)


def kbit_quant_compress_ref(
    delta: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    *,
    bits: int,
    residual: jax.Array | None = None,
    want_residual: bool = False,
) -> tuple[jax.Array, jax.Array | None]:
    """k-bit sibling of :func:`stoch_quant_compress_ref` (one client).

    Stochastic-rounds onto the uniform ``2**bits``-level grid in [-b, b]
    and packs the level index as ``bits`` one-bit planes (plane-major,
    each plane the exact one-bit pack) — see
    :func:`repro.core.quantizer.quantize_levels` /
    :func:`repro.core.quantizer.pack_levels`. ``bits=1`` reproduces the
    one-bit ref wire byte-for-byte (level 1 == code +1, plane 0 == the
    sign-bit plane).

    Args:
      delta: (N,) float, N divisible by 8.
      b: (N,) float public range.
      uniforms: (N,) float32 in [0, 1) — the rounding draws.
      residual: optional EF carry added to delta first.
      want_residual: also return ``eff - dequantize(level)``.
    Returns:
      ((bits * N // 8,) uint8 packed planes, (N,) f32 residual or None).
    """
    from ..core.quantizer import dequantize_levels, pack_levels, quantize_levels

    eff = delta.astype(jnp.float32)
    if residual is not None:
        eff = eff + residual.astype(jnp.float32)
    b = jnp.broadcast_to(b, eff.shape).astype(jnp.float32)
    levels = quantize_levels(uniforms, eff, b, bits)
    packed = pack_levels(levels, bits)
    if not want_residual:
        return packed, None
    return packed, eff - dequantize_levels(levels, b, bits)


def kbit_aggregate_ref(packed: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """Popcount-count each bit plane, then the L-level ML estimate.

    The plane-major wire keeps the octet-transpose popcount reduction
    (:func:`repro.core.quantizer.packed_counts`) valid verbatim: the flat
    count of an ``(M, bits * P)`` wire *is* the per-plane vote count laid
    out plane-major, and ``sum_p 2**p N_p`` is the level-histogram mean
    the estimate needs.

    Args:
      packed: (M, bits * P) uint8, P = N // 8.
      b: (N,) float32.
    Returns:
      (N,) float32 — :func:`repro.core.aggregation.kbit_estimate_from_counts`.
    """
    from ..core.aggregation import kbit_estimate_from_counts
    from ..core.quantizer import packed_counts

    m = packed.shape[0]
    n = b.shape[0]
    flat = packed_counts(packed)
    plane_counts = flat.reshape(bits, -1)[:, :n]
    return kbit_estimate_from_counts(plane_counts, m, b, bits)


def prox_sgd_ref(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta: float,
    lam: float,
    mu: float,
) -> tuple[jax.Array, jax.Array]:
    """Fused prox-regularized SGD+momentum step (paper Eq. 4 local solver).

    g_total = grad + lam * (w - w0)
    momentum' = mu * momentum + g_total
    w' = w - eta * momentum'
    """
    g = grad + lam * (w - w0)
    new_m = mu * momentum + g
    return w - eta * new_m, new_m
