"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel semantics; tests sweep
shapes/dtypes and ``assert_allclose`` the Pallas outputs against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stoch_quant_pack_ref(
    delta: jax.Array, b: jax.Array, uniforms: jax.Array
) -> jax.Array:
    """Fused Eq.-5 binarize + LSB-first 8:1 bit pack.

    Args:
      delta: (N,) float — model difference (N divisible by 8).
      b: (N,) float — public quantization range (>= 0).
      uniforms: (N,) float32 in [0, 1).
    Returns:
      (N // 8,) uint8 packed codes; bit=1 encodes c=+1.
    """
    b = b.astype(jnp.float32)
    d = jnp.clip(delta.astype(jnp.float32), -b, b)
    safe_b = jnp.where(b > 0, b, 1.0)
    p = jnp.where(b > 0, 0.5 + 0.5 * d / safe_b, 0.5)
    bits = (uniforms < p).astype(jnp.uint8).reshape(-1, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def bit_aggregate_ref(packed: jax.Array, b: jax.Array) -> jax.Array:
    """Unpack M clients' packed codes, popcount-sum, ML-estimate (Eq. 13).

    Args:
      packed: (M, N // 8) uint8.
      b: (N,) float32.
    Returns:
      (N,) float32 — theta_hat = (2 N_i - M) / M * b_i.
    """
    m = packed.shape[0]
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)  # (M, N//8, 8)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0).reshape(-1)  # (N,)
    return (2.0 * counts - m) / m * b.astype(jnp.float32)


def prox_sgd_ref(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta: float,
    lam: float,
    mu: float,
) -> tuple[jax.Array, jax.Array]:
    """Fused prox-regularized SGD+momentum step (paper Eq. 4 local solver).

    g_total = grad + lam * (w - w0)
    momentum' = mu * momentum + g_total
    w' = w - eta * momentum'
    """
    g = grad + lam * (w - w0)
    new_m = mu * momentum + g
    return w - eta * new_m, new_m
