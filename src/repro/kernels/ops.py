"""Jit'd public wrappers around the Pallas kernels, with engine dispatch.

These accept flat (N,) vectors of arbitrary length, handle padding to the
(rows, 1024) tile layout, and dispatch to one of three engines:

  * ``"pallas"``    — the compiled Mosaic kernels. Requires a backend with
    a Pallas compiler (TPU); this is the deployment target.
  * ``"ref"``       — the pure-JAX reference wire (:mod:`repro.kernels.ref`
    + the :mod:`repro.core.quantizer` primitives), bit-identical to the
    kernels and compiled by stock XLA on any backend.
  * ``"interpret"`` — interpret-mode Pallas: the kernel emulated
    lane-by-lane in Python/XLA. Orders of magnitude slower than either of
    the above; it exists *only* so kernel-correctness tests can validate
    the Pallas lowering on CPU, and is never auto-selected.

:func:`resolve_engine` implements the policy: an explicit ``engine=`` wins;
otherwise TPU resolves to ``"pallas"`` and every other backend to
``"ref"``. (A previous revision auto-selected interpret mode on CPU, which
put the emulator in the hot path and made ``use_kernels=True`` ~115x
slower than the pure-JAX wire — see ``benchmarks/kernels_micro.py``, whose
smoke mode now guards this exact regression.)

Randomness: the quantizer uniforms are counter-derived per client via
:func:`repro.core.quantizer.client_uniforms` (chunk ``j`` of the client
draws from ``fold_in(client_key, j)``), the same schedule as
``packed_binarize_batch``. All three engines therefore produce
bit-identical packed wires — dense, chunked-streaming, and kernel paths
are interchangeable per wire, validated exactly in
``tests/test_pipeline.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.quantizer import (
    PACK_CHUNK,
    client_uniforms,
    packed_binarize_batch,
    packed_counts,
    packed_quantize_batch,
)
from .stoch_quant import LANES, stoch_quant_ef_2d, stoch_quant_pack_2d
from .bit_aggregate import bit_aggregate_2d
from .prox_sgd import prox_sgd_2d
from . import ref

__all__ = [
    "ENGINES",
    "resolve_engine",
    "stoch_quant_pack",
    "stoch_quant_compress",
    "stoch_quant_compress_batch",
    "quant_pack_u",
    "bit_aggregate",
    "prox_sgd",
    "padded_len",
]

ENGINES = ("pallas", "ref", "interpret")


def resolve_engine(engine: str | None = None, backend: str | None = None) -> str:
    """Dispatch policy: explicit ``engine`` wins; else TPU->pallas, *->ref.

    ``interpret`` is only ever returned when explicitly requested — it is a
    test harness for the kernel lowering, not an execution engine.
    """
    if engine is not None:
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        return engine
    backend = backend or jax.default_backend()
    return "pallas" if backend == "tpu" else "ref"


def _engine_arg(engine: str | None, interpret: bool | None) -> str:
    """Back-compat shim: ``interpret=True`` means engine="interpret"."""
    if interpret is not None:
        if engine is not None:
            raise ValueError("pass either engine= or interpret=, not both")
        engine = "interpret" if interpret else "pallas"
    return resolve_engine(engine)


def padded_len(n: int) -> int:
    return ((n + LANES - 1) // LANES) * LANES


def _pad_to_rows(x: jax.Array, fill: float) -> jax.Array:
    n = x.shape[0]
    p = padded_len(n)
    x = jnp.pad(x.astype(jnp.float32), (0, p - n), constant_values=fill)
    return x.reshape(-1, LANES)


@functools.partial(
    jax.jit, static_argnames=("chunk", "want_residual", "engine", "interpret")
)
def stoch_quant_compress(
    key: jax.Array,
    delta: jax.Array,
    b: jax.Array,
    residual: jax.Array | None = None,
    *,
    chunk: int = PACK_CHUNK,
    want_residual: bool = False,
    engine: str | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Fused EF-add + Eq.-5 binarize + bit-pack for one client.

    ``key`` is the *client* key (already ``fold_in(round_key, row)``-ed by
    the caller); uniforms follow the counter-derived ``client_uniforms``
    schedule at ``chunk``, so the emitted wire prefix is bit-identical to
    ``packed_binarize_batch(..., chunk=chunk)``'s for the same client.

    Args:
      delta: (N,) f32 model difference.
      b: scalar or (N,) public range.
      residual: optional (N,) EF carry added to delta before quantizing.
      want_residual: also return the next carry ``eff - c * b``.
    Returns:
      (packed (padded_len(N)/8,) uint8, residual (N,) f32 or None). Pad
      coordinates beyond N get delta=-1, b=1 (deterministic 0 bits), the
      same convention as the pure wire's ``_pad_batch``.
    """
    engine = _engine_arg(engine, interpret)
    n = delta.shape[0]
    b_full = jnp.broadcast_to(b, (n,)).astype(jnp.float32)
    u = client_uniforms(key, n, chunk)
    if engine == "ref":
        pad = padded_len(n) - n
        d_p = jnp.pad(delta.astype(jnp.float32), (0, pad), constant_values=-1.0)
        b_p = jnp.pad(b_full, (0, pad), constant_values=1.0)
        u_p = jnp.pad(u, (0, pad), constant_values=1.0)
        r_p = None
        if residual is not None:
            r_p = jnp.pad(residual.astype(jnp.float32), (0, pad))
        packed, res = ref.stoch_quant_compress_ref(
            d_p, b_p, u_p, r_p, want_residual=want_residual
        )
        return packed, None if res is None else res[:n]
    itp = engine == "interpret"
    d2 = _pad_to_rows(delta, -1.0)
    b2 = _pad_to_rows(b_full, 1.0)
    u2 = _pad_to_rows(u, 1.0)
    if residual is None and not want_residual:
        packed = stoch_quant_pack_2d(d2, b2, u2, interpret=itp)
        return packed.reshape(-1), None
    r2 = (
        _pad_to_rows(residual, 0.0)
        if residual is not None
        else jnp.zeros_like(d2)
    )
    packed, res = stoch_quant_ef_2d(d2, r2, b2, u2, interpret=itp)
    if not want_residual:
        return packed.reshape(-1), None
    return packed.reshape(-1), res.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("chunk", "engine", "interpret"))
def stoch_quant_pack(
    key: jax.Array,
    delta: jax.Array,
    b: jax.Array,
    *,
    chunk: int = PACK_CHUNK,
    engine: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Flat (N,) delta/b -> packed (padded_len(N)/8,) uint8 codes."""
    packed, _ = stoch_quant_compress(
        key, delta, b, chunk=chunk, engine=_engine_arg(engine, interpret)
    )
    return packed


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "want_residual", "engine", "interpret", "bits"),
)
def stoch_quant_compress_batch(
    key: jax.Array,
    deltas: jax.Array,
    b: jax.Array,
    *,
    row_offset: jax.Array | int = 0,
    chunk: int = PACK_CHUNK,
    want_residual: bool = False,
    engine: str | None = None,
    interpret: bool | None = None,
    bits: int = 1,
    gamma: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Batch compress of an (M, d) cohort to the kernel-aligned wire.

    Client ``i`` draws from ``fold_in(key, row_offset + i)`` with the
    ``client_uniforms`` chunk schedule — the ``packed_binarize_batch``
    convention, so the wire is bit-identical across engines *and* across
    client-chunked streaming splits (``row_offset`` rebases the cohort
    position).

    The ref engine *is* ``packed_binarize_batch`` (the chunked pure-JAX
    packer — cache-blocked, the fast path on CPU), realigned losslessly to
    the kernel wire width ``padded_len(d)/8`` (both pads are deterministic
    0 bits); pallas/interpret vmap the fused kernel over clients.

    ``bits > 1`` emits the plane-major k-bit wire
    (:func:`repro.core.quantizer.packed_quantize_batch`, optionally
    randomized-response-mixed via ``gamma``), each plane realigned to the
    kernel width — (M, bits * padded_len(d)/8). There is no Mosaic k-bit
    kernel yet, so every backend routes k > 1 through the ref engine
    (interpret mode, being strictly a lowering test for the one-bit
    kernel, rejects it).

    Returns (packed (M, bits * padded_len(d)/8) uint8, residuals (M, d)
    or None).
    """
    engine = _engine_arg(engine, interpret)
    m, d = deltas.shape
    target = padded_len(d) // 8
    if bits > 1:
        if engine == "interpret":
            raise NotImplementedError(
                "bits > 1 has no Pallas lowering; interpret mode only "
                "emulates existing kernels (use engine='ref')"
            )
        packed, res = packed_quantize_batch(
            key, deltas, b, bits=bits, chunk=chunk,
            want_residual=want_residual, row_offset=row_offset, gamma=gamma,
        )
        src = packed.shape[1] // bits
        planes = packed.reshape(m, bits, src)
        if src > target:
            planes = planes[:, :, :target]
        elif src < target:
            planes = jnp.pad(planes, ((0, 0), (0, 0), (0, target - src)))
        return planes.reshape(m, bits * target), res
    if engine == "ref":
        packed, res = packed_binarize_batch(
            key, deltas, b, chunk=chunk, want_residual=want_residual,
            row_offset=row_offset,
        )
        if packed.shape[1] > target:
            packed = packed[:, :target]
        elif packed.shape[1] < target:
            packed = jnp.pad(packed, ((0, 0), (0, target - packed.shape[1])))
        return packed, res
    client_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        row_offset + jnp.arange(m)
    )
    return jax.vmap(
        lambda ck, row: stoch_quant_compress(
            ck, row, b, chunk=chunk, want_residual=want_residual, engine=engine
        )
    )(client_keys, deltas)


@functools.partial(jax.jit, static_argnames=("engine", "interpret"))
def quant_pack_u(
    delta: jax.Array,
    b: jax.Array,
    uniforms: jax.Array,
    *,
    engine: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Explicit-uniforms Eq.-5 binarize + pack (the top-k gathered values).

    Unlike :func:`stoch_quant_compress` this draws nothing itself — the
    caller supplies the uniforms (e.g. ``uniform(client_key, (k,))``, the
    sparse path's schedule). (K,) float arrays -> (padded_len(K)/8,) uint8;
    pad coordinates get deterministic 0 bits, so slicing the first
    ``ceil(K/8)`` bytes reproduces ``pack_bits``'s output exactly.
    """
    engine = _engine_arg(engine, interpret)
    k = delta.shape[0]
    pad = padded_len(k) - k
    d_p = jnp.pad(delta.astype(jnp.float32), (0, pad), constant_values=-1.0)
    b_p = jnp.pad(
        jnp.broadcast_to(b, (k,)).astype(jnp.float32), (0, pad),
        constant_values=1.0,
    )
    u_p = jnp.pad(uniforms, (0, pad), constant_values=1.0)
    if engine == "ref":
        packed, _ = ref.stoch_quant_compress_ref(d_p, b_p, u_p)
        return packed
    packed = stoch_quant_pack_2d(
        d_p.reshape(-1, LANES),
        b_p.reshape(-1, LANES),
        u_p.reshape(-1, LANES),
        interpret=engine == "interpret",
    )
    return packed.reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "engine", "interpret"))
def bit_aggregate(
    packed: jax.Array,
    b: jax.Array,
    n: int,
    *,
    engine: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """packed (M, P) uint8 (P = padded_len(n)/8), b (n,) -> theta_hat (n,).

    The vote count is popcount-based on every engine
    (``jax.lax.population_count`` after an octet bit-transpose) and
    bit-exact with ``repro.core.quantizer.packed_counts``; pad columns are
    sliced away before the estimate so tail lanes can never leak.
    """
    engine = _engine_arg(engine, interpret)
    m = packed.shape[0]
    b_full = jnp.broadcast_to(b, (n,)).astype(jnp.float32)
    if engine == "ref":
        counts = packed_counts(packed)[:n]
        return (2.0 * counts.astype(jnp.float32) - m) / m * b_full
    b2 = _pad_to_rows(b_full, 0.0)
    theta2 = bit_aggregate_2d(packed, b2, interpret=engine == "interpret")
    return theta2.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("engine", "interpret"))
def prox_sgd(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta: jax.Array,
    lam: jax.Array,
    mu: jax.Array,
    *,
    engine: str | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Flat (N,) fused prox-SGD step; returns (w_new, momentum_new)."""
    engine = _engine_arg(engine, interpret)
    if engine == "ref":
        return ref.prox_sgd_ref(w, w0, grad, momentum, eta, lam, mu)
    n = w.shape[0]
    args = [_pad_to_rows(x, 0.0) for x in (w, w0, grad, momentum)]
    elm = jnp.stack(
        [jnp.asarray(eta, jnp.float32), jnp.asarray(lam, jnp.float32),
         jnp.asarray(mu, jnp.float32)]
    )
    w2, m2 = prox_sgd_2d(*args, elm, interpret=engine == "interpret")
    return w2.reshape(-1)[:n], m2.reshape(-1)[:n]
