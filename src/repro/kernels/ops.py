"""Jit'd public wrappers around the Pallas kernels.

These accept flat (N,) vectors of arbitrary length, handle padding to the
(rows, 1024) tile layout, and dispatch to the kernels. ``interpret`` is
auto-selected: True on CPU (the container's validation mode), False on TPU
(the deployment target).

``stoch_quant_pack`` / ``bit_aggregate`` are the ``use_kernels=True``
engine of the "probit_plus" :class:`repro.core.AggregatorPipeline`: they
produce and consume the same packed uint8 wire as the pure-JAX chunked
path (``repro.core.quantizer.packed_binarize_batch`` / ``packed_counts``),
so the two are interchangeable per wire (validated in
``tests/test_pipeline.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .stoch_quant import LANES, stoch_quant_pack_2d
from .bit_aggregate import bit_aggregate_2d
from .prox_sgd import prox_sgd_2d
from . import ref

__all__ = ["stoch_quant_pack", "bit_aggregate", "prox_sgd", "padded_len"]


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def padded_len(n: int) -> int:
    return ((n + LANES - 1) // LANES) * LANES


def _pad_to_rows(x: jax.Array, fill: float) -> jax.Array:
    n = x.shape[0]
    p = padded_len(n)
    x = jnp.pad(x.astype(jnp.float32), (0, p - n), constant_values=fill)
    return x.reshape(-1, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def stoch_quant_pack(
    key: jax.Array, delta: jax.Array, b: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Flat (N,) delta/b -> packed (ceil(N/1024)*128,) uint8 codes."""
    if interpret is None:
        interpret = _interpret_default()
    n = delta.shape[0]
    d2 = _pad_to_rows(delta, 0.0)
    b2 = _pad_to_rows(jnp.broadcast_to(b, delta.shape), 0.0)
    u2 = jax.random.uniform(key, d2.shape, dtype=jnp.float32)
    packed = stoch_quant_pack_2d(d2, b2, u2, interpret=interpret)
    return packed.reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def bit_aggregate(
    packed: jax.Array, b: jax.Array, n: int, *, interpret: bool | None = None
) -> jax.Array:
    """packed (M, P) uint8 (P = padded_len(n)/8), b (n,) -> theta_hat (n,)."""
    if interpret is None:
        interpret = _interpret_default()
    b2 = _pad_to_rows(jnp.broadcast_to(b, (n,)), 0.0)
    theta2 = bit_aggregate_2d(packed, b2, interpret=interpret)
    return theta2.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def prox_sgd(
    w: jax.Array,
    w0: jax.Array,
    grad: jax.Array,
    momentum: jax.Array,
    eta: jax.Array,
    lam: jax.Array,
    mu: jax.Array,
    *,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Flat (N,) fused prox-SGD step; returns (w_new, momentum_new)."""
    if interpret is None:
        interpret = _interpret_default()
    n = w.shape[0]
    args = [_pad_to_rows(x, 0.0) for x in (w, w0, grad, momentum)]
    elm = jnp.stack(
        [jnp.asarray(eta, jnp.float32), jnp.asarray(lam, jnp.float32),
         jnp.asarray(mu, jnp.float32)]
    )
    w2, m2 = prox_sgd_2d(*args, elm, interpret=interpret)
    return w2.reshape(-1)[:n], m2.reshape(-1)[:n]
