"""Differential-privacy accounting for PRoBit+ (paper Theorem 3).

The compressor of Eq. 5 is itself a local randomizer. Theorem 3 proves the
mechanism is ``(eps, 0)``-DP per round when the public range satisfies::

    b_i >= max_m |delta_i^m| + (1 + 1/eps) * Delta_1

where ``Delta_1`` is the l1-sensitivity of the local update (the paper uses
``Delta_1 = 0.02 * eta``). This module provides the b-floor, an empirical
privacy-loss check used by tests, and the per-round composition math; the
stateful cross-round bookkeeping lives in :mod:`repro.core.ledger`
(:class:`~repro.core.ledger.PrivacyLedger`).

Subsampling assumptions (amplification)
---------------------------------------
Theorem 3's guarantee is *per participating client per round*. Under
partial participation the server runs the round on a random cohort, and
the round's **release** (the aggregated estimate) enjoys amplification by
subsampling: a client included only with probability ``q`` suffers
``eps' = ln(1 + q * (e^eps - 1)) < eps``. The pure-DP amplification bound
holds for either sampling model:

* **Poisson sampling** — each client tossed in independently with
  probability ``q`` (the textbook amplification setting);
* **without-replacement sampling** — a uniform ``m``-subset of the ``M``
  clients, ``q = m / M``. This is what the runtime does
  (``jax.random.choice(..., replace=False)`` over ``m_clients``), and it
  qualifies for the same pure-eps bound: under replace-one adjacency the
  challenge client is in the cohort with probability exactly ``q``, and
  conditioned on exclusion the release distribution is unchanged, which
  is all the two-point mixture argument needs.

The amplified eps is what :class:`~repro.core.ledger.PrivacyLedger`
composes under its ``subsampled`` accountant; ``q = 1`` reproduces the
unamplified per-round eps bit-identically.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .quantizer import WIRE_BITS, binarize_prob, level_probs

__all__ = [
    "DPConfig",
    "DELTA_SLACK",
    "dp_b_floor",
    "rr_gamma",
    "privacy_loss",
    "basic_composition",
    "strong_composition",
    "advanced_composition",
    "rounds_for_budget",
]

# Default failure probability spent by the advanced (DRV) accountant —
# shared by advanced_composition, rounds_for_budget, and the ledger.
DELTA_SLACK = 1e-5

# Clamps for the empirical log-likelihood ratio: keep privacy_loss finite
# when a coordinate sits on the public range (|delta| == b, where Eq. 5's
# probability is exactly 0 or 1 and the log diverges). Chosen at the edges
# of the float32 probability grid so NO interior value is altered: the
# f32 Eq.-5 map produces no nonzero probability below 2^-25 and no value
# strictly between 1 - 2^-24 and 1, so clipping to [_P_MIN, _P_MAX] bites
# only at the deterministic endpoints (and at interior deltas so close to
# b that f32 rounding already collapsed their probability onto 0/1 —
# those get the same finite sentinel, an over- not under-report).
_P_MIN = 2.0**-25
_P_MAX = 1.0 - 2.0**-24


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Per-round local-DP requirement.

    ``epsilon <= 0`` disables privacy (b-floor reduces to max |delta|).
    """

    epsilon: float = 0.1
    l1_sensitivity: float = 2e-4  # paper: 0.02 * eta with eta = 0.01

    @property
    def enabled(self) -> bool:
        return self.epsilon > 0


def dp_b_floor(delta_abs_max: jax.Array, cfg: DPConfig) -> jax.Array:
    """Smallest ``b`` satisfying Theorem 3 given ``max_m |delta_i^m]``."""
    if not cfg.enabled:
        return delta_abs_max
    margin = (1.0 + 1.0 / cfg.epsilon) * cfg.l1_sensitivity
    return delta_abs_max + margin


def rr_gamma(
    epsilon: float | jax.Array,
    l1_sensitivity: float | jax.Array,
    b: jax.Array,
    bits: int,
) -> jax.Array:
    """Uniform-mixing weight of the L-level randomized-response wire.

    The one-bit mechanism earns pure (eps, 0)-DP from the b-floor margin
    alone; stochastic rounding onto ``L = 2**k > 2`` levels does *not* —
    two adjacent updates can put probability 0 vs > 0 on the same level,
    so the raw likelihood ratio diverges. The k-bit wire therefore mixes
    in classical L-level randomized response: with probability ``gamma``
    the emitted level is replaced by a uniform draw over all L levels
    (whose grid mean is 0, so the server debias is a ``1/(1-gamma)``
    rescale). Every outcome then has probability ``>= gamma/L`` and the
    per-coordinate log-ratio is bounded by
    ``(1-gamma)/(gamma/L) * |delta_a - delta_b| / step`` (the adjacent
    -level probabilities are 1-Lipschitz in the grid position). Summing
    under the l1-sensitivity budget ``||delta_a - delta_b||_1 <= Delta_1``
    and solving ``(1-gamma)/gamma * L * Delta_1 / step = eps`` gives::

        gamma = L * Delta_1 / (L * Delta_1 + eps * step),  step = 2b/(L-1)

    which the tests certify empirically via :func:`privacy_loss`. The
    (eps, 0) guarantee is per round exactly as at k = 1, so all four
    ledger accountants compose unchanged.
    """
    if bits not in WIRE_BITS:
        raise ValueError(f"bits must be one of {WIRE_BITS}, got {bits}")
    n_levels = 1 << bits
    b = jnp.asarray(b, jnp.float32)
    step = 2.0 * b / (n_levels - 1)
    num = n_levels * jnp.asarray(l1_sensitivity, jnp.float32)
    return num / (num + jnp.asarray(epsilon, jnp.float32) * jnp.maximum(step, 1e-30))


def privacy_loss(
    delta_a: jax.Array,
    delta_b: jax.Array,
    b: jax.Array,
    *,
    bits: int = 1,
    gamma: jax.Array | None = None,
) -> jax.Array:
    """Worst-case total log-likelihood ratio between two adjacent updates.

    For each coordinate the loss is ``|ln P(c|delta_a) - ln P(c|delta_b)|``
    maximized over the outcome ``c``; summed over coordinates. Tests assert
    this is ``<= eps`` whenever ``b`` respects :func:`dp_b_floor` and
    ``||delta_a - delta_b||_1 <= Delta_1``.

    Boundary coordinates — ``|delta| == b`` exactly, where Eq. 5 emits a
    deterministic bit (probability 0 or 1) — would make the raw log ratio
    ``inf``/NaN. The probabilities are clamped to ``[_P_MIN, _P_MAX]``
    before the logs, so the returned loss is finite for every
    ``delta in [-b, b]`` *including the endpoints* (a large-but-finite
    sentinel of ``~ln(1/_P_MIN)`` per boundary coordinate rather than a
    diverging one). The clamps sit exactly on the edges of the float32
    probability grid (see their definition), so every probability the
    compressor can actually realize strictly inside (0, 1) passes through
    untouched — interior losses are reported exactly, never shrunk.

    ``bits > 1`` evaluates the k-bit wire's L-level mechanism instead: the
    outcome distribution is the adjacent-level tent
    (:func:`repro.core.quantizer.level_probs`), mixed with the uniform
    level distribution when ``gamma`` (from :func:`rr_gamma`) is given —
    the randomized-response wire, whose every outcome probability is
    ``>= gamma/L`` and whose loss the mixing provably caps at eps. With
    ``bits > 1`` and ``gamma=None`` the raw (non-private) rounding
    distribution is measured under the same clamps; zero-probability
    levels then report the finite ``ln(_P_MAX/_P_MIN)`` sentinel rather
    than infinity.
    """
    if bits == 1 and gamma is None:
        pa = jnp.clip(binarize_prob(delta_a, b), _P_MIN, _P_MAX)
        pb = jnp.clip(binarize_prob(delta_b, b), _P_MIN, _P_MAX)
        loss_plus = jnp.abs(jnp.log(pa) - jnp.log(pb))
        loss_minus = jnp.abs(jnp.log1p(-pa) - jnp.log1p(-pb))
        return jnp.sum(jnp.maximum(loss_plus, loss_minus))
    qa = level_probs(delta_a, b, bits)  # (L,) + delta.shape
    qb = level_probs(delta_b, b, bits)
    if gamma is None:
        pa = jnp.clip(qa, _P_MIN, _P_MAX)
        pb = jnp.clip(qb, _P_MIN, _P_MAX)
    else:
        mix = jnp.asarray(gamma, jnp.float32) / (1 << bits)
        pa = (1.0 - jnp.asarray(gamma, jnp.float32)) * qa + mix
        pb = (1.0 - jnp.asarray(gamma, jnp.float32)) * qb + mix
    llr = jnp.abs(jnp.log(pa) - jnp.log(pb))
    return jnp.sum(jnp.max(llr, axis=0))


def basic_composition(eps_per_round: float, rounds: int) -> float:
    """Basic sequential composition across ``rounds`` (paper notes advanced
    composition / moments accountant are also applicable)."""
    return eps_per_round * rounds


def strong_composition(eps_sq_sum, linear_sum, delta_slack: float):
    """The Dwork-Rothblum-Vadhan kernel shared by every advanced-composition
    call site (:func:`advanced_composition`, the ledger's event-log
    ``compose`` and closed-form ``trajectory``)::

        eps' = sqrt(2 ln(1/delta') * sum_t eps_t^2)
               + sum_t eps_t * (e^{eps_t} - 1)

    Takes the two sufficient statistics (scalars or numpy arrays) so the
    heterogeneous, homogeneous, and vectorized callers all evaluate the
    identical expression — one future correction fixes all of them.
    """
    return np.sqrt(2.0 * math.log(1.0 / delta_slack) * eps_sq_sum) + linear_sum


def advanced_composition(
    eps_per_round: float, rounds: int, delta_slack: float = DELTA_SLACK
) -> tuple[float, float]:
    """Strong composition [Dwork-Rothblum-Vadhan]: T rounds of (eps,0)-DP
    give (eps', delta')-DP with::

        eps' = sqrt(2 T ln(1/delta')) * eps + T * eps * (e^eps - 1)

    Returns (eps_total, delta_slack). Beats basic composition whenever
    T > 2 ln(1/delta') / eps^2 is NOT yet reached — i.e. for the small
    per-round eps this system runs (0.1 and below), advanced composition
    is the right multi-round accountant.

    Degenerate input: ``rounds <= 0`` reports exactly ``(0, 0)`` —
    composing zero mechanisms spends neither eps nor the delta slack
    (identical to the ledger's empty event log).
    """
    if rounds <= 0:
        return 0.0, 0.0
    eps = eps_per_round
    eps_total = float(
        strong_composition(
            rounds * (eps * eps), rounds * (eps * math.expm1(eps)), delta_slack
        )
    )
    return eps_total, delta_slack


def rounds_for_budget(
    eps_budget: float, eps_per_round: float, delta_slack: float = DELTA_SLACK
) -> int:
    """Largest T such that advanced composition stays within eps_budget.

    Returns 0 when even a single round exceeds the budget (the previous
    implementation could only count up from 1, silently reporting one
    affordable round for arbitrarily small budgets). A budget exactly at
    the T-round cost returns T. ``eps_per_round <= 0`` (DP disabled) is
    rejected: every horizon is free, so "largest affordable T" has no
    answer — and the previous code spun the search loop to its 10M cap.
    """
    if eps_per_round <= 0.0:
        raise ValueError(
            f"eps_per_round must be > 0, got {eps_per_round} (with DP "
            "disabled every budget allows unboundedly many rounds)"
        )
    if advanced_composition(eps_per_round, 1, delta_slack)[0] > eps_budget:
        return 0
    t = 1
    while advanced_composition(eps_per_round, t + 1, delta_slack)[0] <= eps_budget:
        t += 1
        if t > 10_000_000:
            break
    return t
