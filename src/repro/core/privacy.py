"""Differential-privacy accounting for PRoBit+ (paper Theorem 3).

The compressor of Eq. 5 is itself a local randomizer. Theorem 3 proves the
mechanism is ``(eps, 0)``-DP per round when the public range satisfies::

    b_i >= max_m |delta_i^m| + (1 + 1/eps) * Delta_1

where ``Delta_1`` is the l1-sensitivity of the local update (the paper uses
``Delta_1 = 0.02 * eta``). This module provides the b-floor, an empirical
privacy-loss check used by tests, and simple composition helpers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .quantizer import binarize_prob

__all__ = ["DPConfig", "dp_b_floor", "privacy_loss", "basic_composition"]


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Per-round local-DP requirement.

    ``epsilon <= 0`` disables privacy (b-floor reduces to max |delta|).
    """

    epsilon: float = 0.1
    l1_sensitivity: float = 2e-4  # paper: 0.02 * eta with eta = 0.01

    @property
    def enabled(self) -> bool:
        return self.epsilon > 0


def dp_b_floor(delta_abs_max: jax.Array, cfg: DPConfig) -> jax.Array:
    """Smallest ``b`` satisfying Theorem 3 given ``max_m |delta_i^m]``."""
    if not cfg.enabled:
        return delta_abs_max
    margin = (1.0 + 1.0 / cfg.epsilon) * cfg.l1_sensitivity
    return delta_abs_max + margin


def privacy_loss(
    delta_a: jax.Array, delta_b: jax.Array, b: jax.Array
) -> jax.Array:
    """Worst-case total log-likelihood ratio between two adjacent updates.

    For each coordinate the loss is ``|ln P(c|delta_a) - ln P(c|delta_b)|``
    maximized over the outcome ``c``; summed over coordinates. Tests assert
    this is ``<= eps`` whenever ``b`` respects :func:`dp_b_floor` and
    ``||delta_a - delta_b||_1 <= Delta_1``.
    """
    pa = binarize_prob(delta_a, b)
    pb = binarize_prob(delta_b, b)
    loss_plus = jnp.abs(jnp.log(pa) - jnp.log(pb))
    loss_minus = jnp.abs(jnp.log1p(-pa) - jnp.log1p(-pb))
    return jnp.sum(jnp.maximum(loss_plus, loss_minus))


def basic_composition(eps_per_round: float, rounds: int) -> float:
    """Basic sequential composition across ``rounds`` (paper notes advanced
    composition / moments accountant are also applicable)."""
    return eps_per_round * rounds


def advanced_composition(
    eps_per_round: float, rounds: int, delta_slack: float = 1e-5
) -> tuple[float, float]:
    """Strong composition [Dwork-Rothblum-Vadhan]: T rounds of (eps,0)-DP
    give (eps', delta')-DP with::

        eps' = sqrt(2 T ln(1/delta')) * eps + T * eps * (e^eps - 1)

    Returns (eps_total, delta_slack). Beats basic composition whenever
    T > 2 ln(1/delta') / eps^2 is NOT yet reached — i.e. for the small
    per-round eps this system runs (0.1 and below), advanced composition
    is the right multi-round accountant.
    """
    import math

    eps = eps_per_round
    eps_total = math.sqrt(2.0 * rounds * math.log(1.0 / delta_slack)) * eps + (
        rounds * eps * (math.exp(eps) - 1.0)
    )
    return eps_total, delta_slack


def rounds_for_budget(
    eps_budget: float, eps_per_round: float, delta_slack: float = 1e-5
) -> int:
    """Largest T such that advanced composition stays within eps_budget."""
    t = 1
    while advanced_composition(eps_per_round, t + 1, delta_slack)[0] <= eps_budget:
        t += 1
        if t > 10_000_000:
            break
    return t
