"""Dynamic quantization-range controller for ``b`` (paper §VI-B).

Each client uploads ONE extra bit per round: +1 if its local loss decreased
during local training, -1 otherwise. The server majority-votes; on overall
progress ``b`` is multiplied by ``up`` (paper: 1.01), on regression by
``down`` (paper: 0.98). ``b`` starts at 0.01 elementwise.

The controller also supports the two non-adaptive settings used in the
paper's Fig. 3 ablation: ``fixed`` (b frozen at init) and ``oracle``
(b_i = max_m |delta_i^m| + DP margin — requires omniscient clients, the
upper bound of achievable performance).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .privacy import DPConfig, dp_b_floor

__all__ = [
    "BControlConfig",
    "BState",
    "init_b_state",
    "loss_bit",
    "update_b",
    "update_b_from_vote",
    "oracle_b",
]


@dataclasses.dataclass(frozen=True)
class BControlConfig:
    mode: str = "dynamic"  # dynamic | fixed | oracle
    init: float = 0.01
    up: float = 1.01
    down: float = 0.98


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BState:
    """Scalar controller state (b is isotropic in the paper's experiments;
    a per-coordinate vector is materialized at quantization time)."""

    b: jax.Array  # scalar f32
    prev_vote: jax.Array  # last majority vote, for logging


def init_b_state(cfg: BControlConfig) -> BState:
    return BState(b=jnp.float32(cfg.init), prev_vote=jnp.float32(0.0))


def loss_bit(loss_before: jax.Array, loss_after: jax.Array) -> jax.Array:
    """The one-bit training signal a client uploads: +1 = loss decreased."""
    return jnp.where(loss_after < loss_before, jnp.int8(1), jnp.int8(-1))


def update_b(
    state: BState,
    bits: jax.Array,
    cfg: BControlConfig,
    weights: jax.Array | None = None,
) -> BState:
    """Majority-vote the loss bits and rescale b (jit-safe).

    ``weights`` (one per bit) restricts the vote to a weighted sub-cohort —
    the campaign engine's fused heterogeneous-M groups pass the 0/1
    active-client mask so padded clients cast no vote. A float sum of
    masked ±1 bits is exact below 2**24, so the masked vote equals the
    unpadded integer vote value-for-value.
    """
    votes = bits.astype(jnp.float32)
    if weights is not None:
        votes = votes * weights
    return update_b_from_vote(state, jnp.sum(votes), cfg)


def update_b_from_vote(
    state: BState, vote: jax.Array, cfg: BControlConfig
) -> BState:
    """Rescale ``b`` from an already-summed (possibly weighted) vote.

    The streaming round accumulates ``sum_m w_m bit_m`` chunk by chunk —
    the vote is additive over clients like the Eq.-13 counts — and feeds
    the total here; :func:`update_b` is the one-shot composition.
    """
    factor = jnp.where(vote > 0, cfg.up, cfg.down)
    if cfg.mode == "fixed":
        factor = jnp.float32(1.0)
    return BState(b=state.b * factor, prev_vote=vote)


def oracle_b(updates: jax.Array, dp: DPConfig) -> jax.Array:
    """Omniscient per-coordinate optimum: max_m |delta_i^m| + DP margin."""
    return dp_b_floor(jnp.max(jnp.abs(updates), axis=0), dp)
