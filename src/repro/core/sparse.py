"""Top-k sparse PRoBit+ — the paper's stated future work ("partial network
updates"), implemented as a beyond-paper extension.

Each client uploads bits only for the ``k`` coordinates of largest
|delta| (plus their indices). In the aggregation pipeline this is the
``SparseWire`` format: the ``ClientCompressor`` bit-packs the k codes and
``ProBitPlusServer`` routes them here (see ``core/aggregation.py``).
The server forms the per-coordinate ML estimate with a per-coordinate
client count::

    theta_hat_i = (2 N_i - M_i) / M_i * b_i     (M_i = #clients reporting i)

which reduces to Eq. 13 when k = d. Wire cost: k * (1 bit + log2(d) index
bits) vs d bits — a win below k/d ≈ 1/(1+log2 d).

Security notes (documented, enforced in the FL runtime):
  * Byzantine: magnitude immunity is preserved (bits are still ±1), but a
    malicious client can CONCENTRATE its 2b/M-per-coordinate influence on
    k chosen coordinates — the Thm-2 bound becomes 2 beta ||b_S|| over the
    attacked support. Same order for k = Theta(d), worse for tiny k.
  * DP: the index set is data-dependent; releasing it breaks pure
    (eps,0)-DP of the bit mechanism alone. The runtime therefore refuses
    topk_frac < 1 with dp_epsilon > 0 (a noisy-top-k selector is the
    standard fix and is left as future work, as in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantizer import binarize_prob

__all__ = ["topk_binarize", "sparse_aggregate"]


def topk_binarize(
    key: jax.Array, delta: jax.Array, b: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Returns (indices (k,) int32, codes (k,) int8) for one client."""
    mag = jnp.abs(delta)
    _, idx = jax.lax.top_k(mag, k)
    d_sel = jnp.take(delta, idx)
    b_sel = jnp.take(jnp.broadcast_to(b, delta.shape), idx)
    p = binarize_prob(d_sel, b_sel)
    u = jax.random.uniform(key, (k,), dtype=jnp.float32)
    codes = jnp.where(u < p, jnp.int8(1), jnp.int8(-1))
    return idx.astype(jnp.int32), codes


def sparse_aggregate(
    indices: jax.Array, codes: jax.Array, b: jax.Array, d: int
) -> jax.Array:
    """indices/codes: (M, k); returns theta_hat (d,).

    Per-coordinate ML estimate with varying client counts; coordinates no
    client reported stay at 0 (no update — the server cannot infer a sign
    it never observed).
    """
    m, k = indices.shape
    plus = jnp.zeros((d,), jnp.float32)
    count = jnp.zeros((d,), jnp.float32)
    ones = jnp.ones((m, k), jnp.float32)
    plus = plus.at[indices.reshape(-1)].add(
        (codes.reshape(-1) > 0).astype(jnp.float32)
    )
    count = count.at[indices.reshape(-1)].add(ones.reshape(-1))
    safe = jnp.maximum(count, 1.0)
    theta = (2.0 * plus - count) / safe * jnp.broadcast_to(b, (d,))
    return jnp.where(count > 0, theta, 0.0)
