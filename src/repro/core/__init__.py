"""PRoBit+ core: the paper's contribution as composable JAX modules."""

from .quantizer import (
    binarize_prob,
    stochastic_binarize,
    pack_bits,
    unpack_bits,
    codes_to_counts,
)
from .aggregation import (
    ml_estimate_from_counts,
    probit_plus_aggregate,
    probit_plus_from_updates,
    fedavg_aggregate,
    geometric_median,
    signsgd_mv_aggregate,
    rsa_aggregate,
    get_bit_aggregator,
    get_full_precision_aggregator,
)
from .privacy import DPConfig, dp_b_floor, privacy_loss, basic_composition
from .attacks import get_attack, ATTACKS, flip_codes
from .bcontrol import (
    BControlConfig,
    BState,
    init_b_state,
    loss_bit,
    update_b,
    oracle_b,
)

__all__ = [
    "binarize_prob",
    "stochastic_binarize",
    "pack_bits",
    "unpack_bits",
    "codes_to_counts",
    "ml_estimate_from_counts",
    "probit_plus_aggregate",
    "probit_plus_from_updates",
    "fedavg_aggregate",
    "geometric_median",
    "signsgd_mv_aggregate",
    "rsa_aggregate",
    "get_bit_aggregator",
    "get_full_precision_aggregator",
    "DPConfig",
    "dp_b_floor",
    "privacy_loss",
    "basic_composition",
    "get_attack",
    "ATTACKS",
    "flip_codes",
    "BControlConfig",
    "BState",
    "init_b_state",
    "loss_bit",
    "update_b",
    "oracle_b",
]
