"""Byzantine attacks from the paper's §VI-D, applied to stacked updates.

Each *delta-level* attack rewrites the *first* ``n_byz`` rows of the
``(M, d)`` update matrix (the FL runtime shuffles client order, so which
clients are Byzantine is immaterial). Attacks operate on the full-precision
update; bit-based schemes then compress the malicious update with the
honest quantizer — the clipping inside the compressor is exactly the
paper's amplitude immunity.

Beyond the paper's four attacks the registry carries two adaptive
adversaries from the Byzantine-ML literature (both colluding, both aware of
the honest updates):

* ``alie``  — "A Little Is Enough" [Baruch et al. 2019] variance attack:
  Byzantines upload ``mean - z * std`` of the honest updates, with ``z``
  the breakdown-point normal quantile implied by the (cohort size,
  Byzantine count) pair (:func:`alie_z`) — the largest perturbation that
  still hides inside the honest spread for a majority-based defense.
* ``ipm``   — inner-product manipulation [Xie et al. 2020]: Byzantines
  upload a negatively scaled honest mean, targeting
  ``<aggregate, true mean> < 0``.

A Byzantine client in a bit scheme may also ignore the quantizer and put
arbitrary bits on the wire. ``bit_flip`` is that adversary as a
first-class attack: it is a no-op at the delta level and instead inverts
the first ``n_byz`` clients' *post-quantization* codes on the packed wire
(:func:`flip_wire`, applied inside
:meth:`repro.core.AggregatorPipeline.__call__`). For dense wires the
analogue is row negation. ``flip_codes`` remains the unpacked-codes helper
used by the Theorem-2 tests.

Buffered-asynchronous rounds add a third adversarial axis — *timing*. The
``straggler`` adversary withholds Byzantine uploads: a (colluding)
Byzantine client delivers into the server's staleness buffer only while
its slot holds no Byzantine upload and then the cohort never refreshes,
so the poisoned upload sits in the buffer at ever-growing age (and is
re-delivered to re-poison the slot if a slot-sharing honest client
evicts it under ``async_buffer < n_active`` contention). Against a uniform staleness weighting (decay 0) that
frozen vote keeps full weight while honest votes track the moving model —
the timing analogue of a fixed-point poisoning attack. ``straggler``
composes with any payload via ``"straggler+<name>"`` (e.g.
``straggler+sign_flip``, ``straggler+alie``): the payload shapes *what*
the Byzantine rows upload, straggler shapes *when* it arrives
(:func:`parse_attack` splits the two stages; the timing gate is traced,
so straggler and prompt cells share one vmapped campaign program).

``ATTACK_IDS`` fixes an integer id per delta-level attack so a whole
scenario axis of attacks can be a *traced* value: :func:`apply_attack`
dispatches via ``lax.switch``, which is what lets the campaign engine
(``repro.sim``) vmap cells that differ only in the attack.
"""

from __future__ import annotations

import dataclasses
import functools
import statistics
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "alie_z",
    "get_attack",
    "ATTACKS",
    "ATTACK_IDS",
    "WIRE_ATTACKS",
    "TIMING_ATTACKS",
    "attack_id",
    "is_wire_attack",
    "is_timing_attack",
    "parse_attack",
    "available_attacks",
    "apply_attack",
    "apply_attack_stream",
    "STREAM_ATTACKS",
    "flip_codes",
    "flip_wire",
    "flip_wire_rows",
    "EDGE_ATTACK_IDS",
    "edge_attack_id",
    "apply_edge_attack",
]


def _no_attack(key, updates, n_byz):
    return updates


def _gaussian(key, updates, n_byz):
    """Each Byzantine uploads i.i.d. N(0, 100) (sigma = 10)."""
    noise = 10.0 * jax.random.normal(key, updates[:n_byz].shape, updates.dtype)
    return updates.at[:n_byz].set(noise)


def _sign_flip(key, updates, n_byz):
    """Scale the honest update by -5."""
    return updates.at[:n_byz].set(-5.0 * updates[:n_byz])


def _zero_gradient(key, updates, n_byz):
    """Colluding: all Byzantine send the same value making the sum zero."""
    honest_sum = jnp.sum(updates[n_byz:], axis=0)
    z = -honest_sum / jnp.maximum(n_byz, 1)
    return updates.at[:n_byz].set(jnp.broadcast_to(z, updates[:n_byz].shape))


def _sample_duplicate(key, updates, n_byz):
    """Every Byzantine replicates the first honest client's update."""
    return updates.at[:n_byz].set(jnp.broadcast_to(updates[n_byz], updates[:n_byz].shape))


@functools.lru_cache(maxsize=None)
def alie_z(n: int, n_byz: int) -> float:
    """The ALIE perturbation size ``z`` from the breakdown-point quantile.

    Baruch et al. (2019) pick the largest ``z`` such that the malicious
    update ``mean - z * std`` still looks like a plausible honest worker to
    a majority-based defense: with ``n`` workers of which ``m = n_byz``
    collude, the attackers need ``s = floor(n/2 + 1) - m`` honest
    *supporters* (workers even further from the mean than the attack
    point) to hide inside the majority, giving::

        z = Phi^{-1}((n - m - s) / (n - m))

    where ``Phi`` is the standard normal CDF. The quantile is clamped to
    ``[1/2, 1)`` — a ratio below 1/2 means the Byzantine cohort cannot
    recruit a majority at any non-negative ``z`` (the breakdown point is
    not reached), so the attack degrades to uploading the honest mean
    (``z = 0``), and ``n_byz = 0`` trivially maps there too.

    Both arguments are static shapes, so the quantile is evaluated on the
    host (stdlib ``NormalDist``) and folds into the trace as a constant —
    an (M, byz_frac) campaign axis still vmaps, each cohort size compiling
    with its own pinned ``z``.
    """
    if n_byz <= 0 or n - n_byz <= 0:
        return 0.0
    s = n // 2 + 1 - n_byz
    frac = (n - n_byz - s) / (n - n_byz)
    if frac <= 0.5:
        return 0.0
    frac = min(frac, 1.0 - 1e-9)
    return float(statistics.NormalDist().inv_cdf(frac))


def _alie(key, updates, n_byz):
    """ALIE variance attack [Baruch et al. 2019]: ``mean - z * std`` of the
    honest updates, with ``z`` the breakdown-point quantile implied by the
    (cohort size, Byzantine count) pair — see :func:`alie_z`."""
    honest = updates[n_byz:]
    mu = jnp.mean(honest, axis=0)
    sigma = jnp.std(honest, axis=0)
    evil = mu - alie_z(updates.shape[0], n_byz) * sigma
    return updates.at[:n_byz].set(jnp.broadcast_to(evil, updates[:n_byz].shape))


def _ipm(key, updates, n_byz):
    """Inner-product manipulation: negatively scaled honest mean."""
    mu = jnp.mean(updates[n_byz:], axis=0)
    return updates.at[:n_byz].set(jnp.broadcast_to(-1.1 * mu, updates[:n_byz].shape))


# Delta-level registry. Order of ATTACK_IDS is the lax.switch branch order
# and therefore part of the campaign wire format — append, never reorder.
ATTACK_IDS: tuple[str, ...] = (
    "none",
    "gaussian",
    "sign_flip",
    "zero_gradient",
    "sample_duplicate",
    "alie",
    "ipm",
)

ATTACKS: dict[str, Callable] = {
    "none": _no_attack,
    "gaussian": _gaussian,
    "sign_flip": _sign_flip,
    "zero_gradient": _zero_gradient,
    "sample_duplicate": _sample_duplicate,
    "alie": _alie,
    "ipm": _ipm,
    # wire-level: delta stage is a no-op; the pipeline flips packed codes
    "bit_flip": _no_attack,
}

# Attacks that act after quantization, on the wire (see flip_wire).
WIRE_ATTACKS: frozenset[str] = frozenset({"bit_flip"})

# Attacks on *when* uploads arrive rather than what they contain; only
# meaningful in buffered-asynchronous rounds (FLConfig.async_buffer > 0).
TIMING_ATTACKS: frozenset[str] = frozenset({"straggler"})

_TIMING_PREFIX = "straggler+"


def parse_attack(name: str) -> tuple[str, bool]:
    """Split an attack name into ``(payload, straggler)`` stages.

    ``"straggler"`` is a pure timing adversary (payload ``"none"``);
    ``"straggler+<payload>"`` composes the timing stage with any delta- or
    wire-level payload from :data:`ATTACKS`. Raises ``ValueError`` on an
    unknown payload so config validation gets a precise message.
    """
    if name in TIMING_ATTACKS:
        return "none", True
    if name.startswith(_TIMING_PREFIX):
        payload = name[len(_TIMING_PREFIX):]
        if payload == "none" or payload not in ATTACKS:
            # "straggler+none" is rejected so the accepted grammar matches
            # available_attacks(); the payload-free spelling is "straggler"
            raise ValueError(
                f"unknown straggler payload {payload!r}; "
                f"available: {tuple(sorted(set(ATTACKS) - {'none'}))} "
                "(for a payload-free timing adversary use 'straggler')"
            )
        return payload, True
    if name not in ATTACKS:
        raise ValueError(
            f"unknown attack {name!r}; available: {available_attacks()}"
        )
    return name, False


def available_attacks() -> tuple[str, ...]:
    """All accepted attack names, including straggler compositions."""
    return tuple(sorted(ATTACKS)) + tuple(sorted(TIMING_ATTACKS)) + tuple(
        _TIMING_PREFIX + p for p in sorted(ATTACKS) if p != "none"
    )


def get_attack(name: str) -> Callable:
    """Return the *delta-level* ``attack(key, updates(M,d), n_byz) -> updates``.

    For wire-level attacks (``bit_flip``) this is the identity; the bit
    inversion happens inside the aggregation pipeline. For straggler
    compositions this is the payload's delta stage.
    """
    payload, _ = parse_attack(name)
    return ATTACKS["none" if payload in WIRE_ATTACKS else payload]


def attack_id(name: str) -> int:
    """Integer id of the delta-level stage of ``name`` (lax.switch index)."""
    payload, _ = parse_attack(name)
    return ATTACK_IDS.index("none" if payload in WIRE_ATTACKS else payload)


def is_wire_attack(name: str) -> bool:
    return parse_attack(name)[0] in WIRE_ATTACKS


def is_timing_attack(name: str) -> bool:
    return parse_attack(name)[1]


def apply_attack(idx: jax.Array, key: jax.Array, updates: jax.Array, n_byz: int) -> jax.Array:
    """Delta-level attack dispatch over a (possibly traced) attack id.

    With a concrete ``idx`` this computes exactly
    ``ATTACKS[ATTACK_IDS[idx]](key, updates, n_byz)``; with a traced one it
    lowers to ``lax.switch`` so an attack axis can ride a vmapped campaign
    cell batch. ``n_byz`` stays static (it shapes the ``.at[:n]`` updates).
    """
    branches = [
        (lambda k, u, _f=ATTACKS[name]: _f(k, u, n_byz)) for name in ATTACK_IDS
    ]
    return jax.lax.switch(idx, branches, key, updates)


# Attacks whose Byzantine rewrite depends only on the row's own update and
# its cohort position — the streamable subset. Colluding attacks
# (zero_gradient, sample_duplicate, alie, ipm) read the *whole* honest
# cohort to craft their payload and therefore cannot run under a
# client-chunk scan; FLConfig validation rejects them when
# ``client_chunk > 0`` with ``byz_frac > 0``.
STREAM_ATTACKS: frozenset[str] = frozenset(
    {"none", "gaussian", "sign_flip", "bit_flip"}
)


def apply_attack_stream(
    idx: jax.Array,
    key: jax.Array,
    updates: jax.Array,
    byz_mask: jax.Array,
    row_ids: jax.Array,
) -> jax.Array:
    """Chunk-local delta-level attack dispatch for the streaming round.

    ``updates`` is one ``(C, d)`` client chunk; ``byz_mask`` marks which of
    its rows are Byzantine (in the dense round those are the first
    ``n_byz`` cohort rows, here ``row_ids < n_byz``); ``row_ids`` are the
    rows' global cohort positions. Branch order follows
    :data:`ATTACK_IDS` so the same traced attack id drives both paths.

    Parity with :func:`apply_attack`:

    * ``none`` / ``sign_flip`` — value-identical (row-local rewrites).
    * ``gaussian`` — per-row noise keyed by ``fold_in(key, row_id)`` so the
      draw is *chunk-invariant* (any chunking of the same cohort produces
      the same noise) but a different sample than the dense path's single
      blocked ``normal(key, (n_byz, d))`` draw — same N(0, 100)
      distribution, so statistical suites agree while bit-level parity is
      asserted stream-vs-stream.
    * colluding ids — identity here; excluded by config validation.
    """
    d = updates.shape[1]

    def _identity(k, u):
        return u

    def _gauss_stream(k, u):
        noise = 10.0 * jax.vmap(
            lambda r: jax.random.normal(jax.random.fold_in(k, r), (d,), u.dtype)
        )(row_ids)
        return jnp.where(byz_mask[:, None], noise, u)

    def _sign_flip_stream(k, u):
        return jnp.where(byz_mask[:, None], -5.0 * u, u)

    branch_map = {
        "gaussian": _gauss_stream,
        "sign_flip": _sign_flip_stream,
    }
    branches = [branch_map.get(name, _identity) for name in ATTACK_IDS]
    return jax.lax.switch(idx, branches, key, updates)


# -- Byzantine *edge aggregators* (hierarchical tree rounds) ---------------
#
# The tree topology (fl/hierarchy.py) introduces a new adversary class per
# Egger & Bitar (arxiv 2506.09870): a compromised *edge node* that honestly
# collected its clients' one-bit codes but ships a corrupted count tensor
# to the root. Unlike client attacks, an edge attack rewrites an entire
# (8 * p_bytes,) vote-count vector at once — one bad edge speaks with the
# weight of its whole client slice. All three adversaries preserve the
# count invariant 0 <= N_i <= mass (a root-side range check cannot detect
# them), which is what makes the robust rate-space merges in
# ``fl.hierarchy._root_merge`` necessary rather than simple sanitization:
#
# * ``edge_sign_flip`` — ships the per-plane complement ``mass - N``:
#   every client bit on the edge reads inverted, the count-space analogue
#   of the ``bit_flip`` wire adversary applied to the whole slice.
# * ``edge_inflate``  — saturates every count to the full active mass
#   (``N = mass``: "all my clients voted +1 on every coordinate"), driving
#   the Eq. 13 estimate to the +b corner.
# * ``edge_replay``   — re-ships the count tensor the root last buffered
#   for this edge's slot (stale-replay; falls back to the honest fresh
#   tensor while the slot is empty). The replayed tensor arrives as a
#   fresh delivery, so its staleness age resets — the timing analogue of
#   the ``straggler`` client adversary, freezing the edge's vote at an old
#   model. Requires a buffered tree (``FLConfig.edge_buffer > 0``).
#
# Like ATTACK_IDS, branch order is part of the dispatch contract:
# append, never reorder.
EDGE_ATTACK_IDS: tuple[str, ...] = (
    "none",
    "edge_sign_flip",
    "edge_inflate",
    "edge_replay",
)


def edge_attack_id(name: str) -> int:
    """Integer id of an edge-aggregator attack (lax.switch branch index)."""
    if name not in EDGE_ATTACK_IDS:
        raise ValueError(
            f"unknown edge attack {name!r}; available: {EDGE_ATTACK_IDS}"
        )
    return EDGE_ATTACK_IDS.index(name)


def apply_edge_attack(
    idx,
    counts: jax.Array,
    mass: jax.Array,
    prev_counts: jax.Array,
    prev_mass: jax.Array,
    prev_valid: jax.Array,
    byz_mask: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Rewrite Byzantine edges' shipped count tensors before the root merge.

    ``counts`` is the stacked ``(E, 8 * p_bytes)`` f32 per-edge vote counts
    and ``mass`` the ``(E,)`` per-edge active-mass scalars, both honest as
    produced by the edge scans; ``prev_*`` is what the root's buffer held
    for each edge's slot *before* this round's deliveries (zeros/invalid in
    unbuffered trees — config validation keeps ``edge_replay`` out of
    those). ``byz_mask`` marks the compromised edges (the first
    ``FLConfig.byz_edges`` rows, mirroring the client convention). Honest
    edges pass through bit-untouched; no attack alters the shipped *mass*
    (the adversaries forge votes, not cohort sizes — a mass forgery is
    root-detectable by cross-edge bookkeeping).
    """

    def _identity(c, m):
        return c, m

    def _sign_flip(c, m):
        return m[:, None] - c, m

    def _inflate(c, m):
        return jnp.broadcast_to(m[:, None], c.shape), m

    def _replay(c, m):
        return (
            jnp.where(prev_valid[:, None], prev_counts, c),
            jnp.where(prev_valid, prev_mass, m),
        )

    branch_map = {
        "edge_sign_flip": _sign_flip,
        "edge_inflate": _inflate,
        "edge_replay": _replay,
    }
    branches = [branch_map.get(name, _identity) for name in EDGE_ATTACK_IDS]
    c_att, m_att = jax.lax.switch(idx, branches, counts, mass)
    return (
        jnp.where(byz_mask[:, None], c_att, counts),
        jnp.where(byz_mask, m_att, mass),
    )


def flip_codes(codes: jax.Array, n_byz: int) -> jax.Array:
    """Worst-case bit adversary: invert the first ``n_byz`` clients' codes."""
    return codes.at[:n_byz].set(-codes[:n_byz])


def flip_wire(wire, n_byz: int):
    """:func:`flip_codes` on the wire itself — the ``bit_flip`` attack.

    Packed wires invert every bit of the first ``n_byz`` rows (bitwise NOT
    flips each ±1 code; pad bits flip too, but every consumer slices the
    estimate back to the true dimension, so they are inert). Dense wires
    negate the rows — the full-precision analogue of inverting every code.
    """
    from .aggregation import DenseWire

    if isinstance(wire, DenseWire):
        return DenseWire(updates=wire.updates.at[:n_byz].set(-wire.updates[:n_byz]))
    flipped = wire.packed.at[:n_byz].set(jnp.bitwise_not(wire.packed[:n_byz]))
    return dataclasses.replace(wire, packed=flipped)


def flip_wire_rows(wire, row_mask: jax.Array):
    """:func:`flip_wire` with a traced per-row Byzantine mask.

    The streaming round cannot use the static ``.at[:n_byz]`` slice — its
    chunk straddles the Byzantine/honest boundary at a traced offset — so
    membership arrives as a boolean mask over the chunk's rows.
    """
    from .aggregation import DenseWire

    if isinstance(wire, DenseWire):
        return DenseWire(
            updates=jnp.where(row_mask[:, None], -wire.updates, wire.updates)
        )
    flipped = jnp.where(
        row_mask[:, None], jnp.bitwise_not(wire.packed), wire.packed
    )
    return dataclasses.replace(wire, packed=flipped)
