"""Byzantine attacks from the paper's §VI-D, applied to stacked updates.

Each attack rewrites the *first* ``n_byz`` rows of the ``(M, d)`` update
matrix (the FL runtime shuffles client order, so which clients are Byzantine
is immaterial). Attacks operate on the full-precision update; bit-based
schemes then compress the malicious update with the honest quantizer — the
clipping inside the compressor is exactly the paper's amplitude immunity.
A Byzantine client in a bit scheme may also send arbitrary bits; the
``flip_codes`` helper models the strongest such adversary for tests.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["get_attack", "ATTACKS", "flip_codes"]


def _no_attack(key, updates, n_byz):
    return updates


def _gaussian(key, updates, n_byz):
    """Each Byzantine uploads i.i.d. N(0, 100) (sigma = 10)."""
    noise = 10.0 * jax.random.normal(key, updates[:n_byz].shape, updates.dtype)
    return updates.at[:n_byz].set(noise)


def _sign_flip(key, updates, n_byz):
    """Scale the honest update by -5."""
    return updates.at[:n_byz].set(-5.0 * updates[:n_byz])


def _zero_gradient(key, updates, n_byz):
    """Colluding: all Byzantine send the same value making the sum zero."""
    honest_sum = jnp.sum(updates[n_byz:], axis=0)
    z = -honest_sum / jnp.maximum(n_byz, 1)
    return updates.at[:n_byz].set(jnp.broadcast_to(z, updates[:n_byz].shape))


def _sample_duplicate(key, updates, n_byz):
    """Every Byzantine replicates the first honest client's update."""
    return updates.at[:n_byz].set(jnp.broadcast_to(updates[n_byz], updates[:n_byz].shape))


ATTACKS: dict[str, Callable] = {
    "none": _no_attack,
    "gaussian": _gaussian,
    "sign_flip": _sign_flip,
    "zero_gradient": _zero_gradient,
    "sample_duplicate": _sample_duplicate,
}


def get_attack(name: str) -> Callable:
    """Return ``attack(key, updates(M,d), n_byz) -> updates``."""
    return ATTACKS[name]


def flip_codes(codes: jax.Array, n_byz: int) -> jax.Array:
    """Worst-case bit adversary: invert the first ``n_byz`` clients' codes."""
    return codes.at[:n_byz].set(-codes[:n_byz])
