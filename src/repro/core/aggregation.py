"""Server-side aggregators: PRoBit+ (paper Eq. 13) and the paper's baselines.

Every aggregator shares the signature::

    theta_hat = aggregate(updates, **kw)          # updates: (M, d) float
or, for bit-based schemes::

    theta_hat = aggregate_codes(codes, b, **kw)   # codes: (M, d) int8 ±1

``d`` is the flattened model dimension (callers ravel the param pytree with
``jax.flatten_util.ravel_pytree``). All run under ``jax.jit``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .quantizer import codes_to_counts, stochastic_binarize

__all__ = [
    "ml_estimate_from_counts",
    "probit_plus_aggregate",
    "probit_plus_from_updates",
    "fedavg_aggregate",
    "geometric_median",
    "signsgd_mv_aggregate",
    "rsa_aggregate",
    "get_bit_aggregator",
    "get_full_precision_aggregator",
]


# ---------------------------------------------------------------------------
# PRoBit+
# ---------------------------------------------------------------------------

def ml_estimate_from_counts(counts: jax.Array, m: int, b: jax.Array) -> jax.Array:
    """Eq. 13: ``theta_hat_i = (2 N_i - M)/M * b_i``.

    This is the exact ML estimate of the mean parameter under the two-point
    likelihood (Eq. 12); it equals ``mean_m(c_i^m) * b_i``.
    """
    return (2.0 * counts.astype(jnp.float32) - m) / m * b


def probit_plus_aggregate(codes: jax.Array, b: jax.Array) -> jax.Array:
    """Aggregate client one-bit codes ``(M, d)`` into ``theta_hat (d,)``."""
    m = codes.shape[0]
    return ml_estimate_from_counts(codes_to_counts(codes), m, b)


def probit_plus_from_updates(
    key: jax.Array, updates: jax.Array, b: jax.Array
) -> jax.Array:
    """End-to-end reference path: quantize each client then ML-aggregate."""
    keys = jax.random.split(key, updates.shape[0])
    codes = jax.vmap(stochastic_binarize, in_axes=(0, 0, None))(keys, updates, b)
    return probit_plus_aggregate(codes, b)


# ---------------------------------------------------------------------------
# Full-precision baselines
# ---------------------------------------------------------------------------

def fedavg_aggregate(updates: jax.Array) -> jax.Array:
    """FedAvg: plain mean of the (M, d) client updates."""
    return jnp.mean(updates, axis=0)


def geometric_median(
    updates: jax.Array, iters: int = 16, eps: float = 1e-8
) -> jax.Array:
    """Fed-GM [Yin et al. 2018]: geometric median via Weiszfeld iterations.

    Smoothed Weiszfeld: weights ``1/max(||u_m - y||, eps)``; ``iters`` fixed
    steps under ``lax.fori_loop`` (convergence is geometric; 16 suffices for
    aggregation noise levels in the paper's regime).
    """
    y0 = jnp.mean(updates, axis=0)

    def body(_, y):
        dist = jnp.sqrt(jnp.sum((updates - y) ** 2, axis=-1) + eps)
        w = 1.0 / dist
        return jnp.sum(updates * w[:, None], axis=0) / jnp.sum(w)

    return jax.lax.fori_loop(0, iters, body, y0)


# ---------------------------------------------------------------------------
# Bit-based baselines (paper §VI-A)
# ---------------------------------------------------------------------------

def signsgd_mv_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """signSGD with Majority Vote [Bernstein et al. 2019].

    Clients upload ``sign(delta)``; the server takes the majority sign and
    applies a hand-tuned step size (paper sets 0.01). The manual step size is
    exactly the instability PRoBit+ removes.
    """
    vote = jnp.sign(jnp.sum(codes.astype(jnp.float32), axis=0))
    return step * vote


def rsa_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """RSA [Li et al. 2019] server step: accumulate client signs × step."""
    return step * jnp.sum(codes.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_BIT_AGGREGATORS: dict[str, Callable] = {
    "probit_plus": probit_plus_aggregate,
    "signsgd_mv": lambda codes, b, step=0.01: signsgd_mv_aggregate(codes, step),
    "rsa": lambda codes, b, step=0.01: rsa_aggregate(codes, step),
}

_FP_AGGREGATORS: dict[str, Callable] = {
    "fedavg": fedavg_aggregate,
    "fed_gm": geometric_median,
}


def get_bit_aggregator(name: str) -> Callable:
    return _BIT_AGGREGATORS[name]


def get_full_precision_aggregator(name: str) -> Callable:
    return _FP_AGGREGATORS[name]
