"""Aggregation pipeline: client compressors, server aggregators, registry.

Architecture — the packed-wire contract
=======================================

Every aggregation path in this repo (CPU simulation in ``fl/runtime.py``,
the Pallas kernels in ``kernels/``, the sharded mesh step in
``launch/fl_step.py``, and the microbenchmarks) speaks one protocol,
split into two halves joined by an explicit wire format:

``ClientCompressor``
    error feedback -> top-k selection -> stochastic binarize (Eq. 5) ->
    uint8 bit-pack. Emits one of three wire formats:

    * :class:`PackedWire` — the **canonical** format: an ``(M, d_pad/8)``
      uint8 matrix of LSB-first packed one-bit codes plus the public
      range vector ``b`` (d,). This is 1 bit/parameter on the wire — the
      paper's 32x upload saving vs f32, realized in memory traffic too
      because both producer and consumer work in d-chunks
      (:func:`repro.core.quantizer.packed_binarize_batch` /
      :func:`repro.core.quantizer.packed_counts`) and the dense (M, d)
      code tensor never materializes.
    * :class:`SparseWire` — top-k variant: per-client index sets plus
      packed codes (beyond-paper extension, see ``core/sparse.py``).
    * :class:`DenseWire` — full-precision passthrough for the FedAvg /
      Fed-GM baselines.

``ServerAggregator``
    unpack / vote-count -> estimate. For bit-based schemes the shared hot
    path is the chunked vote count ``N_i``; the per-scheme estimate is a
    pure function of ``(counts, M, b)``:

    * PRoBit+  : ``(2 N_i - M)/M * b_i``            (ML estimate, Eq. 13)
    * signSGD-MV: ``step * sign(2 N_i - M)``        [Bernstein et al. 2019]
    * RSA      : ``step * (2 N_i - M)``             [Li et al. 2019]

    FedAvg / Fed-GM consume :class:`DenseWire` directly.

An :class:`AggregatorPipeline` bundles one compressor with one server
aggregator; :func:`build_pipeline` resolves a registered name
("probit_plus" | "fedavg" | "fed_gm" | "signsgd_mv" | "rsa") into a
configured pipeline. ``use_kernels=True`` swaps PRoBit+'s two halves for
the fused Pallas kernels (``kernels/stoch_quant.py`` client-side,
``kernels/bit_aggregate.py`` server-side; interpret mode on CPU) — same
wire, same estimate, different engine.

The standalone functions below (``probit_plus_aggregate`` etc.) remain
the mathematical reference implementations the pipelines and tests are
validated against.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

from .privacy import DPConfig
from .quantizer import (
    PACK_CHUNK,
    codes_to_counts,
    packed_binarize_batch,
    packed_counts,
    packed_sign_batch,
    packed_weighted_counts,
    padded_dim,
    stochastic_binarize,
    binarize_prob,
)

__all__ = [
    "ml_estimate_from_counts",
    "staleness_weights",
    "probit_plus_aggregate",
    "probit_plus_from_updates",
    "fedavg_aggregate",
    "geometric_median",
    "signsgd_mv_aggregate",
    "rsa_aggregate",
    "PackedWire",
    "SparseWire",
    "DenseWire",
    "ClientCompressor",
    "ServerAggregator",
    "AggregatorPipeline",
    "build_pipeline",
    "available_aggregators",
]


# ---------------------------------------------------------------------------
# PRoBit+ reference math
# ---------------------------------------------------------------------------

def ml_estimate_from_counts(counts: jax.Array, m: int, b: jax.Array) -> jax.Array:
    """Eq. 13: ``theta_hat_i = (2 N_i - M)/M * b_i``.

    This is the exact ML estimate of the mean parameter under the two-point
    likelihood (Eq. 12); it equals ``mean_m(c_i^m) * b_i``.
    """
    return (2.0 * counts.astype(jnp.float32) - m) / m * b


def staleness_weights(
    ages: jax.Array, decay: jax.Array, valid: jax.Array | None = None
) -> jax.Array:
    """Polynomial staleness discount ``w(age) = (1 + age) ** (-decay)``.

    The weight an asynchronous server gives a buffered upload that is
    ``age`` rounds old (FedBuff-style; ``decay = 0.5`` is the classical
    ``1/sqrt(1+age)`` discount). Properties the async suite asserts:
    non-negative, monotone non-increasing in ``age`` for ``decay >= 0``,
    and exactly uniform (all ones) at ``decay = 0`` — which is what makes
    the zero-latency async round reduce to the synchronous one. ``valid``
    masks empty buffer slots to weight zero. Weights are normalized by
    their sum inside the weighted estimate, not here.
    """
    w = (1.0 + ages.astype(jnp.float32)) ** (-decay)
    if valid is not None:
        w = jnp.where(valid, w, 0.0)
    return w


def probit_plus_aggregate(codes: jax.Array, b: jax.Array) -> jax.Array:
    """Aggregate client one-bit codes ``(M, d)`` into ``theta_hat (d,)``."""
    m = codes.shape[0]
    return ml_estimate_from_counts(codes_to_counts(codes), m, b)


def probit_plus_from_updates(
    key: jax.Array, updates: jax.Array, b: jax.Array
) -> jax.Array:
    """End-to-end reference path: quantize each client then ML-aggregate."""
    keys = jax.random.split(key, updates.shape[0])
    codes = jax.vmap(stochastic_binarize, in_axes=(0, 0, None))(keys, updates, b)
    return probit_plus_aggregate(codes, b)


# ---------------------------------------------------------------------------
# Full-precision baselines
# ---------------------------------------------------------------------------

def fedavg_aggregate(
    updates: jax.Array, weights: jax.Array | None = None
) -> jax.Array:
    """FedAvg: (weighted) mean of the (M, d) client updates.

    ``weights`` is the staleness weighting of the buffered-async server.
    The weighted mean is computed as ``mean(u * w * (M / sum(w)))`` rather
    than ``sum(u * w) / sum(w)``: with unit weights the rescale is exactly
    1.0 and the call lowers to the *identical* op sequence as the
    unweighted ``jnp.mean`` (whose division XLA folds into a reciprocal
    multiply), which the async zero-latency parity test requires bit for
    bit.
    """
    if weights is None:
        return jnp.mean(updates, axis=0)
    wsum = jnp.sum(weights)
    scale = updates.shape[0] / jnp.maximum(wsum, 1e-12)
    mean = jnp.mean(updates * (weights * scale)[:, None], axis=0)
    return jnp.where(wsum > 0, mean, 0.0)


def geometric_median(
    updates: jax.Array,
    iters: int = 16,
    eps: float = 1e-8,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Fed-GM [Yin et al. 2018]: geometric median via Weiszfeld iterations.

    Smoothed Weiszfeld: weights ``1/max(||u_m - y||, eps)``; ``iters`` fixed
    steps under ``lax.fori_loop`` (convergence is geometric; 16 suffices for
    aggregation noise levels in the paper's regime). Optional ``weights``
    compute the *weighted* geometric median (staleness-discounted async
    buffers): each Weiszfeld weight is scaled by the row weight, so
    zero-weight (empty/evicted) rows drop out of the fixed point.
    """
    y0 = fedavg_aggregate(updates, weights)

    def body(_, y):
        dist = jnp.sqrt(jnp.sum((updates - y) ** 2, axis=-1) + eps)
        w = 1.0 / dist if weights is None else weights / dist
        return jnp.sum(updates * w[:, None], axis=0) / jnp.maximum(
            jnp.sum(w), 1e-12
        )

    return jax.lax.fori_loop(0, iters, body, y0)


# ---------------------------------------------------------------------------
# Bit-based baselines (paper §VI-A)
# ---------------------------------------------------------------------------

def signsgd_mv_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """signSGD with Majority Vote [Bernstein et al. 2019].

    Clients upload ``sign(delta)``; the server takes the majority sign and
    applies a hand-tuned step size (paper sets 0.01). The manual step size is
    exactly the instability PRoBit+ removes.
    """
    vote = jnp.sign(jnp.sum(codes.astype(jnp.float32), axis=0))
    return step * vote


def rsa_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """RSA [Li et al. 2019] server step: accumulate client signs × step."""
    return step * jnp.sum(codes.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# Wire formats
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedWire:
    """Canonical one-bit wire: (M, d_pad/8) uint8 packed codes + range b."""

    packed: jax.Array  # (M, P) uint8, P * 8 >= d
    b: jax.Array  # (d,) f32 public quantization range
    d: int = dataclasses.field(metadata=dict(static=True))  # true dimension

    @property
    def n_clients(self) -> int:
        return self.packed.shape[0]

    @property
    def wire_bytes(self) -> int:
        return self.packed.shape[0] * self.packed.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseWire:
    """Top-k wire: per-client indices (M, k) + packed codes (M, ceil(k/8))."""

    indices: jax.Array  # (M, k) int32
    packed: jax.Array  # (M, ceil(k/8)) uint8
    b: jax.Array  # (d,) f32
    d: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseWire:
    """Full-precision passthrough (FedAvg / Fed-GM baselines)."""

    updates: jax.Array  # (M, d) f32


Wire = Union[PackedWire, SparseWire, DenseWire]


# ---------------------------------------------------------------------------
# Client compressor
# ---------------------------------------------------------------------------

def _unpack_rows(packed: jax.Array, n: int) -> jax.Array:
    """(M, P) uint8 -> (M, n) ±1 int8 (test/sparse helper, materializes)."""
    from .quantizer import unpack_bits

    return jax.vmap(lambda p: unpack_bits(p, n))(packed)


@dataclasses.dataclass(frozen=True)
class ClientCompressor:
    """Client half of the pipeline: EF -> top-k -> binarize -> bit-pack.

    ``mode``:
      * "pack_stochastic" — PRoBit+ Eq. 5 compressor, packed wire;
      * "pack_sign"       — deterministic sign codes (signSGD-MV / RSA);
      * "dense"           — identity (full-precision baselines).
    """

    mode: str = "pack_stochastic"
    error_feedback: bool = False
    topk_frac: float = 1.0
    dp: DPConfig = DPConfig(0.0)
    b_mode: str = "dynamic"
    use_kernels: bool = False
    chunk: int = PACK_CHUNK
    # Quantizer draw width: 32 = f32 uniforms (canonical), 16 = uint16
    # draws against a uint32 threshold (half the RNG memory; see
    # quantizer.threshold_u16). Kernel and top-k wires require 32.
    rand_bits: int = 32

    def __post_init__(self):
        if self.rand_bits not in (16, 32):
            raise ValueError(f"rand_bits must be 16 or 32, got {self.rand_bits}")
        if self.rand_bits == 16 and self.use_kernels:
            raise ValueError("rand_bits=16 is not supported on the kernel wire")
        if self.rand_bits == 16 and self.topk_frac < 1.0:
            raise ValueError("rand_bits=16 is not supported on the top-k wire")

    # The Eq.-5 bit probability — shared with the mesh path (fl_step).
    bit_probability = staticmethod(binarize_prob)

    def b_vector(self, d: int, b_scalar: jax.Array) -> jax.Array:
        """The public range vector for dimension ``d`` (non-oracle modes).

        The streaming round needs ``b`` once, outside the client-chunk
        scan, to finalize the accumulated counts; oracle mode maxes over
        the full client axis and therefore cannot stream.
        """
        if self.b_mode == "oracle":
            raise ValueError("oracle b depends on all updates and cannot stream")
        if self.mode == "pack_sign":
            return jnp.ones((d,), jnp.float32)
        return self._b_vector(jnp.zeros((1, d), jnp.float32), b_scalar)

    def wire_bytes(self, d: int) -> int | None:
        """Bytes per packed wire row for dimension ``d`` (None for dense).

        The async round buffer must be allocated before any wire exists;
        this mirrors the padding the compress path will apply (chunked
        pure-JAX padding, or the Pallas kernel's 128-byte lane alignment).
        """
        if self.mode == "dense":
            return None
        # pack_sign always compresses via the chunked packer, so the
        # kernel alignment applies only to the stochastic kernel wire
        if self.use_kernels and self.mode == "pack_stochastic":
            from ..kernels import ops as kops

            return kops.padded_len(d) // 8
        return padded_dim(d, self.chunk) // 8

    def _b_vector(self, eff: jax.Array, b_scalar: jax.Array) -> jax.Array:
        d = eff.shape[1]
        if self.b_mode == "oracle":
            from .bcontrol import oracle_b

            return oracle_b(eff, self.dp)
        b_eff = b_scalar
        if self.dp.enabled:
            b_eff = b_eff + (1.0 + 1.0 / self.dp.epsilon) * self.dp.l1_sensitivity
        return jnp.full((d,), b_eff, jnp.float32)

    def compress(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        row_offset: jax.Array | int = 0,
    ) -> tuple[Wire, jax.Array]:
        """(M, d) updates -> (wire, residuals'). Residuals pass through
        unchanged unless error feedback is active (PRoBit+, no DP).

        ``row_offset`` rebases the per-client quantizer keys: a streaming
        round compressing cohort chunk ``[g0, g0 + C)`` passes ``g0`` so
        row ``i`` draws exactly the bits it would draw at cohort position
        ``g0 + i`` of an all-at-once compress (see
        :func:`~repro.core.quantizer.packed_binarize_batch`).
        """
        if self.mode == "dense":
            return DenseWire(updates=deltas), residuals
        if self.mode == "pack_sign":
            d = deltas.shape[1]
            wire = PackedWire(
                packed=packed_sign_batch(deltas, chunk=self.chunk),
                b=jnp.ones((d,), jnp.float32),
                d=d,
            )
            return wire, residuals

        # PRoBit+ (pack_stochastic)
        m, d = deltas.shape
        use_ef = self.error_feedback and not self.dp.enabled
        eff = deltas + residuals if use_ef else deltas
        b_vec = self._b_vector(eff, b_scalar)

        if self.topk_frac < 1.0:
            from .sparse import topk_binarize
            from .quantizer import pack_bits

            k = max(int(d * self.topk_frac), 1)
            keys = jax.random.split(key, m)
            codes = None
            if self.use_kernels:
                from ..kernels import ops as kops

                # Same key/uniform schedule and top-k gather as
                # topk_binarize; the gathered values binarize + pack
                # through the kernel engine, so the sparse wire is
                # bit-identical to the pure path's vmap(pack_bits)(codes)
                # while the int8 code tensor never materializes.
                def one(ck, row):
                    _, idx = jax.lax.top_k(jnp.abs(row), k)
                    d_sel = jnp.take(row, idx)
                    b_sel = jnp.take(b_vec, idx)
                    u = jax.random.uniform(ck, (k,), dtype=jnp.float32)
                    pk = kops.quant_pack_u(d_sel, b_sel, u)
                    return idx.astype(jnp.int32), pk[: (k + 7) // 8]

                idx, packed_k = jax.vmap(one)(keys, eff)
            else:
                idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
                    keys, eff, b_vec, k
                )
                packed_k = jax.vmap(pack_bits)(codes)
            if use_ef:
                if codes is None:
                    codes = _unpack_rows(packed_k, k)
                rows = jnp.arange(m)[:, None]
                sent = jnp.zeros_like(eff).at[rows, idx].set(
                    codes.astype(jnp.float32)
                )
                # unreported coordinates carry their full delta forward
                residuals = eff - sent * b_vec
            wire = SparseWire(
                indices=idx,
                packed=packed_k,
                b=b_vec,
                d=d,
                k=k,
            )
            return wire, residuals

        if self.use_kernels:
            from ..kernels import ops as kops

            packed, res = kops.stoch_quant_compress_batch(
                key, eff, b_vec, row_offset=row_offset, chunk=self.chunk,
                want_residual=use_ef,
            )
            if use_ef:
                residuals = res
            return PackedWire(packed=packed, b=b_vec, d=d), residuals

        packed, res = packed_binarize_batch(
            key, eff, b_vec, chunk=self.chunk, want_residual=use_ef,
            row_offset=row_offset, rand_bits=self.rand_bits,
        )
        if use_ef:
            residuals = res
        return PackedWire(packed=packed, b=b_vec, d=d), residuals


# ---------------------------------------------------------------------------
# Server aggregators
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerAggregator:
    """Server half: count accumulation -> estimate.

    Count accumulation is the **first-class aggregation primitive**: the
    packed path of every bit scheme composes from

    * :meth:`init_counts` — a zero count carry for a ``P``-byte wire row;
    * :meth:`accumulate_counts` — fold one ``(C, P)`` wire chunk (any
      client subset) into the carry. Vote counts are additive over
      clients, so chunks may arrive in any split — a streaming round
      scans client-chunks through this with O(C * P) resident memory;
    * :meth:`finalize` — the per-scheme estimate from ``(counts, M, b)``.

    :meth:`aggregate` is the one-shot composition (single chunk = whole
    cohort), bit-identical to pre-streaming behavior. Bit-based schemes
    override :meth:`from_counts`; dense schemes override
    :meth:`from_dense` and advertise their streaming form via
    ``stream_kind``: ``"counts"`` (PRoBit+ / signSGD-MV / RSA stream
    exactly), ``"sum"`` (FedAvg streams a weighted running sum), or
    ``"buffer"`` (Fed-GM needs all rows resident — parity fallback only,
    not memory-bounded).

    ``weights`` (one per wire row) activates the weighted count path used
    by the buffered-asynchronous server and the fused heterogeneous-M /
    padded-chunk masks: the vote counts become
    ``N_i^w = sum_m w_m 1[c_i^m = +1]`` and the effective cohort size
    ``M^w = sum_m w_m``, both fed to the *same* per-scheme estimate —
    Eq. 13 and the signSGD-MV / RSA rules are all affine in ``(N, M)``, so
    the weighting folds into the counts and the wire format is untouched.
    With unit weights this is value-identical to the unweighted path.
    """

    chunk: int = PACK_CHUNK
    stream_kind = "counts"

    def from_counts(self, counts: jax.Array, m, b: jax.Array) -> jax.Array:
        raise NotImplementedError

    def from_dense(
        self, updates: jax.Array, weights: jax.Array | None = None
    ) -> jax.Array:
        raise NotImplementedError

    # -- streaming count protocol ------------------------------------------

    def init_counts(self, p_bytes: int, *, weighted: bool = False) -> jax.Array:
        """Zero vote-count carry for a ``p_bytes``-per-row packed wire.

        Count-dtype policy: int32 for the exact unweighted count (any
        cohort below 2**31 clients); f32 when per-row weights (staleness /
        active-client masks) fold in — f32 sums of 0/1-weighted bits stay
        exact below 2**24 contributing clients. The uint8 dtype of the
        *wire rows* must never leak into the accumulator: a uint8 count
        silently wraps mod 256 past 255 clients, exactly the large-M
        regime the paper's O(1/M) result targets.
        """
        return jnp.zeros((8 * p_bytes,), jnp.float32 if weighted else jnp.int32)

    def accumulate_counts(
        self,
        counts: jax.Array,
        wire_chunk: jax.Array,
        weights_chunk: jax.Array | None = None,
    ) -> jax.Array:
        """Fold one packed client-chunk ``(C, P)`` into the count carry."""
        if weights_chunk is None:
            return counts + packed_counts(wire_chunk, chunk=self.chunk)
        return counts + packed_weighted_counts(
            wire_chunk, weights_chunk, chunk=self.chunk
        )

    def finalize(self, counts: jax.Array, m, b: jax.Array) -> jax.Array:
        """Per-scheme estimate from accumulated counts (slices pad bits)."""
        return self.from_counts(counts[: b.shape[0]], m, b)

    # -- streaming dense-sum protocol (FedAvg) -----------------------------

    def init_stream_sum(self, d: int) -> tuple[jax.Array, jax.Array]:
        """Zero ``(sum_m w_m u_m, sum_m w_m)`` carry for dense streaming."""
        return jnp.zeros((d,), jnp.float32), jnp.float32(0.0)

    def accumulate_sum(self, carry, updates: jax.Array, weights_chunk: jax.Array):
        s, w = carry
        return (
            s + jnp.sum(updates * weights_chunk[:, None], axis=0),
            w + jnp.sum(weights_chunk),
        )

    def finalize_sum(self, carry) -> jax.Array:
        s, w = carry
        return jnp.where(w > 0, s / jnp.maximum(w, 1e-12), 0.0)

    # -- one-shot composition ----------------------------------------------

    def aggregate(
        self, wire: Wire, weights: jax.Array | None = None
    ) -> jax.Array:
        if isinstance(wire, DenseWire):
            return self.from_dense(wire.updates, weights)
        if isinstance(wire, SparseWire):
            raise TypeError(f"{type(self).__name__} cannot consume SparseWire")
        p_bytes = wire.packed.shape[1]
        if weights is None:
            counts = self.accumulate_counts(
                self.init_counts(p_bytes), wire.packed
            )
            return self.finalize(counts, wire.n_clients, wire.b)
        wcounts = self.accumulate_counts(
            self.init_counts(p_bytes, weighted=True), wire.packed, weights
        )
        wsum = jnp.sum(weights.astype(jnp.float32))
        est = self.finalize(wcounts, jnp.maximum(wsum, 1e-12), wire.b)
        # An all-empty buffer (round 0 under heavy latency) estimates zero.
        return jnp.where(wsum > 0, est, 0.0)


@dataclasses.dataclass(frozen=True)
class ProBitPlusServer(ServerAggregator):
    """Eq. 13 ML estimate; optionally via the fused Pallas count kernel."""

    use_kernels: bool = False

    def from_counts(self, counts, m, b):
        return ml_estimate_from_counts(counts, m, b)

    def aggregate(self, wire: Wire, weights: jax.Array | None = None) -> jax.Array:
        if isinstance(wire, SparseWire):
            if weights is not None:
                raise TypeError("weighted aggregation needs a dense PackedWire")
            from .sparse import sparse_aggregate

            codes = _unpack_rows(wire.packed, wire.k)
            return sparse_aggregate(wire.indices, codes, wire.b, wire.d)
        if weights is not None:
            # The fused count kernel has no weighted variant; the chunked
            # pure-JAX weighted count consumes the same packed wire.
            return super().aggregate(wire, weights)
        if self.use_kernels and isinstance(wire, PackedWire):
            from ..kernels import ops as kops

            # The kernel expects 1024-lane (128-byte) alignment; a wire from
            # the chunked pure-JAX compressor may carry more (or fewer) pad
            # bytes. Pad bits encode coordinates >= d, which bit_aggregate
            # slices off, so realigning is lossless.
            pbytes = kops.padded_len(wire.d) // 8
            packed = wire.packed
            if packed.shape[1] > pbytes:
                packed = packed[:, :pbytes]
            elif packed.shape[1] < pbytes:
                packed = jnp.pad(
                    packed, ((0, 0), (0, pbytes - packed.shape[1]))
                )
            return kops.bit_aggregate(packed, wire.b, wire.d)
        return super().aggregate(wire)


@dataclasses.dataclass(frozen=True)
class SignSGDMVServer(ServerAggregator):
    step: float = 0.01

    def from_counts(self, counts, m, b):
        return self.step * jnp.sign(2.0 * counts.astype(jnp.float32) - m)


@dataclasses.dataclass(frozen=True)
class RSAServer(ServerAggregator):
    step: float = 0.01

    def from_counts(self, counts, m, b):
        return self.step * (2.0 * counts.astype(jnp.float32) - m)


@dataclasses.dataclass(frozen=True)
class FedAvgServer(ServerAggregator):
    """Dense mean; streams as a weighted running sum (``stream_kind="sum"``)."""

    stream_kind = "sum"

    def from_dense(self, updates, weights=None):
        return fedavg_aggregate(updates, weights)


@dataclasses.dataclass(frozen=True)
class FedGMServer(ServerAggregator):
    """Weiszfeld geometric median — every iteration touches every row, so
    streaming buffers all rows (``stream_kind="buffer"``; parity fallback
    only, memory stays O(M * d))."""

    iters: int = 16
    stream_kind = "buffer"

    def from_dense(self, updates, weights=None):
        return geometric_median(updates, self.iters, weights=weights)


# ---------------------------------------------------------------------------
# Pipeline + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregatorPipeline:
    """One named aggregation scheme: compressor + server, jit-composable."""

    name: str
    compressor: ClientCompressor
    server: ServerAggregator

    def compress_wire(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        flip_n: int = 0,
        flip_gate: jax.Array | None = None,
        row_offset: jax.Array | int = 0,
    ) -> tuple[Wire, jax.Array]:
        """Client half only: compress all clients onto the wire.

        ``flip_n > 0`` arms the ``bit_flip`` wire adversary: the first
        ``flip_n`` clients' codes are inverted *after* compression (see
        :func:`repro.core.attacks.flip_wire`). ``flip_gate`` optionally
        gates the flip with a traced boolean, so a vmapped campaign batch
        can mix bit_flip cells with delta-level-attack cells. Residuals are
        the honest compressor's (Byzantine rows lie about those too, which
        is exactly what an adversarial client would do under EF).

        ``row_offset`` identifies the rows as cohort positions
        ``[row_offset, row_offset + M)`` — the streaming round passes its
        chunk start so both the quantizer keys and the first-``flip_n``
        Byzantine membership resolve against global cohort position, not
        chunk-local row index.

        Exposed separately from :meth:`estimate` so the asynchronous round
        can interpose its staleness buffer between compression and the
        server estimate without reformatting the wire.
        """
        static_zero_offset = isinstance(row_offset, int) and row_offset == 0
        wire, residuals = self.compressor.compress(
            key, deltas, b_scalar, residuals, row_offset=row_offset
        )
        if flip_n:
            from .attacks import flip_wire, flip_wire_rows

            if static_zero_offset:
                flipped = flip_wire(wire, flip_n)
            else:
                rows = row_offset + jnp.arange(deltas.shape[0])
                flipped = flip_wire_rows(wire, rows < flip_n)
            if flip_gate is None:
                wire = flipped
            else:
                wire = jax.tree.map(
                    lambda f, w: jnp.where(flip_gate, f, w), flipped, wire
                )
        return wire, residuals

    def estimate(self, wire: Wire, weights: jax.Array | None = None) -> jax.Array:
        """Server half only: estimate theta_hat from a (buffered) wire.

        ``weights`` — one non-negative weight per wire row — selects the
        age-weighted count path (see :class:`ServerAggregator`).
        """
        return self.server.aggregate(wire, weights)

    def __call__(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        flip_n: int = 0,
        flip_gate: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Full synchronous round: compress, aggregate, return (theta, res')."""
        wire, residuals = self.compress_wire(
            key, deltas, b_scalar, residuals, flip_n=flip_n, flip_gate=flip_gate
        )
        return self.estimate(wire), residuals


_PIPELINES: dict[str, Callable[..., AggregatorPipeline]] = {}


def _register(name: str):
    def deco(builder: Callable[..., AggregatorPipeline]):
        _PIPELINES[name] = builder
        return builder

    return deco


def available_aggregators() -> tuple[str, ...]:
    return tuple(sorted(_PIPELINES))


def build_pipeline(
    name: str,
    *,
    dp: DPConfig = DPConfig(0.0),
    b_mode: str = "dynamic",
    error_feedback: bool = False,
    topk_frac: float = 1.0,
    agg_step: float = 0.01,
    gm_iters: int = 16,
    use_kernels: bool = False,
    chunk: int = PACK_CHUNK,
    rand_bits: int = 32,
) -> AggregatorPipeline:
    """Resolve a registered aggregator name into a configured pipeline."""
    try:
        builder = _PIPELINES[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; available: {available_aggregators()}"
        ) from None
    return builder(
        dp=dp,
        b_mode=b_mode,
        error_feedback=error_feedback,
        topk_frac=topk_frac,
        agg_step=agg_step,
        gm_iters=gm_iters,
        use_kernels=use_kernels,
        chunk=chunk,
        rand_bits=rand_bits,
    )


@_register("probit_plus")
def _build_probit_plus(
    *, dp, b_mode, error_feedback, topk_frac, agg_step, gm_iters, use_kernels,
    chunk, rand_bits,
):
    kernel_wire = use_kernels
    return AggregatorPipeline(
        name="probit_plus",
        compressor=ClientCompressor(
            mode="pack_stochastic",
            error_feedback=error_feedback,
            topk_frac=topk_frac,
            dp=dp,
            b_mode=b_mode,
            use_kernels=kernel_wire,
            chunk=chunk,
            rand_bits=rand_bits,
        ),
        server=ProBitPlusServer(use_kernels=kernel_wire, chunk=chunk),
    )


@_register("fedavg")
def _build_fedavg(*, gm_iters, chunk, **_):
    return AggregatorPipeline(
        name="fedavg",
        compressor=ClientCompressor(mode="dense", chunk=chunk),
        server=FedAvgServer(chunk=chunk),
    )


@_register("fed_gm")
def _build_fed_gm(*, gm_iters, chunk, **_):
    return AggregatorPipeline(
        name="fed_gm",
        compressor=ClientCompressor(mode="dense", chunk=chunk),
        server=FedGMServer(iters=gm_iters, chunk=chunk),
    )


@_register("signsgd_mv")
def _build_signsgd_mv(*, agg_step, chunk, **_):
    return AggregatorPipeline(
        name="signsgd_mv",
        compressor=ClientCompressor(mode="pack_sign", chunk=chunk),
        server=SignSGDMVServer(step=agg_step, chunk=chunk),
    )


@_register("rsa")
def _build_rsa(*, agg_step, chunk, **_):
    return AggregatorPipeline(
        name="rsa",
        compressor=ClientCompressor(mode="pack_sign", chunk=chunk),
        server=RSAServer(step=agg_step, chunk=chunk),
    )
