"""Aggregation pipeline: client compressors, server aggregators, registry.

Architecture — the packed-wire contract
=======================================

Every aggregation path in this repo (CPU simulation in ``fl/runtime.py``,
the Pallas kernels in ``kernels/``, the sharded mesh step in
``launch/fl_step.py``, and the microbenchmarks) speaks one protocol,
split into two halves joined by an explicit wire format:

``ClientCompressor``
    error feedback -> top-k selection -> stochastic binarize (Eq. 5) ->
    uint8 bit-pack. Emits one of three wire formats:

    * :class:`PackedWire` — the **canonical** format: an
      ``(M, bits * d_pad/8)`` uint8 matrix of LSB-first packed codes plus
      the public range vector ``b`` (d,) and the static per-value width
      ``bits`` (``wire_bits`` in {1, 2, 4}; 1 is the paper's wire,
      bit-exact with pre-k-bit history). ``bits`` bits/parameter on the
      wire — the paper's 32x upload saving vs f32 at k=1, realized in
      memory traffic too because both producer and consumer work in
      d-chunks (:func:`repro.core.quantizer.packed_binarize_batch` /
      :func:`repro.core.quantizer.packed_quantize_batch` /
      :func:`repro.core.quantizer.packed_counts`) and the dense (M, d)
      code tensor never materializes. k > 1 levels travel as ``bits``
      one-bit planes concatenated plane-major along the byte axis, so the
      count protocol below consumes them unchanged.
    * :class:`HeteroWire` — HeteroSAg-style per-client bit-widths: the
      cohort is partitioned into contiguous groups of equal ``bits``,
      each group an independent :class:`PackedWire`; the server
      aggregates per group and MLE-merges with inverse-variance weights
      ``M_g * (2**k_g - 1)**2``.
    * :class:`SparseWire` — top-k variant: per-client index sets plus
      packed codes (beyond-paper extension, see ``core/sparse.py``).
    * :class:`DenseWire` — full-precision passthrough for the FedAvg /
      Fed-GM baselines.

``ServerAggregator``
    unpack / vote-count -> estimate. For bit-based schemes the shared hot
    path is the chunked vote count ``N_i``; the per-scheme estimate is a
    pure function of ``(counts, M, b)``:

    * PRoBit+  : ``(2 N_i - M)/M * b_i``            (ML estimate, Eq. 13)
    * signSGD-MV: ``step * sign(2 N_i - M)``        [Bernstein et al. 2019]
    * RSA      : ``step * (2 N_i - M)``             [Li et al. 2019]

    FedAvg / Fed-GM consume :class:`DenseWire` directly.

    At k > 1 the count carry of a ``bits * d_pad/8``-byte wire row is the
    flattened **per-plane** vote count — the sufficient statistic of the
    (L, d) per-level histogram's mean (``sum_l l N_l = sum_p 2^p
    N_plane_p``) — and PRoBit+'s finalize becomes the L-level multinomial
    ML estimate :func:`kbit_estimate_from_counts`, which reduces to Eq. 13
    at k = 1 (the k = 1 path keeps the literal Eq. 13 code, bit-exact).

An :class:`AggregatorPipeline` bundles one compressor with one server
aggregator; :func:`build_pipeline` resolves a registered name
("probit_plus" | "fedavg" | "fed_gm" | "signsgd_mv" | "rsa") into a
configured pipeline. ``use_kernels=True`` swaps PRoBit+'s two halves for
the fused Pallas kernels (``kernels/stoch_quant.py`` client-side,
``kernels/bit_aggregate.py`` server-side; interpret mode on CPU) — same
wire, same estimate, different engine.

The standalone functions below (``probit_plus_aggregate`` etc.) remain
the mathematical reference implementations the pipelines and tests are
validated against.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

from .privacy import DPConfig, rr_gamma
from .quantizer import (
    PACK_CHUNK,
    WIRE_BITS,
    codes_to_counts,
    packed_binarize_batch,
    packed_counts,
    packed_quantize_batch,
    packed_sign_batch,
    packed_weighted_counts,
    padded_dim,
    stochastic_binarize,
    binarize_prob,
)
from .quantizer import wire_bytes as _wire_row_bytes

__all__ = [
    "ml_estimate_from_counts",
    "kbit_estimate_from_counts",
    "hetero_client_groups",
    "staleness_weights",
    "probit_plus_aggregate",
    "probit_plus_from_updates",
    "fedavg_aggregate",
    "geometric_median",
    "signsgd_mv_aggregate",
    "rsa_aggregate",
    "PackedWire",
    "HeteroWire",
    "SparseWire",
    "DenseWire",
    "ClientCompressor",
    "ServerAggregator",
    "AggregatorPipeline",
    "build_pipeline",
    "available_aggregators",
]


# ---------------------------------------------------------------------------
# PRoBit+ reference math
# ---------------------------------------------------------------------------

def ml_estimate_from_counts(counts: jax.Array, m: int, b: jax.Array) -> jax.Array:
    """Eq. 13: ``theta_hat_i = (2 N_i - M)/M * b_i``.

    This is the exact ML estimate of the mean parameter under the two-point
    likelihood (Eq. 12); it equals ``mean_m(c_i^m) * b_i``.
    """
    return (2.0 * counts.astype(jnp.float32) - m) / m * b


def kbit_estimate_from_counts(
    counts: jax.Array,
    m,
    b: jax.Array,
    bits: int,
    gamma: jax.Array | None = None,
) -> jax.Array:
    """Eq. 13 generalized to the L-level multinomial, from plane counts.

    ``counts`` is the ``(bits, d)`` per-plane vote count (plane ``p``
    counts bit ``p`` of each client's level index); the mean level
    ``sum_p 2^p N_p / M`` is the sufficient statistic the full (L, d)
    per-level histogram contributes to the grid-mean ML estimate::

        theta_hat_i = -b_i + (2 b_i / (L-1)) * mean_level_i

    — the sample mean of the dequantized levels, i.e. the ML estimate of
    the mean parameter constrained to [-b, b] (clipped there, so the
    estimate is always bounded by the public range; at k = 1 the formula
    collapses to ``(2 N - M)/M * b``, Eq. 13 — the k = 1 wire keeps the
    literal :func:`ml_estimate_from_counts` code path for bit-exactness).
    ``gamma`` debiases the randomized-response DP wire: the uniform level
    mix has grid mean 0, so ``E[v] = (1-gamma) * theta`` and the estimate
    rescales by ``1/(1-gamma)`` before clipping. Monotone non-decreasing
    in every count (all plane weights are positive), which the property
    tests assert.
    """
    n_steps = (1 << bits) - 1
    weights = (2.0 ** jnp.arange(bits, dtype=jnp.float32))[:, None]
    mean_level = jnp.sum(weights * counts.astype(jnp.float32), axis=0) / m
    b = jnp.broadcast_to(b, mean_level.shape).astype(jnp.float32)
    theta = -b + (2.0 * b / n_steps) * mean_level
    if gamma is not None:
        theta = theta / jnp.maximum(1.0 - gamma, 1e-6)
    return jnp.clip(theta, -b, b)


def hetero_client_groups(client_bits) -> tuple[tuple[int, int, int], ...]:
    """Run-length encode per-client bit-widths into contiguous groups.

    ``(k_0, k_1, ...)`` (one entry per cohort row) -> ``((start, stop,
    bits), ...)`` — the HeteroSAg-style client groups the compressor
    compresses independently and the server MLE-merges. Non-contiguous
    equal-bits clients simply form more groups (correctness is unchanged;
    sort the cohort by bit-width to minimize group count).
    """
    bits_list = tuple(int(k) for k in client_bits)
    for k in bits_list:
        if k not in WIRE_BITS:
            raise ValueError(
                f"per-client bit-widths must be in {WIRE_BITS}, got {k}"
            )
    groups: list[tuple[int, int, int]] = []
    start = 0
    for i in range(1, len(bits_list) + 1):
        if i == len(bits_list) or bits_list[i] != bits_list[start]:
            groups.append((start, i, bits_list[start]))
            start = i
    return tuple(groups)


def staleness_weights(
    ages: jax.Array, decay: jax.Array, valid: jax.Array | None = None
) -> jax.Array:
    """Polynomial staleness discount ``w(age) = (1 + age) ** (-decay)``.

    The weight an asynchronous server gives a buffered upload that is
    ``age`` rounds old (FedBuff-style; ``decay = 0.5`` is the classical
    ``1/sqrt(1+age)`` discount). Properties the async suite asserts:
    non-negative, monotone non-increasing in ``age`` for ``decay >= 0``,
    and exactly uniform (all ones) at ``decay = 0`` — which is what makes
    the zero-latency async round reduce to the synchronous one. ``valid``
    masks empty buffer slots to weight zero. Weights are normalized by
    their sum inside the weighted estimate, not here.
    """
    w = (1.0 + ages.astype(jnp.float32)) ** (-decay)
    if valid is not None:
        w = jnp.where(valid, w, 0.0)
    return w


def probit_plus_aggregate(codes: jax.Array, b: jax.Array) -> jax.Array:
    """Aggregate client one-bit codes ``(M, d)`` into ``theta_hat (d,)``."""
    m = codes.shape[0]
    return ml_estimate_from_counts(codes_to_counts(codes), m, b)


def probit_plus_from_updates(
    key: jax.Array, updates: jax.Array, b: jax.Array
) -> jax.Array:
    """End-to-end reference path: quantize each client then ML-aggregate."""
    keys = jax.random.split(key, updates.shape[0])
    codes = jax.vmap(stochastic_binarize, in_axes=(0, 0, None))(keys, updates, b)
    return probit_plus_aggregate(codes, b)


# ---------------------------------------------------------------------------
# Full-precision baselines
# ---------------------------------------------------------------------------

def fedavg_aggregate(
    updates: jax.Array, weights: jax.Array | None = None
) -> jax.Array:
    """FedAvg: (weighted) mean of the (M, d) client updates.

    ``weights`` is the staleness weighting of the buffered-async server.
    The weighted mean is computed as ``mean(u * w * (M / sum(w)))`` rather
    than ``sum(u * w) / sum(w)``: with unit weights the rescale is exactly
    1.0 and the call lowers to the *identical* op sequence as the
    unweighted ``jnp.mean`` (whose division XLA folds into a reciprocal
    multiply), which the async zero-latency parity test requires bit for
    bit.
    """
    if weights is None:
        return jnp.mean(updates, axis=0)
    wsum = jnp.sum(weights)
    scale = updates.shape[0] / jnp.maximum(wsum, 1e-12)
    mean = jnp.mean(updates * (weights * scale)[:, None], axis=0)
    return jnp.where(wsum > 0, mean, 0.0)


def geometric_median(
    updates: jax.Array,
    iters: int = 16,
    eps: float = 1e-8,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Fed-GM [Yin et al. 2018]: geometric median via Weiszfeld iterations.

    Smoothed Weiszfeld: weights ``1/max(||u_m - y||, eps)``; ``iters`` fixed
    steps under ``lax.fori_loop`` (convergence is geometric; 16 suffices for
    aggregation noise levels in the paper's regime). Optional ``weights``
    compute the *weighted* geometric median (staleness-discounted async
    buffers): each Weiszfeld weight is scaled by the row weight, so
    zero-weight (empty/evicted) rows drop out of the fixed point.
    """
    y0 = fedavg_aggregate(updates, weights)

    def body(_, y):
        dist = jnp.sqrt(jnp.sum((updates - y) ** 2, axis=-1) + eps)
        w = 1.0 / dist if weights is None else weights / dist
        return jnp.sum(updates * w[:, None], axis=0) / jnp.maximum(
            jnp.sum(w), 1e-12
        )

    return jax.lax.fori_loop(0, iters, body, y0)


# ---------------------------------------------------------------------------
# Bit-based baselines (paper §VI-A)
# ---------------------------------------------------------------------------

def signsgd_mv_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """signSGD with Majority Vote [Bernstein et al. 2019].

    Clients upload ``sign(delta)``; the server takes the majority sign and
    applies a hand-tuned step size (paper sets 0.01). The manual step size is
    exactly the instability PRoBit+ removes.
    """
    vote = jnp.sign(jnp.sum(codes.astype(jnp.float32), axis=0))
    return step * vote


def rsa_aggregate(codes: jax.Array, step: float = 0.01) -> jax.Array:
    """RSA [Li et al. 2019] server step: accumulate client signs × step."""
    return step * jnp.sum(codes.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# Wire formats
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedWire:
    """Canonical wire: (M, bits * d_pad/8) uint8 packed codes + range b.

    ``bits = 1`` is the paper's one-bit wire, byte-identical to the
    pre-k-bit format. ``bits > 1`` carries the level index as ``bits``
    one-bit planes concatenated plane-major along the byte axis, each
    plane packed exactly like the one-bit wire (chunk-ordered, byte-major,
    LSB-first) — see :func:`repro.core.quantizer.pack_levels`.
    """

    packed: jax.Array  # (M, bits * P) uint8, P * 8 >= d
    b: jax.Array  # (d,) f32 public quantization range
    d: int = dataclasses.field(metadata=dict(static=True))  # true dimension
    bits: int = dataclasses.field(default=1, metadata=dict(static=True))

    @property
    def n_clients(self) -> int:
        return self.packed.shape[0]

    @property
    def wire_bytes(self) -> int:
        return self.packed.shape[0] * self.packed.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HeteroWire:
    """HeteroSAg-style heterogeneous wire: per-client bit-widths.

    The cohort is partitioned into contiguous groups of equal bit-width
    (:func:`hetero_client_groups`); each group travels as an independent
    :class:`PackedWire` over the same coordinate range. The server
    aggregates each group with its own L-level ML estimate and merges with
    inverse-variance weights ``M_g * (2**k_g - 1)**2`` (the per-level
    multinomial variance scales as ``step_g**2 / M_g`` and
    ``step_g = 2b/(L_g - 1)``).
    """

    wires: tuple  # tuple[PackedWire, ...], group order = cohort order

    @property
    def n_clients(self) -> int:
        return sum(w.n_clients for w in self.wires)

    @property
    def d(self) -> int:
        return self.wires[0].d

    @property
    def wire_bytes(self) -> int:
        return sum(w.wire_bytes for w in self.wires)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseWire:
    """Top-k wire: per-client indices (M, k) + packed codes (M, ceil(k/8))."""

    indices: jax.Array  # (M, k) int32
    packed: jax.Array  # (M, ceil(k/8)) uint8
    b: jax.Array  # (d,) f32
    d: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseWire:
    """Full-precision passthrough (FedAvg / Fed-GM baselines)."""

    updates: jax.Array  # (M, d) f32


Wire = Union[PackedWire, HeteroWire, SparseWire, DenseWire]


# ---------------------------------------------------------------------------
# Client compressor
# ---------------------------------------------------------------------------

def _unpack_rows(packed: jax.Array, n: int) -> jax.Array:
    """(M, P) uint8 -> (M, n) ±1 int8 (test/sparse helper, materializes)."""
    from .quantizer import unpack_bits

    return jax.vmap(lambda p: unpack_bits(p, n))(packed)


@dataclasses.dataclass(frozen=True)
class ClientCompressor:
    """Client half of the pipeline: EF -> top-k -> binarize -> bit-pack.

    ``mode``:
      * "pack_stochastic" — PRoBit+ Eq. 5 compressor, packed wire;
      * "pack_sign"       — deterministic sign codes (signSGD-MV / RSA);
      * "dense"           — identity (full-precision baselines).
    """

    mode: str = "pack_stochastic"
    error_feedback: bool = False
    topk_frac: float = 1.0
    dp: DPConfig = DPConfig(0.0)
    b_mode: str = "dynamic"
    use_kernels: bool = False
    chunk: int = PACK_CHUNK
    # Quantizer draw width: 32 = f32 uniforms (canonical), 16 = uint16
    # draws against a uint32 threshold (half the RNG memory; see
    # quantizer.threshold_u16). Kernel and top-k wires require 32.
    rand_bits: int = 32
    # Wire width k in {1, 2, 4} bits/parameter. 1 is the paper's one-bit
    # wire (bit-exact with pre-k-bit history); k > 1 quantizes onto the
    # uniform 2**k-level grid and, under DP, mixes in L-level randomized
    # response (see privacy.rr_gamma).
    wire_bits: int = 1
    # HeteroSAg-style per-client bit-widths: one entry per cohort row,
    # each in WIRE_BITS. Overrides wire_bits; emits a HeteroWire.
    client_bits: tuple | None = None

    def __post_init__(self):
        if self.rand_bits not in (16, 32):
            raise ValueError(f"rand_bits must be 16 or 32, got {self.rand_bits}")
        if self.rand_bits == 16 and self.use_kernels:
            raise ValueError("rand_bits=16 is not supported on the kernel wire")
        if self.rand_bits == 16 and self.topk_frac < 1.0:
            raise ValueError("rand_bits=16 is not supported on the top-k wire")
        if self.wire_bits not in WIRE_BITS:
            raise ValueError(
                f"wire_bits must be one of {WIRE_BITS}, got {self.wire_bits}"
            )
        if self.wire_bits > 1:
            if self.mode != "pack_stochastic":
                raise ValueError(
                    "wire_bits > 1 requires the pack_stochastic wire "
                    f"(got mode={self.mode!r})"
                )
            if self.topk_frac < 1.0:
                raise ValueError("wire_bits > 1 is not supported on the top-k wire")
            if self.rand_bits != 32:
                raise ValueError("wire_bits > 1 requires rand_bits=32")
        if self.client_bits is not None:
            object.__setattr__(
                self, "client_bits", tuple(int(k) for k in self.client_bits)
            )
            hetero_client_groups(self.client_bits)  # validates each entry
            if self.mode != "pack_stochastic":
                raise ValueError(
                    "per-client bit-widths require the pack_stochastic wire"
                )
            if self.use_kernels:
                raise ValueError(
                    "per-client bit-widths are not supported on the kernel "
                    "wire (compress per-group without use_kernels)"
                )
            if self.topk_frac < 1.0:
                raise ValueError(
                    "per-client bit-widths are not supported on the top-k wire"
                )

    # The Eq.-5 bit probability — shared with the mesh path (fl_step).
    bit_probability = staticmethod(binarize_prob)

    def b_vector(self, d: int, b_scalar: jax.Array) -> jax.Array:
        """The public range vector for dimension ``d`` (non-oracle modes).

        The streaming round needs ``b`` once, outside the client-chunk
        scan, to finalize the accumulated counts; oracle mode maxes over
        the full client axis and therefore cannot stream.
        """
        if self.b_mode == "oracle":
            raise ValueError("oracle b depends on all updates and cannot stream")
        if self.mode == "pack_sign":
            return jnp.ones((d,), jnp.float32)
        return self._b_vector(jnp.zeros((1, d), jnp.float32), b_scalar)

    def wire_bytes(self, d: int) -> int | None:
        """Bytes per packed wire row for dimension ``d`` (None for dense).

        The async round buffer must be allocated before any wire exists;
        this mirrors the padding the compress path will apply (chunked
        pure-JAX padding, or the Pallas kernel's 128-byte lane alignment).
        """
        if self.mode == "dense":
            return None
        # pack_sign always compresses via the chunked packer, so the
        # kernel alignment applies only to the stochastic kernel wire
        if self.use_kernels and self.mode == "pack_stochastic":
            from ..kernels import ops as kops

            return _wire_row_bytes(d, self.wire_bits, d_pad=kops.padded_len(d))
        return _wire_row_bytes(d, self.wire_bits, d_pad=padded_dim(d, self.chunk))

    def _b_vector(self, eff: jax.Array, b_scalar: jax.Array) -> jax.Array:
        d = eff.shape[1]
        # k > 1 earns its (eps, 0) guarantee from randomized-response
        # mixing (privacy.rr_gamma), not the Theorem-3 b-floor margin,
        # so the range stays at the honest b.
        dp = self.dp if self.wire_bits == 1 else DPConfig(0.0)
        if self.b_mode == "oracle":
            from .bcontrol import oracle_b

            return oracle_b(eff, dp)
        b_eff = b_scalar
        if dp.enabled:
            b_eff = b_eff + (1.0 + 1.0 / dp.epsilon) * dp.l1_sensitivity
        return jnp.full((d,), b_eff, jnp.float32)

    def _gamma(self, b_vec: jax.Array) -> jax.Array | None:
        """RR mixing weight of the k-bit DP wire (None when not mixing)."""
        if self.wire_bits > 1 and self.dp.enabled:
            return rr_gamma(
                self.dp.epsilon, self.dp.l1_sensitivity, b_vec, self.wire_bits
            )
        return None

    def compress(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        row_offset: jax.Array | int = 0,
    ) -> tuple[Wire, jax.Array]:
        """(M, d) updates -> (wire, residuals'). Residuals pass through
        unchanged unless error feedback is active (PRoBit+, no DP).

        ``row_offset`` rebases the per-client quantizer keys: a streaming
        round compressing cohort chunk ``[g0, g0 + C)`` passes ``g0`` so
        row ``i`` draws exactly the bits it would draw at cohort position
        ``g0 + i`` of an all-at-once compress (see
        :func:`~repro.core.quantizer.packed_binarize_batch`).
        """
        if self.mode == "dense":
            return DenseWire(updates=deltas), residuals
        if self.mode == "pack_sign":
            d = deltas.shape[1]
            wire = PackedWire(
                packed=packed_sign_batch(deltas, chunk=self.chunk),
                b=jnp.ones((d,), jnp.float32),
                d=d,
            )
            return wire, residuals

        if self.client_bits is not None:
            # HeteroSAg-style groups: compress each contiguous equal-bits
            # group through a homogeneous sub-compressor, rebasing the
            # counter-derived keys so each row draws the bits of its
            # global cohort position.
            if len(self.client_bits) != deltas.shape[0]:
                raise ValueError(
                    f"client_bits has {len(self.client_bits)} entries for "
                    f"a {deltas.shape[0]}-client cohort"
                )
            wires = []
            res_parts = []
            for start, stop, gbits in hetero_client_groups(self.client_bits):
                sub = dataclasses.replace(
                    self, client_bits=None, wire_bits=gbits
                )
                w, r = sub.compress(
                    key,
                    deltas[start:stop],
                    b_scalar,
                    residuals[start:stop],
                    row_offset=row_offset + start,
                )
                wires.append(w)
                res_parts.append(r)
            return HeteroWire(wires=tuple(wires)), jnp.concatenate(
                res_parts, axis=0
            )

        # PRoBit+ (pack_stochastic)
        m, d = deltas.shape
        use_ef = self.error_feedback and not self.dp.enabled
        eff = deltas + residuals if use_ef else deltas
        b_vec = self._b_vector(eff, b_scalar)

        if self.topk_frac < 1.0:
            from .sparse import topk_binarize
            from .quantizer import pack_bits

            k = max(int(d * self.topk_frac), 1)
            keys = jax.random.split(key, m)
            codes = None
            if self.use_kernels:
                from ..kernels import ops as kops

                # Same key/uniform schedule and top-k gather as
                # topk_binarize; the gathered values binarize + pack
                # through the kernel engine, so the sparse wire is
                # bit-identical to the pure path's vmap(pack_bits)(codes)
                # while the int8 code tensor never materializes.
                def one(ck, row):
                    _, idx = jax.lax.top_k(jnp.abs(row), k)
                    d_sel = jnp.take(row, idx)
                    b_sel = jnp.take(b_vec, idx)
                    u = jax.random.uniform(ck, (k,), dtype=jnp.float32)
                    pk = kops.quant_pack_u(d_sel, b_sel, u)
                    return idx.astype(jnp.int32), pk[: (k + 7) // 8]

                idx, packed_k = jax.vmap(one)(keys, eff)
            else:
                idx, codes = jax.vmap(topk_binarize, in_axes=(0, 0, None, None))(
                    keys, eff, b_vec, k
                )
                packed_k = jax.vmap(pack_bits)(codes)
            if use_ef:
                if codes is None:
                    codes = _unpack_rows(packed_k, k)
                rows = jnp.arange(m)[:, None]
                sent = jnp.zeros_like(eff).at[rows, idx].set(
                    codes.astype(jnp.float32)
                )
                # unreported coordinates carry their full delta forward
                residuals = eff - sent * b_vec
            wire = SparseWire(
                indices=idx,
                packed=packed_k,
                b=b_vec,
                d=d,
                k=k,
            )
            return wire, residuals

        if self.use_kernels:
            from ..kernels import ops as kops

            packed, res = kops.stoch_quant_compress_batch(
                key, eff, b_vec, row_offset=row_offset, chunk=self.chunk,
                want_residual=use_ef, bits=self.wire_bits,
                gamma=self._gamma(b_vec),
            )
            if use_ef:
                residuals = res
            return (
                PackedWire(packed=packed, b=b_vec, d=d, bits=self.wire_bits),
                residuals,
            )

        if self.wire_bits > 1:
            packed, res = packed_quantize_batch(
                key, eff, b_vec, bits=self.wire_bits, chunk=self.chunk,
                want_residual=use_ef, row_offset=row_offset,
                gamma=self._gamma(b_vec),
            )
            if use_ef:
                residuals = res
            return (
                PackedWire(packed=packed, b=b_vec, d=d, bits=self.wire_bits),
                residuals,
            )

        packed, res = packed_binarize_batch(
            key, eff, b_vec, chunk=self.chunk, want_residual=use_ef,
            row_offset=row_offset, rand_bits=self.rand_bits,
        )
        if use_ef:
            residuals = res
        return PackedWire(packed=packed, b=b_vec, d=d), residuals


# ---------------------------------------------------------------------------
# Server aggregators
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerAggregator:
    """Server half: count accumulation -> estimate.

    Count accumulation is the **first-class aggregation primitive**: the
    packed path of every bit scheme composes from

    * :meth:`init_counts` — a zero count carry for a ``P``-byte wire row;
    * :meth:`accumulate_counts` — fold one ``(C, P)`` wire chunk (any
      client subset) into the carry. Vote counts are additive over
      clients, so chunks may arrive in any split — a streaming round
      scans client-chunks through this with O(C * P) resident memory;
    * :meth:`finalize` — the per-scheme estimate from ``(counts, M, b)``.

    :meth:`aggregate` is the one-shot composition (single chunk = whole
    cohort), bit-identical to pre-streaming behavior. Bit-based schemes
    override :meth:`from_counts`; dense schemes override
    :meth:`from_dense` and advertise their streaming form via
    ``stream_kind``: ``"counts"`` (PRoBit+ / signSGD-MV / RSA stream
    exactly), ``"sum"`` (FedAvg streams a weighted running sum), or
    ``"buffer"`` (Fed-GM needs all rows resident — parity fallback only,
    not memory-bounded).

    ``weights`` (one per wire row) activates the weighted count path used
    by the buffered-asynchronous server and the fused heterogeneous-M /
    padded-chunk masks: the vote counts become
    ``N_i^w = sum_m w_m 1[c_i^m = +1]`` and the effective cohort size
    ``M^w = sum_m w_m``, both fed to the *same* per-scheme estimate —
    Eq. 13 and the signSGD-MV / RSA rules are all affine in ``(N, M)``, so
    the weighting folds into the counts and the wire format is untouched.
    With unit weights this is value-identical to the unweighted path.
    """

    chunk: int = PACK_CHUNK
    stream_kind = "counts"

    def from_counts(self, counts: jax.Array, m, b: jax.Array) -> jax.Array:
        raise NotImplementedError

    def from_dense(
        self, updates: jax.Array, weights: jax.Array | None = None
    ) -> jax.Array:
        raise NotImplementedError

    # -- streaming count protocol ------------------------------------------

    def init_counts(self, p_bytes: int, *, weighted: bool = False) -> jax.Array:
        """Zero vote-count carry for a ``p_bytes``-per-row packed wire.

        Count-dtype policy: int32 for the exact unweighted count (any
        cohort below 2**31 clients); f32 when per-row weights (staleness /
        active-client masks) fold in — f32 sums of 0/1-weighted bits stay
        exact below 2**24 contributing clients. The uint8 dtype of the
        *wire rows* must never leak into the accumulator: a uint8 count
        silently wraps mod 256 past 255 clients, exactly the large-M
        regime the paper's O(1/M) result targets.
        """
        return jnp.zeros((8 * p_bytes,), jnp.float32 if weighted else jnp.int32)

    def accumulate_counts(
        self,
        counts: jax.Array,
        wire_chunk: jax.Array,
        weights_chunk: jax.Array | None = None,
    ) -> jax.Array:
        """Fold one packed client-chunk ``(C, P)`` into the count carry."""
        if weights_chunk is None:
            return counts + packed_counts(wire_chunk, chunk=self.chunk)
        return counts + packed_weighted_counts(
            wire_chunk, weights_chunk, chunk=self.chunk
        )

    def finalize(self, counts: jax.Array, m, b: jax.Array) -> jax.Array:
        """Per-scheme estimate from accumulated counts (slices pad bits)."""
        return self.from_counts(counts[: b.shape[0]], m, b)

    # -- streaming dense-sum protocol (FedAvg) -----------------------------

    def init_stream_sum(self, d: int) -> tuple[jax.Array, jax.Array]:
        """Zero ``(sum_m w_m u_m, sum_m w_m)`` carry for dense streaming."""
        return jnp.zeros((d,), jnp.float32), jnp.float32(0.0)

    def accumulate_sum(self, carry, updates: jax.Array, weights_chunk: jax.Array):
        s, w = carry
        return (
            s + jnp.sum(updates * weights_chunk[:, None], axis=0),
            w + jnp.sum(weights_chunk),
        )

    def finalize_sum(self, carry) -> jax.Array:
        s, w = carry
        return jnp.where(w > 0, s / jnp.maximum(w, 1e-12), 0.0)

    # -- one-shot composition ----------------------------------------------

    def aggregate(
        self, wire: Wire, weights: jax.Array | None = None
    ) -> jax.Array:
        if isinstance(wire, DenseWire):
            return self.from_dense(wire.updates, weights)
        if isinstance(wire, SparseWire):
            raise TypeError(f"{type(self).__name__} cannot consume SparseWire")
        p_bytes = wire.packed.shape[1]
        if weights is None:
            counts = self.accumulate_counts(
                self.init_counts(p_bytes), wire.packed
            )
            return self.finalize(counts, wire.n_clients, wire.b)
        wcounts = self.accumulate_counts(
            self.init_counts(p_bytes, weighted=True), wire.packed, weights
        )
        wsum = jnp.sum(weights.astype(jnp.float32))
        est = self.finalize(wcounts, jnp.maximum(wsum, 1e-12), wire.b)
        # An all-empty buffer (round 0 under heavy latency) estimates zero.
        return jnp.where(wsum > 0, est, 0.0)


@dataclasses.dataclass(frozen=True)
class ProBitPlusServer(ServerAggregator):
    """Eq. 13 ML estimate; optionally via the fused Pallas count kernel.

    ``wire_bits > 1`` switches :meth:`finalize` to the L-level multinomial
    estimate :func:`kbit_estimate_from_counts` — the count *accumulation*
    is untouched, because the plane-major k-bit wire makes the flat count
    carry exactly the per-plane vote counts. ``dp`` mirrors the
    compressor's config so the server can debias the randomized-response
    mix (same closed-form gamma from the public ``(eps, Delta_1, b, k)``).
    """

    use_kernels: bool = False
    wire_bits: int = 1
    dp: DPConfig = DPConfig(0.0)

    def from_counts(self, counts, m, b):
        return ml_estimate_from_counts(counts, m, b)

    def finalize(self, counts: jax.Array, m, b: jax.Array) -> jax.Array:
        if self.wire_bits == 1:
            return super().finalize(counts, m, b)
        d = b.shape[0]
        plane = counts.shape[0] // self.wire_bits
        plane_counts = counts.reshape(self.wire_bits, plane)[:, :d]
        gamma = None
        if self.dp.enabled:
            gamma = rr_gamma(
                self.dp.epsilon, self.dp.l1_sensitivity, b, self.wire_bits
            )
        return kbit_estimate_from_counts(
            plane_counts, m, b, self.wire_bits, gamma
        )

    def aggregate(self, wire: Wire, weights: jax.Array | None = None) -> jax.Array:
        if isinstance(wire, PackedWire) and wire.bits != self.wire_bits:
            # The wire's static width is authoritative (a pipeline built
            # at k=1 can still consume a k-bit wire and vice versa).
            srv = dataclasses.replace(self, wire_bits=wire.bits)
            return srv.aggregate(wire, weights)
        if isinstance(wire, HeteroWire):
            # Per-group L-level estimates, merged with inverse-variance
            # weights M_g * (2**k_g - 1)**2 (step_g**2 / M_g variance).
            num = jnp.zeros((wire.d,), jnp.float32)
            den = 0.0
            off = 0
            for w in wire.wires:
                srv = dataclasses.replace(
                    self, wire_bits=w.bits, use_kernels=False
                )
                wsel = (
                    None if weights is None else weights[off : off + w.n_clients]
                )
                gw = w.n_clients * ((1 << w.bits) - 1) ** 2
                num = num + gw * srv.aggregate(w, wsel)
                den += gw
                off += w.n_clients
            return num / den
        if isinstance(wire, SparseWire):
            if weights is not None:
                raise TypeError("weighted aggregation needs a dense PackedWire")
            from .sparse import sparse_aggregate

            codes = _unpack_rows(wire.packed, wire.k)
            return sparse_aggregate(wire.indices, codes, wire.b, wire.d)
        if weights is not None:
            # The fused count kernel has no weighted variant; the chunked
            # pure-JAX weighted count consumes the same packed wire.
            return super().aggregate(wire, weights)
        if (
            self.use_kernels
            and isinstance(wire, PackedWire)
            and wire.bits == 1
        ):
            from ..kernels import ops as kops

            # The kernel expects 1024-lane (128-byte) alignment; a wire from
            # the chunked pure-JAX compressor may carry more (or fewer) pad
            # bytes. Pad bits encode coordinates >= d, which bit_aggregate
            # slices off, so realigning is lossless.
            pbytes = kops.padded_len(wire.d) // 8
            packed = wire.packed
            if packed.shape[1] > pbytes:
                packed = packed[:, :pbytes]
            elif packed.shape[1] < pbytes:
                packed = jnp.pad(
                    packed, ((0, 0), (0, pbytes - packed.shape[1]))
                )
            return kops.bit_aggregate(packed, wire.b, wire.d)
        return super().aggregate(wire)


@dataclasses.dataclass(frozen=True)
class SignSGDMVServer(ServerAggregator):
    step: float = 0.01

    def from_counts(self, counts, m, b):
        return self.step * jnp.sign(2.0 * counts.astype(jnp.float32) - m)


@dataclasses.dataclass(frozen=True)
class RSAServer(ServerAggregator):
    step: float = 0.01

    def from_counts(self, counts, m, b):
        return self.step * (2.0 * counts.astype(jnp.float32) - m)


@dataclasses.dataclass(frozen=True)
class FedAvgServer(ServerAggregator):
    """Dense mean; streams as a weighted running sum (``stream_kind="sum"``)."""

    stream_kind = "sum"

    def from_dense(self, updates, weights=None):
        return fedavg_aggregate(updates, weights)


@dataclasses.dataclass(frozen=True)
class FedGMServer(ServerAggregator):
    """Weiszfeld geometric median — every iteration touches every row, so
    streaming buffers all rows (``stream_kind="buffer"``; parity fallback
    only, memory stays O(M * d))."""

    iters: int = 16
    stream_kind = "buffer"

    def from_dense(self, updates, weights=None):
        return geometric_median(updates, self.iters, weights=weights)


# ---------------------------------------------------------------------------
# Pipeline + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregatorPipeline:
    """One named aggregation scheme: compressor + server, jit-composable."""

    name: str
    compressor: ClientCompressor
    server: ServerAggregator

    def compress_wire(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        flip_n: int = 0,
        flip_gate: jax.Array | None = None,
        row_offset: jax.Array | int = 0,
    ) -> tuple[Wire, jax.Array]:
        """Client half only: compress all clients onto the wire.

        ``flip_n > 0`` arms the ``bit_flip`` wire adversary: the first
        ``flip_n`` clients' codes are inverted *after* compression (see
        :func:`repro.core.attacks.flip_wire`). ``flip_gate`` optionally
        gates the flip with a traced boolean, so a vmapped campaign batch
        can mix bit_flip cells with delta-level-attack cells. Residuals are
        the honest compressor's (Byzantine rows lie about those too, which
        is exactly what an adversarial client would do under EF).

        ``row_offset`` identifies the rows as cohort positions
        ``[row_offset, row_offset + M)`` — the streaming round passes its
        chunk start so both the quantizer keys and the first-``flip_n``
        Byzantine membership resolve against global cohort position, not
        chunk-local row index.

        Exposed separately from :meth:`estimate` so the asynchronous round
        can interpose its staleness buffer between compression and the
        server estimate without reformatting the wire.
        """
        static_zero_offset = isinstance(row_offset, int) and row_offset == 0
        wire, residuals = self.compressor.compress(
            key, deltas, b_scalar, residuals, row_offset=row_offset
        )
        if flip_n:
            from .attacks import flip_wire, flip_wire_rows

            if static_zero_offset:
                flipped = flip_wire(wire, flip_n)
            else:
                rows = row_offset + jnp.arange(deltas.shape[0])
                flipped = flip_wire_rows(wire, rows < flip_n)
            if flip_gate is None:
                wire = flipped
            else:
                wire = jax.tree.map(
                    lambda f, w: jnp.where(flip_gate, f, w), flipped, wire
                )
        return wire, residuals

    def estimate(self, wire: Wire, weights: jax.Array | None = None) -> jax.Array:
        """Server half only: estimate theta_hat from a (buffered) wire.

        ``weights`` — one non-negative weight per wire row — selects the
        age-weighted count path (see :class:`ServerAggregator`).
        """
        return self.server.aggregate(wire, weights)

    def __call__(
        self,
        key: jax.Array,
        deltas: jax.Array,
        b_scalar: jax.Array,
        residuals: jax.Array,
        *,
        flip_n: int = 0,
        flip_gate: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Full synchronous round: compress, aggregate, return (theta, res')."""
        wire, residuals = self.compress_wire(
            key, deltas, b_scalar, residuals, flip_n=flip_n, flip_gate=flip_gate
        )
        return self.estimate(wire), residuals


_PIPELINES: dict[str, Callable[..., AggregatorPipeline]] = {}


def _register(name: str):
    def deco(builder: Callable[..., AggregatorPipeline]):
        _PIPELINES[name] = builder
        return builder

    return deco


def available_aggregators() -> tuple[str, ...]:
    return tuple(sorted(_PIPELINES))


def build_pipeline(
    name: str,
    *,
    dp: DPConfig = DPConfig(0.0),
    b_mode: str = "dynamic",
    error_feedback: bool = False,
    topk_frac: float = 1.0,
    agg_step: float = 0.01,
    gm_iters: int = 16,
    use_kernels: bool = False,
    chunk: int = PACK_CHUNK,
    rand_bits: int = 32,
    wire_bits: int = 1,
    client_bits: tuple | None = None,
) -> AggregatorPipeline:
    """Resolve a registered aggregator name into a configured pipeline."""
    try:
        builder = _PIPELINES[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; available: {available_aggregators()}"
        ) from None
    if (wire_bits != 1 or client_bits is not None) and name != "probit_plus":
        raise ValueError(
            "wire_bits > 1 / per-client bit-widths are only supported by "
            f"the probit_plus wire, got {name!r}"
        )
    return builder(
        dp=dp,
        b_mode=b_mode,
        error_feedback=error_feedback,
        topk_frac=topk_frac,
        agg_step=agg_step,
        gm_iters=gm_iters,
        use_kernels=use_kernels,
        chunk=chunk,
        rand_bits=rand_bits,
        wire_bits=wire_bits,
        client_bits=client_bits,
    )


@_register("probit_plus")
def _build_probit_plus(
    *, dp, b_mode, error_feedback, topk_frac, agg_step, gm_iters, use_kernels,
    chunk, rand_bits, wire_bits=1, client_bits=None,
):
    kernel_wire = use_kernels
    return AggregatorPipeline(
        name="probit_plus",
        compressor=ClientCompressor(
            mode="pack_stochastic",
            error_feedback=error_feedback,
            topk_frac=topk_frac,
            dp=dp,
            b_mode=b_mode,
            use_kernels=kernel_wire,
            chunk=chunk,
            rand_bits=rand_bits,
            wire_bits=wire_bits,
            client_bits=client_bits,
        ),
        server=ProBitPlusServer(
            use_kernels=kernel_wire, chunk=chunk, wire_bits=wire_bits, dp=dp
        ),
    )


@_register("fedavg")
def _build_fedavg(*, gm_iters, chunk, **_):
    return AggregatorPipeline(
        name="fedavg",
        compressor=ClientCompressor(mode="dense", chunk=chunk),
        server=FedAvgServer(chunk=chunk),
    )


@_register("fed_gm")
def _build_fed_gm(*, gm_iters, chunk, **_):
    return AggregatorPipeline(
        name="fed_gm",
        compressor=ClientCompressor(mode="dense", chunk=chunk),
        server=FedGMServer(iters=gm_iters, chunk=chunk),
    )


@_register("signsgd_mv")
def _build_signsgd_mv(*, agg_step, chunk, **_):
    return AggregatorPipeline(
        name="signsgd_mv",
        compressor=ClientCompressor(mode="pack_sign", chunk=chunk),
        server=SignSGDMVServer(step=agg_step, chunk=chunk),
    )


@_register("rsa")
def _build_rsa(*, agg_step, chunk, **_):
    return AggregatorPipeline(
        name="rsa",
        compressor=ClientCompressor(mode="pack_sign", chunk=chunk),
        server=RSAServer(step=agg_step, chunk=chunk),
    )
