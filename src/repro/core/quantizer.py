"""Stochastic one-bit compressor (paper Eq. 5) and bit packing.

The PRoBit+ client-side compressor maps a model difference ``delta`` and a
public quantization-range vector ``b`` (with ``b_i >= max_m |delta_i^m|``)
to one bit per component::

    c_i = +1  with probability (b_i + delta_i) / (2 b_i)
    c_i = -1  with probability (b_i - delta_i) / (2 b_i)

which is an unbiased one-bit estimate of ``delta_i / b_i``:
``E[c_i] * b_i = delta_i``.

All functions are pure-JAX and shape-polymorphic; the Pallas-accelerated
versions live in :mod:`repro.kernels` and are validated against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "binarize_prob",
    "stochastic_binarize",
    "pack_bits",
    "unpack_bits",
    "codes_to_counts",
]


def binarize_prob(delta: jax.Array, b: jax.Array) -> jax.Array:
    """Probability that the compressor emits +1 (Eq. 5), with clipping.

    ``delta`` outside ``[-b, b]`` is clipped so the result is a valid
    probability even when a (Byzantine or mis-calibrated) update exceeds the
    public range — this is precisely the magnitude-immunity mechanism of
    Theorem 2.
    """
    b = jnp.broadcast_to(b, delta.shape).astype(jnp.float32)
    delta = jnp.clip(delta.astype(jnp.float32), -b, b)
    # Guard b == 0 (dead coordinate): probability 1/2 keeps E[c]*b = 0 = delta.
    safe_b = jnp.where(b > 0, b, 1.0)
    p = 0.5 + 0.5 * delta / safe_b
    return jnp.where(b > 0, p, 0.5)


def stochastic_binarize(key: jax.Array, delta: jax.Array, b: jax.Array) -> jax.Array:
    """Draw the one-bit codes ``c in {-1, +1}`` (int8) for one client."""
    p = binarize_prob(delta, b)
    u = jax.random.uniform(key, delta.shape, dtype=jnp.float32)
    return jnp.where(u < p, jnp.int8(1), jnp.int8(-1))


def pack_bits(codes: jax.Array) -> jax.Array:
    """Pack ±1 int8 codes into uint8 words, 8 codes/byte (LSB-first).

    The flat length is padded to a multiple of 8 with -1 codes (which unpack
    to 0-bits and are sliced away by :func:`unpack_bits`).
    """
    flat = codes.reshape(-1)
    pad = (-flat.shape[0]) % 8
    flat = jnp.pad(flat, (0, pad), constant_values=-1)
    bits = (flat > 0).astype(jnp.uint8).reshape(-1, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns ±1 int8 codes of length ``n``."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[:, None] >> shifts) & jnp.uint8(1)
    codes = jnp.where(bits > 0, jnp.int8(1), jnp.int8(-1)).reshape(-1)
    return codes[:n]


def codes_to_counts(codes: jax.Array) -> jax.Array:
    """``N_i`` of Eq. 12: number of +1 codes across the leading client axis."""
    return jnp.sum((codes > 0).astype(jnp.int32), axis=0)
