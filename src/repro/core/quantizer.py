"""Stochastic k-bit compressor (paper Eq. 5 and its k-bit extension).

The PRoBit+ client-side compressor maps a model difference ``delta`` and a
public quantization-range vector ``b`` (with ``b_i >= max_m |delta_i^m|``)
to one bit per component::

    c_i = +1  with probability (b_i + delta_i) / (2 b_i)
    c_i = -1  with probability (b_i - delta_i) / (2 b_i)

which is an unbiased one-bit estimate of ``delta_i / b_i``:
``E[c_i] * b_i = delta_i``.

k-bit generalization (``wire_bits`` in {1, 2, 4})
-------------------------------------------------
Eq. 5 is the L = 2 case of stochastic rounding onto the uniform
``L = 2**k``-level grid ``v_l = -b + l * 2b/(L-1)``: a clipped delta
between grid neighbours ``v_l <= delta <= v_{l+1}`` emits level ``l+1``
with probability ``(delta - v_l)/(v_{l+1} - v_l)`` and level ``l``
otherwise — adjacent-level probabilities, still unbiased
(``E[v_level] = delta``), with per-coordinate variance shrinking as
``(2b/(L-1))^2``. Levels travel as ``k`` one-bit *planes* (plane ``p``
carries bit ``p`` of each level index), each packed exactly like the
one-bit wire, concatenated plane-major along the byte axis — so the
packed-wire machinery below (chunked pack, popcount count reduction,
count streaming) consumes a k-bit wire unchanged: the flattened counts of
a ``(M, k * d_pad/8)`` wire *are* the per-plane vote counts, the
sufficient statistic of the (L, d) level histogram's mean. The k=1 wire
is produced by the original one-bit path (:func:`packed_binarize_batch`)
and stays bit-exact with it; k > 1 goes through
:func:`packed_quantize_batch` with the **same** counter-derived
``client_uniforms`` draw schedule. Pad coordinates carry deterministic 0
bits in every plane.

All functions are pure-JAX and shape-polymorphic; the Pallas-accelerated
versions live in :mod:`repro.kernels` and are validated against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "binarize_prob",
    "threshold_u16",
    "stochastic_binarize",
    "pack_bits",
    "unpack_bits",
    "codes_to_counts",
    "byte_popcount",
    "PACK_CHUNK",
    "WIRE_BITS",
    "wire_bytes",
    "padded_dim",
    "client_uniforms",
    "level_positions",
    "level_probs",
    "quantize_levels",
    "dequantize_levels",
    "pack_levels",
    "unpack_levels",
    "packed_binarize_batch",
    "packed_quantize_batch",
    "packed_sign_batch",
    "packed_counts",
    "packed_weighted_counts",
    "packed_residuals",
]

# Supported per-value wire widths. 8/k must divide evenly into bytes and
# the (L-1)-level grid must stay addressable in uint8 planes; {1, 2, 4}
# covers the Two-Bit Aggregation and HeteroSAg operating points.
WIRE_BITS = (1, 2, 4)


def wire_bytes(
    d: int, bits: int = 1, *, topk_frac: float = 1.0, d_pad: int | None = None
) -> int:
    """Uplink bytes of ONE client's packed wire row — the single place the
    coordinates x bits -> bytes arithmetic lives.

    Every byte-accounting call site (compressor row width, campaign
    ``peak_bytes_est``, pytree wire report, kernel microbenchmark uplink
    ratios) routes through here so the accounting can never drift from
    the actual wire layout.

    ``d_pad`` is the padded coordinate count the producing wire actually
    emits (``padded_dim(d, chunk)`` for the chunked packer,
    ``kernels.ops.padded_len(d)`` for the kernel wire); ``None`` gives the
    unpadded ``ceil(d/8)`` ideal floor. ``topk_frac < 1`` prices the
    sparse wire: int32 indices + packed codes for ``k = max(d*frac, 1)``
    coordinates.
    """
    if bits not in WIRE_BITS:
        raise ValueError(f"bits must be one of {WIRE_BITS}, got {bits}")
    if topk_frac < 1.0:
        k = max(int(d * topk_frac), 1)
        return 4 * k + bits * ((k + 7) // 8)
    n = d if d_pad is None else d_pad
    return bits * ((n + 7) // 8)


def binarize_prob(delta: jax.Array, b: jax.Array) -> jax.Array:
    """Probability that the compressor emits +1 (Eq. 5), with clipping.

    ``delta`` outside ``[-b, b]`` is clipped so the result is a valid
    probability even when a (Byzantine or mis-calibrated) update exceeds the
    public range — this is precisely the magnitude-immunity mechanism of
    Theorem 2.
    """
    b = jnp.broadcast_to(b, delta.shape).astype(jnp.float32)
    delta = jnp.clip(delta.astype(jnp.float32), -b, b)
    # Guard b == 0 (dead coordinate): probability 1/2 keeps E[c]*b = 0 = delta.
    safe_b = jnp.where(b > 0, b, 1.0)
    p = 0.5 + 0.5 * delta / safe_b
    return jnp.where(b > 0, p, 0.5)


def threshold_u16(p: jax.Array) -> jax.Array:
    """Eq.-5 probability -> 16-bit comparison threshold, in uint32.

    The ``rand_bits=16`` wire compares a uint16 draw against
    ``floor(p * 65536)``: probability granularity 2^-16 (relative bias
    < 1.6e-5) at half the random-draw memory of f32 uniforms. The
    comparison domain is uint32 **on purpose**: ``p = 1.0`` (a coordinate
    with ``|delta| >= b``, i.e. a *certain* +1 vote) maps to 65536, which
    a uint16 cast would wrap to 0 and transmit as a certain -1 — the
    fl_step sign-flip bug this function regression-guards. 65536 exceeds
    every uint16 draw, so saturated votes stay certain.
    """
    return (p.astype(jnp.float32) * 65536.0).astype(jnp.uint32)


def stochastic_binarize(key: jax.Array, delta: jax.Array, b: jax.Array) -> jax.Array:
    """Draw the one-bit codes ``c in {-1, +1}`` (int8) for one client."""
    p = binarize_prob(delta, b)
    u = jax.random.uniform(key, delta.shape, dtype=jnp.float32)
    return jnp.where(u < p, jnp.int8(1), jnp.int8(-1))


def pack_bits(codes: jax.Array) -> jax.Array:
    """Pack ±1 int8 codes into uint8 words, 8 codes/byte (LSB-first).

    The flat length is padded to a multiple of 8 with -1 codes (which unpack
    to 0-bits and are sliced away by :func:`unpack_bits`).
    """
    flat = codes.reshape(-1)
    pad = (-flat.shape[0]) % 8
    flat = jnp.pad(flat, (0, pad), constant_values=-1)
    bits = (flat > 0).astype(jnp.uint8).reshape(-1, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns ±1 int8 codes of length ``n``."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[:, None] >> shifts) & jnp.uint8(1)
    codes = jnp.where(bits > 0, jnp.int8(1), jnp.int8(-1)).reshape(-1)
    return codes[:n]


def codes_to_counts(codes: jax.Array) -> jax.Array:
    """``N_i`` of Eq. 12: number of +1 codes across the leading client axis."""
    return jnp.sum((codes > 0).astype(jnp.int32), axis=0)


def byte_popcount(x: jax.Array) -> jax.Array:
    """Per-byte bit count: ``jax.lax.population_count`` with a uint8-LUT
    fallback for backends/versions without the primitive."""
    if hasattr(jax.lax, "population_count"):
        return jax.lax.population_count(x)
    lut = jnp.asarray([bin(i).count("1") for i in range(256)], jnp.uint8)
    return lut[x.astype(jnp.uint8)]


# ---------------------------------------------------------------------------
# Packed wire format: chunked batch quantize / count
#
# The canonical on-the-wire representation of a round is the (M, d_pad/8)
# uint8 matrix of packed one-bit codes. The helpers below produce and
# consume it in d-chunks so the dense (M, d) codes tensor never
# materializes — peak extra memory is O(M * PACK_CHUNK) regardless of d.
# ---------------------------------------------------------------------------

PACK_CHUNK = 8192  # coordinates per chunked-reduction step (multiple of 8)


def padded_dim(d: int, chunk: int = PACK_CHUNK) -> int:
    """Wire dimension: ``d`` rounded up to a whole number of chunks."""
    return ((d + chunk - 1) // chunk) * chunk


def client_uniforms(
    client_key: jax.Array, n: int, chunk: int = PACK_CHUNK
) -> jax.Array:
    """The (n,) quantizer uniforms of one client, counter-derived per chunk.

    Chunk ``j`` draws ``uniform(fold_in(client_key, j), (chunk,))`` — exactly
    the schedule :func:`packed_binarize_batch` uses internally, so any
    compressor (dense, chunked, Pallas kernel) that consumes these uniforms
    with the same ``client_key = fold_in(key, row_offset + m)`` produces a
    bit-identical wire. Materializes the chunks at once (O(padded n)), which
    is fine per-client; the chunked batch path never calls this.
    """
    n_chunks = padded_dim(n, chunk) // chunk
    u = jax.vmap(
        lambda j: jax.random.uniform(
            jax.random.fold_in(client_key, j), (chunk,), dtype=jnp.float32
        )
    )(jnp.arange(n_chunks))
    return u.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# k-bit grid primitives (Eq. 5 generalized to adjacent-level probabilities)
# ---------------------------------------------------------------------------

def level_positions(delta: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """Continuous grid position ``x in [0, L-1]`` of a clipped delta.

    ``x = (clip(delta, -b, b) + b) / step`` with ``step = 2b/(L-1)``; the
    emitted level is ``floor(x)`` or ``floor(x)+1`` with adjacent-level
    probabilities ``1-frac(x)`` / ``frac(x)``. Dead coordinates
    (``b == 0``) sit at the grid midpoint ``(L-1)/2`` so the dequantized
    mean stays 0 — the k-bit analogue of Eq. 5's ``p = 1/2`` guard.
    """
    levels = (1 << bits) - 1
    b = jnp.broadcast_to(b, delta.shape).astype(jnp.float32)
    delta = jnp.clip(delta.astype(jnp.float32), -b, b)
    safe_step = jnp.where(b > 0, 2.0 * b / levels, 1.0)
    x = (delta + b) / safe_step
    return jnp.where(b > 0, x, 0.5 * levels)


def level_probs(delta: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """Per-level emission probabilities ``(L,) + delta.shape``.

    The adjacent-level rule is the tent function
    ``q_l = max(0, 1 - |x - l|)`` of the grid position ``x`` — at most two
    nonzero entries per coordinate, summing to 1. Used by the privacy
    module to evaluate the L-level randomized-response likelihood ratio.
    """
    x = level_positions(delta, b, bits)
    lvls = jnp.arange(1 << bits, dtype=jnp.float32)
    lvls = lvls.reshape((-1,) + (1,) * x.ndim)
    return jnp.clip(1.0 - jnp.abs(x[None] - lvls), 0.0, 1.0)


def quantize_levels(
    u: jax.Array, delta: jax.Array, b: jax.Array, bits: int
) -> jax.Array:
    """Stochastic grid rounding: uniforms + deltas -> uint8 level indices.

    ``u`` follows the same counter-derived :func:`client_uniforms`
    schedule as the one-bit wire; level = ``low + 1[u < frac]`` where
    ``low/frac`` split the grid position. Unbiased:
    ``E[dequantize_levels(level)] = clip(delta, -b, b)``.
    """
    levels = (1 << bits) - 1
    x = level_positions(delta, b, bits)
    low = jnp.clip(jnp.floor(x), 0.0, float(levels - 1))
    frac = x - low
    return (low + (u < frac)).astype(jnp.uint8)


def dequantize_levels(levels: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """Grid value of a level index: ``v_l = -b + l * 2b/(L-1)``."""
    n_steps = (1 << bits) - 1
    b = b.astype(jnp.float32)
    return -b + levels.astype(jnp.float32) * (2.0 * b / n_steps)


def pack_levels(levels: jax.Array, bits: int) -> jax.Array:
    """(..., n) uint8 level indices -> (..., bits * ceil(n/8)) packed planes.

    Bit-plane order: plane ``p`` (bit ``p`` of each level index, LSB
    first) is packed exactly like the one-bit wire and the planes are
    concatenated along the byte axis — plane-major, each plane
    byte-major/LSB-first internally. ``n % 8 != 0`` tails pad each plane
    with 0 bits (level 0), which :func:`unpack_levels` slices away. At
    ``bits=1`` the layout *is* the one-bit wire's.
    """
    if bits not in WIRE_BITS:
        raise ValueError(f"bits must be one of {WIRE_BITS}, got {bits}")
    n = levels.shape[-1]
    pad = (-n) % 8
    levels = jnp.pad(
        levels.astype(jnp.uint8), [(0, 0)] * (levels.ndim - 1) + [(0, pad)]
    )
    planes = [
        _pack_bool_lastdim((levels >> p) & jnp.uint8(1)) for p in range(bits)
    ]
    return jnp.concatenate(planes, axis=-1)


def unpack_levels(packed: jax.Array, n: int, bits: int) -> jax.Array:
    """Inverse of :func:`pack_levels`: packed planes -> (..., n) uint8."""
    plane_bytes = packed.shape[-1] // bits
    shifts = jnp.arange(8, dtype=jnp.uint8)
    out = jnp.zeros(packed.shape[:-1] + (plane_bytes * 8,), jnp.uint8)
    for p in range(bits):
        plane = packed[..., p * plane_bytes : (p + 1) * plane_bytes]
        pbits = (plane[..., None] >> shifts) & jnp.uint8(1)
        out = out | (
            pbits.reshape(packed.shape[:-1] + (plane_bytes * 8,)) << p
        )
    return out[..., :n]


def _pack_bool_lastdim(bits: jax.Array) -> jax.Array:
    """(..., 8k) bool -> (..., k) uint8, LSB-first within each byte."""
    shape = bits.shape[:-1] + (bits.shape[-1] // 8, 8)
    b8 = bits.astype(jnp.uint8).reshape(shape)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(b8 << shifts, axis=-1).astype(jnp.uint8)


def _pad_batch(deltas: jax.Array, b: jax.Array, chunk: int):
    """Pad (M, d) deltas / (d,) b to a whole number of chunks.

    Pad coordinates get delta = -1, b = 1 so their bit is deterministically
    0 (p = 0) — the wire is reproducible and pad bits carry no entropy.
    """
    m, d = deltas.shape
    d_pad = padded_dim(d, chunk)
    deltas = jnp.pad(
        deltas.astype(jnp.float32), ((0, 0), (0, d_pad - d)), constant_values=-1.0
    )
    b_full = jnp.pad(
        jnp.broadcast_to(b, (d,)).astype(jnp.float32),
        (0, d_pad - d),
        constant_values=1.0,
    )
    return deltas, b_full, d_pad


def packed_binarize_batch(
    key: jax.Array,
    deltas: jax.Array,
    b: jax.Array,
    *,
    chunk: int = PACK_CHUNK,
    want_residual: bool = False,
    row_offset: jax.Array | int = 0,
    rand_bits: int = 32,
) -> tuple[jax.Array, jax.Array | None]:
    """Chunked Eq. 5 binarize + pack: (M, d) f32 -> (M, d_pad/8) uint8.

    Randomness schedule: coordinate chunk ``j`` of client ``m`` draws its
    uniforms from ``fold_in(fold_in(key, row_offset + m), j)``, so the
    wire is exactly reproducible chunk-by-chunk without an (M, d) uniform
    or code tensor. ``row_offset`` (static or traced) rebases the client
    index: a streaming round that compresses the cohort in client-chunks
    passes the chunk's first cohort position, making the chunked wire
    bit-identical to the all-at-once one (the counter-derived draws of
    ``jax_threefry_partitionable`` depend only on the absolute row).

    With ``want_residual`` the error-feedback residual
    ``delta - c * b`` (codes in ±1) is emitted alongside, computed inside
    the same chunk loop.

    ``rand_bits=16`` swaps the f32 uniform for a uint16 draw compared
    against :func:`threshold_u16` in uint32 (same fold_in schedule, half
    the random-draw memory, probability granularity 2^-16; saturated
    ``|delta| >= b`` coordinates remain *certain* votes). The 16-bit wire
    is a distinct, reproducible bit stream — not bit-identical to the
    f32 one.
    """
    if rand_bits not in (16, 32):
        raise ValueError(f"rand_bits must be 16 or 32, got {rand_bits}")
    m, d = deltas.shape
    deltas_p, b_full, d_pad = _pad_batch(deltas, b, chunk)
    n_chunks = d_pad // chunk
    client_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        row_offset + jnp.arange(m)
    )

    def one_chunk(j):
        dch = jax.lax.dynamic_slice_in_dim(deltas_p, j * chunk, chunk, axis=1)
        bch = jax.lax.dynamic_slice_in_dim(b_full, j * chunk, chunk, axis=0)

        def per_client(ck, drow):
            kj = jax.random.fold_in(ck, j)
            if rand_bits == 16:
                u16 = jax.random.bits(kj, (chunk,), jnp.uint16)
                bits = u16.astype(jnp.uint32) < threshold_u16(
                    binarize_prob(drow, bch)
                )
            else:
                u = jax.random.uniform(kj, (chunk,), dtype=jnp.float32)
                bits = u < binarize_prob(drow, bch)
            packed = _pack_bool_lastdim(bits)
            if want_residual:
                return packed, drow - jnp.where(bits, bch, -bch)
            return packed, jnp.zeros((), jnp.float32)

        return jax.vmap(per_client)(client_keys, dch)

    packed_c, res_c = jax.lax.map(one_chunk, jnp.arange(n_chunks))
    packed = jnp.moveaxis(packed_c, 0, 1).reshape(m, d_pad // 8)
    if want_residual:
        res = jnp.moveaxis(res_c, 0, 1).reshape(m, d_pad)[:, :d]
        return packed, res
    return packed, None


def packed_quantize_batch(
    key: jax.Array,
    deltas: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    chunk: int = PACK_CHUNK,
    want_residual: bool = False,
    row_offset: jax.Array | int = 0,
    gamma: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Chunked k-bit quantize + plane-pack: (M, d) f32 -> (M, k*d_pad/8).

    The k > 1 counterpart of :func:`packed_binarize_batch` (which remains
    the one-bit wire, bit-exact with pre-k-bit history): same
    counter-derived schedule — the *rounding* uniform of coordinate chunk
    ``j`` of client ``m`` comes from ``fold_in(fold_in(key, row_offset +
    m), j)``, exactly the :func:`client_uniforms` draws — so dense,
    client-chunked, and kernel-dispatched compressions emit identical
    wires. Output layout: ``bits`` one-bit planes, plane-major over the
    full padded row (plane ``p`` occupies bytes ``[p*d_pad/8,
    (p+1)*d_pad/8)``), each plane internally in the one-bit wire's
    chunk/byte/LSB order.

    ``gamma`` (None, scalar, or per-coordinate ``(d,)``) arms the L-level
    randomized-response mixing that carries the (eps, 0)-DP guarantee at
    k > 1 (see :func:`repro.core.privacy.rr_gamma`): with probability
    ``gamma`` the emitted level is replaced by a uniform one. The RR gate
    and replacement level draw from ``fold_in(kj, 1)`` / ``fold_in(kj,
    2)`` of the chunk key — still counter-derived, so the DP wire too is
    reproducible across chunkings. Pad coordinates get ``gamma = 0`` and
    therefore keep their deterministic 0 bits in every plane.

    With ``want_residual`` the EF residual ``delta - v(level)`` (the
    *emitted* level, RR flips included) is returned alongside.
    """
    if bits not in WIRE_BITS:
        raise ValueError(f"bits must be one of {WIRE_BITS}, got {bits}")
    if bits == 1 and gamma is None:
        return packed_binarize_batch(
            key, deltas, b, chunk=chunk, want_residual=want_residual,
            row_offset=row_offset,
        )
    n_levels = 1 << bits
    m, d = deltas.shape
    deltas_p, b_full, d_pad = _pad_batch(deltas, b, chunk)
    gamma_full = None
    if gamma is not None:
        gamma_full = jnp.pad(
            jnp.broadcast_to(gamma, (d,)).astype(jnp.float32), (0, d_pad - d)
        )
    n_chunks = d_pad // chunk
    client_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        row_offset + jnp.arange(m)
    )

    def one_chunk(j):
        dch = jax.lax.dynamic_slice_in_dim(deltas_p, j * chunk, chunk, axis=1)
        bch = jax.lax.dynamic_slice_in_dim(b_full, j * chunk, chunk, axis=0)
        gch = (
            None
            if gamma_full is None
            else jax.lax.dynamic_slice_in_dim(gamma_full, j * chunk, chunk, 0)
        )

        def per_client(ck, drow):
            kj = jax.random.fold_in(ck, j)
            u = jax.random.uniform(kj, (chunk,), dtype=jnp.float32)
            lvl = quantize_levels(u, drow, bch, bits)
            if gch is not None:
                gate = jax.random.uniform(
                    jax.random.fold_in(kj, 1), (chunk,), dtype=jnp.float32
                )
                rand_lvl = jax.random.randint(
                    jax.random.fold_in(kj, 2), (chunk,), 0, n_levels, jnp.uint8
                )
                lvl = jnp.where(gate < gch, rand_lvl, lvl)
            packed = pack_levels(lvl, bits).reshape(bits, chunk // 8)
            if want_residual:
                return packed, drow - dequantize_levels(lvl, bch, bits)
            return packed, jnp.zeros((), jnp.float32)

        return jax.vmap(per_client)(client_keys, dch)

    packed_c, res_c = jax.lax.map(one_chunk, jnp.arange(n_chunks))
    # (n_chunks, M, bits, chunk/8) -> (M, bits, n_chunks, chunk/8)
    packed = jnp.moveaxis(packed_c, 0, 2).reshape(m, bits * d_pad // 8)
    if want_residual:
        res = jnp.moveaxis(res_c, 0, 1).reshape(m, d_pad)[:, :d]
        return packed, res
    return packed, None


def packed_sign_batch(deltas: jax.Array, *, chunk: int = PACK_CHUNK) -> jax.Array:
    """Deterministic sign codes (signSGD-MV / RSA wire): bit = delta >= 0."""
    deltas_p, _, _ = _pad_batch(deltas, jnp.ones((deltas.shape[1],)), chunk)
    return _pack_bool_lastdim(deltas_p >= 0)


def _popcount_colsums(pch: jax.Array) -> jax.Array:
    """Column bit-sums of a packed chunk via octet transpose + popcount.

    (M, cb) uint8 -> (cb * 8,) int32, column order byte-major / LSB-first
    (bit k of byte j is coordinate ``8 j + k``). Clients are grouped into
    octets of 8; the bit-k's of an octet's bytes are re-packed into one
    byte, whose :func:`byte_popcount` counts 8 clients' votes at once —
    the client reduction shortens 8x (M -> M/8 octets) and the widest
    intermediate stays uint8 instead of int32. Zero pad rows (M % 8)
    contribute zero bits, so the counts are exactly the unpack-and-sum
    ones.
    """
    m, cb = pch.shape
    pad = (-m) % 8
    x = jnp.pad(pch, ((0, pad), (0, 0))).reshape(-1, 8, cb)  # (G, 8, cb)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bit_k = (x[:, :, :, None] >> shifts) & jnp.uint8(1)  # (G, 8, cb, 8)
    octet = jnp.sum(
        bit_k << shifts[None, :, None, None], axis=1, dtype=jnp.uint8
    )  # (G, cb, 8) client-major bytes: bit g of octet[., j, k] = client bit
    counts = jnp.sum(byte_popcount(octet).astype(jnp.int32), axis=0)
    return counts.reshape(cb * 8)


def _chunked_bit_counts(
    packed: jax.Array,
    chunk: int,
    weights: jax.Array | None,
    *,
    use_popcount: bool = True,
) -> jax.Array:
    """Shared chunk walk for the packed-wire count reductions.

    One chunk-layout / pad-handling implementation serves both the integer
    and the weighted count so the two can never diverge; only the
    per-chunk reduction differs. The integer count uses the popcount
    reduction (:func:`_popcount_colsums`) unless ``use_popcount=False``
    selects the unpack-and-sum reference (kept for the microbenchmark and
    as the semantics oracle); the weighted count must unpack (a per-client
    f32 multiply cannot ride a popcount).
    """
    m, pbytes = packed.shape
    cb = min(chunk // 8, pbytes)
    pb_pad = ((pbytes + cb - 1) // cb) * cb
    packed = jnp.pad(packed, ((0, 0), (0, pb_pad - pbytes)))
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def one_chunk(j):
        pch = jax.lax.dynamic_slice_in_dim(packed, j * cb, cb, axis=1)
        if weights is None and use_popcount:
            return _popcount_colsums(pch)
        bits = (pch[..., None] >> shifts) & jnp.uint8(1)  # (M, cb, 8)
        if weights is None:
            acc = bits.astype(jnp.int32)
        else:
            acc = bits.astype(jnp.float32) * weights[:, None, None]
        return jnp.sum(acc, axis=0).reshape(cb * 8)

    counts = jax.lax.map(one_chunk, jnp.arange(pb_pad // cb)).reshape(-1)
    return counts[: 8 * pbytes]


def packed_counts(
    packed: jax.Array, *, chunk: int = PACK_CHUNK, use_popcount: bool = True
) -> jax.Array:
    """Vote counts ``N_i`` straight from the packed wire, chunked over d.

    packed: (M, P) uint8 -> counts (8 * P,) int32. Only O(M * chunk) bits
    are unpacked at a time; the int8 code matrix never materializes.
    ``use_popcount=False`` forces the unpack-and-sum reference reduction
    (identical integer counts; see ``benchmarks/kernels_micro.py`` for the
    measured difference).
    """
    return _chunked_bit_counts(packed, chunk, None, use_popcount=use_popcount)


def packed_weighted_counts(
    packed: jax.Array, weights: jax.Array, *, chunk: int = PACK_CHUNK
) -> jax.Array:
    """Age-weighted vote counts ``N_i^w = sum_m w_m 1[c_i^m = +1]``.

    The buffered-asynchronous server weights each buffered upload by its
    staleness weight *before* the Eq. 13 estimate; the packed uint8 wire is
    consumed unchanged — only the count reduction carries the weights.
    With unit weights the result equals :func:`packed_counts` exactly
    (a float sum of {0, 1} terms is exact below 2**24), which is what makes
    the zero-latency async round bit-exact with the synchronous one.

    packed: (M, P) uint8, weights: (M,) f32 -> counts (8 * P,) f32.
    """
    return _chunked_bit_counts(packed, chunk, weights.astype(jnp.float32))


def packed_residuals(
    packed: jax.Array, deltas: jax.Array, b: jax.Array, *, chunk: int = PACK_CHUNK
) -> jax.Array:
    """Error-feedback residual ``delta - c * b`` recovered from the wire.

    Used when the codes were produced by an external compressor (e.g. the
    Pallas kernel) that does not expose them unpacked; chunked like
    :func:`packed_counts`.
    """
    m, d = deltas.shape
    cb = chunk // 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    deltas_p, b_full, d_pad = _pad_batch(deltas, b, chunk)
    pbytes = packed.shape[1]
    packed = jnp.pad(packed, ((0, 0), (0, max(d_pad // 8 - pbytes, 0))))

    def one_chunk(j):
        pch = jax.lax.dynamic_slice_in_dim(packed, j * cb, cb, axis=1)
        dch = jax.lax.dynamic_slice_in_dim(deltas_p, j * chunk, chunk, axis=1)
        bch = jax.lax.dynamic_slice_in_dim(b_full, j * chunk, chunk, axis=0)
        bits = ((pch[..., None] >> shifts) & jnp.uint8(1)).reshape(m, cb * 8)
        return dch - jnp.where(bits > 0, bch, -bch)

    res = jax.lax.map(one_chunk, jnp.arange(d_pad // chunk))
    return jnp.moveaxis(res, 0, 1).reshape(m, d_pad)[:, :d]
