"""Privacy ledger: cumulative DP accounting across executed FL rounds.

The paper's Theorem 3 makes each round an ``(eps, 0)``-DP local
randomizer; what the *run* spends is a composition question. This module
is the bookkeeping layer on top of the per-round math in
:mod:`repro.core.privacy`: a :class:`PrivacyLedger` records one
:class:`DPEvent` per executed round and reports the cumulative budget
under four interchangeable accountants:

``basic``
    Pure sequential composition: ``eps_total = sum_t eps_t`` with
    ``delta = 0``. This is the conservative number the runtime reported
    before the ledger existed.

``advanced``
    Dwork-Rothblum-Vadhan strong composition (heterogeneous form)::

        eps' = sqrt(2 ln(1/delta') * sum_t eps_t^2)
               + sum_t eps_t * (e^{eps_t} - 1)

    at a ``delta_slack`` failure probability. Degenerate identity:
    zero recorded rounds report exactly ``eps' = 0``.

``subsampled``
    Amplification by subsampling: a round that samples each client with
    rate ``q`` (Poisson sampling, or uniform without-replacement
    sampling of ``m = q*M`` clients — both qualify for the pure-DP
    bound, see :func:`amplified_epsilon`) costs only::

        eps'_t = ln(1 + q * (e^{eps_t} - 1))  <  eps_t   for q < 1,

    composed sequentially (so the total stays pure ``(eps, 0)``-DP).
    Degenerate identity: ``q = 1`` is *bit-identical* to ``basic`` —
    the amplification map is short-circuited, never round-tripped
    through ``log``/``exp`` — so full participation reproduces the
    pre-ledger conservative numbers exactly.

``renyi``
    Rényi (moments) accountant. Each ``(eps, 0)``-DP round is dominated
    by eps-randomized response, whose *exact* Rényi divergence at order
    ``alpha`` is (:func:`rr_renyi_divergence`)::

        rdp(alpha) = log(p^alpha q^(1-alpha) + q^alpha p^(1-alpha))
                     / (alpha - 1),    p = e^eps/(1+e^eps), q = 1 - p

    Rounds compose by *summing* rdp per order; the total converts to
    ``(eps, delta_slack)``-DP with the improved RDP->DP conversion
    [Canonne-Kamath-Steinke 2020], minimized over an order grid and
    capped by the pure ``alpha -> inf`` endpoint (= basic composition).
    Dominance: the reported eps is ``<=`` both ``basic`` and
    ``advanced`` on every multi-round trajectory (property-tested) —
    this is the accountant that tightens the ``eps ~ 0.1`` multi-round
    regime beyond DRV.

Accountant API
--------------
``PrivacyLedger(eps_per_round, q, accountant)`` fixes the homogeneous
per-round parameters; :meth:`PrivacyLedger.record_round` appends events
as rounds execute; :attr:`PrivacyLedger.eps_spent` /
:attr:`PrivacyLedger.delta_spent` give the cumulative budget, and
:meth:`PrivacyLedger.trajectory` the closed-form cumulative-eps curve
for rounds ``1..T`` (what the campaign engine attaches as the
``eps_spent`` metric). :meth:`PrivacyLedger.report` evaluates all four
accountants side by side on the same event log. Heterogeneous events
(per-round ``eps``/``q`` overrides, e.g. an adaptive-clipping schedule)
go through :meth:`PrivacyLedger.record`.

Everything here is host-side ``math``/``numpy`` — accounting never
enters the jitted round programs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .privacy import DELTA_SLACK, strong_composition

__all__ = [
    "ACCOUNTANTS",
    "DPEvent",
    "amplified_epsilon",
    "subsampled_composition",
    "rr_renyi_divergence",
    "renyi_epsilon",
    "PrivacyLedger",
]

ACCOUNTANTS = ("basic", "advanced", "subsampled", "renyi")

# Rényi order grid for the "renyi" accountant: log-spaced just above 1 up
# to 1e6, wide enough that the optimal order for any (eps, T) pair in the
# paper's regimes (eps in [1e-4, ~5], T up to ~1e5) lies strictly inside.
_ALPHA_GRID = 1.0 + np.logspace(-4.0, 6.0, 600)


def rr_renyi_divergence(eps: float, alpha: np.ndarray) -> np.ndarray:
    """Exact RDP curve of eps-randomized response at orders ``alpha``.

    Randomized response is the dominating pair for *any* pure
    ``(eps, 0)``-DP mechanism, so this curve is a valid per-round RDP
    bound for Theorem 3's one-bit randomizer. Computed in log space::

        rdp(alpha) = logaddexp(alpha*log p + (1-alpha)*log q,
                               alpha*log q + (1-alpha)*log p) / (alpha-1)

    with ``p = e^eps / (1 + e^eps)``. Limits: 0 at ``eps = 0``; tends to
    ``eps`` as ``alpha -> inf``; ~``alpha * eps^2 / 2`` for small eps.
    """
    alpha = np.asarray(alpha, np.float64)
    if eps <= 0.0:
        return np.zeros_like(alpha)
    log_p = -np.logaddexp(0.0, -eps)  # log sigmoid(eps)
    log_q = -np.logaddexp(0.0, eps)
    t1 = alpha * log_p + (1.0 - alpha) * log_q
    t2 = alpha * log_q + (1.0 - alpha) * log_p
    return np.logaddexp(t1, t2) / (alpha - 1.0)


def renyi_epsilon(
    rdp_total: np.ndarray, delta: float, basic_cap: np.ndarray | float
) -> np.ndarray | float:
    """Convert composed RDP totals to ``(eps, delta)``-DP.

    ``rdp_total`` holds the summed per-order RDP of the composition,
    shape ``(..., len(alpha_grid))``; the conversion is the improved
    RDP->DP bound [Canonne-Kamath-Steinke 2020]::

        eps = rdp(alpha) + log((alpha-1)/alpha) - (log delta + log alpha)/(alpha-1)

    minimized over the order grid, floored at 0, and finally min'ed with
    ``basic_cap`` — the exact ``alpha -> inf`` endpoint of the RR curve,
    i.e. pure sequential composition, which keeps the reported eps
    ``<= basic`` everywhere (including ``eps_per_round = 0`` -> 0).
    """
    alpha = _ALPHA_GRID
    conv = (
        rdp_total
        + np.log1p(-1.0 / alpha)
        - (math.log(delta) + np.log(alpha)) / (alpha - 1.0)
    )
    eps = np.maximum(conv.min(axis=-1), 0.0)
    return np.minimum(eps, basic_cap)


@dataclasses.dataclass(frozen=True)
class DPEvent:
    """One executed round's privacy parameters.

    ``epsilon`` is the full-participation per-round pure-DP cost
    (Theorem 3); ``q`` the client sampling rate of that round.
    """

    epsilon: float
    q: float = 1.0


def amplified_epsilon(eps: float, q: float) -> float:
    """Per-round eps after amplification by subsampling at rate ``q``.

    For a pure ``(eps, 0)``-DP mechanism run on a random subsample that
    includes each client with probability ``q``, the subsampled mechanism
    is ``(ln(1 + q*(e^eps - 1)), 0)``-DP. The bound holds for Poisson
    sampling and for uniform without-replacement sampling of ``m = q*M``
    of ``M`` clients [Balle-Barthe-Gaboardi 2018; Li et al. 2012] — the
    runtime's ``jax.random.choice(..., replace=False)`` cohort is the
    latter, so ``q = m_sampled / n_clients`` qualifies.

    Identities (relied on by the ledger and property-tested):

    * ``q >= 1`` returns ``eps`` **bit-identically** (short-circuit — no
      ``log1p(expm1(eps))`` float drift), so full participation matches
      the unamplified accounting exactly;
    * ``q <= 0`` or ``eps <= 0`` returns ``0.0``;
    * ``0 < q < 1`` gives ``0 < eps' < eps`` (strict tightening).
    """
    if eps <= 0.0:
        return 0.0
    if q >= 1.0:
        return float(eps)
    if q <= 0.0:
        return 0.0
    return math.log1p(q * math.expm1(eps))


def subsampled_composition(eps_per_round: float, rounds: int, q: float) -> float:
    """Sequential composition of ``rounds`` subsampled ``(eps, 0)`` rounds."""
    if rounds <= 0:
        return 0.0
    return amplified_epsilon(eps_per_round, q) * rounds


class PrivacyLedger:
    """Cumulative DP budget of an FL run, one event per executed round.

    Parameters fix the *homogeneous* per-round cost — ``eps_per_round``
    (Theorem 3's per-round eps; ``<= 0`` means DP disabled and every
    report is 0), the sampling rate ``q``, the ``accountant`` (one of
    :data:`ACCOUNTANTS`), and the ``delta_slack`` spent by the advanced
    accountant. Rounds are appended with :meth:`record_round`;
    :attr:`eps_spent` is the composed total under the configured
    accountant.
    """

    def __init__(
        self,
        eps_per_round: float,
        q: float = 1.0,
        accountant: str = "subsampled",
        delta_slack: float = DELTA_SLACK,
    ):
        if accountant not in ACCOUNTANTS:
            raise ValueError(
                f"unknown accountant {accountant!r}; available: {ACCOUNTANTS}"
            )
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"sampling rate q must be in [0, 1], got {q}")
        if not 0.0 < delta_slack < 1.0:
            raise ValueError(f"delta_slack must be in (0, 1), got {delta_slack}")
        self.eps_per_round_raw = max(float(eps_per_round), 0.0)
        self.q = float(q)
        self.accountant = accountant
        self.delta_slack = float(delta_slack)
        self._events: list[DPEvent] = []

    # -- event log -----------------------------------------------------------

    def record_round(self, n: int = 1) -> None:
        """Append ``n`` executed rounds at the configured (eps, q)."""
        self._events.extend(
            DPEvent(self.eps_per_round_raw, self.q) for _ in range(n)
        )

    def record(self, epsilon: float, q: float | None = None) -> None:
        """Append one round with explicit parameters (heterogeneous path),
        validated like the constructor's (negative eps clamps to 0)."""
        q = self.q if q is None else float(q)
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"sampling rate q must be in [0, 1], got {q}")
        self._events.append(DPEvent(max(float(epsilon), 0.0), q))

    @property
    def events(self) -> tuple[DPEvent, ...]:
        return tuple(self._events)

    @property
    def _homogeneous(self) -> bool:
        """True iff every recorded event carries the configured (eps, q)."""
        return all(
            e.epsilon == self.eps_per_round_raw and e.q == self.q
            for e in self._events
        )

    @property
    def rounds(self) -> int:
        return len(self._events)

    # -- per-round cost ------------------------------------------------------

    @property
    def per_round_epsilon(self) -> float:
        """The per-round eps the configured accountant composes over:
        amplified under ``subsampled``, raw otherwise."""
        if self.accountant == "subsampled":
            return amplified_epsilon(self.eps_per_round_raw, self.q)
        return self.eps_per_round_raw

    # -- composition ---------------------------------------------------------

    def compose(
        self,
        accountant: str | None = None,
        events: Sequence[DPEvent] | None = None,
    ) -> tuple[float, float]:
        """(eps_total, delta_total) of ``events`` (default: the recorded log).

        ``fsum`` keeps the homogeneous event log bit-identical to the
        closed forms in :meth:`trajectory` (the correctly-rounded sum of
        ``t`` copies of ``x`` equals the float product ``t * x``).
        """
        acc = accountant or self.accountant
        if acc not in ACCOUNTANTS:
            raise ValueError(
                f"unknown accountant {acc!r}; available: {ACCOUNTANTS}"
            )
        ev = self._events if events is None else list(events)
        if not ev:
            return 0.0, 0.0
        if acc == "basic":
            return math.fsum(e.epsilon for e in ev), 0.0
        if acc == "subsampled":
            return math.fsum(amplified_epsilon(e.epsilon, e.q) for e in ev), 0.0
        if acc == "renyi":
            if all(e.epsilon <= 0.0 for e in ev):
                return 0.0, 0.0
            # Per-order fsum: for a homogeneous log the correctly-rounded
            # sum of t equal curves is the float product t * rdp, keeping
            # this bit-identical to the closed form in trajectory().
            curves = np.stack(
                [rr_renyi_divergence(e.epsilon, _ALPHA_GRID) for e in ev]
            )
            rdp_tot = np.asarray([math.fsum(col) for col in curves.T])
            basic = math.fsum(e.epsilon for e in ev)
            return (
                float(renyi_epsilon(rdp_tot, self.delta_slack, basic)),
                self.delta_slack,
            )
        # advanced: heterogeneous Dwork-Rothblum-Vadhan strong composition
        s2 = math.fsum(e.epsilon * e.epsilon for e in ev)
        lin = math.fsum(e.epsilon * math.expm1(e.epsilon) for e in ev)
        return float(strong_composition(s2, lin, self.delta_slack)), self.delta_slack

    @property
    def eps_spent(self) -> float:
        return self.compose()[0]

    @property
    def delta_spent(self) -> float:
        return self.compose()[1]

    def eps_at(self, rounds: int, accountant: str | None = None) -> float:
        """Closed-form cumulative eps after ``rounds`` homogeneous rounds
        (no recording needed — what ``rounds`` events *would* cost)."""
        if rounds <= 0:
            return 0.0
        return float(self.trajectory(rounds, accountant)[-1])

    def trajectory(
        self, rounds: int | None = None, accountant: str | None = None
    ) -> np.ndarray:
        """Cumulative-eps curve after rounds ``1..T`` (float64, shape (T,)).

        An explicit ``rounds`` gives the *hypothetical* homogeneous
        closed form — what ``T`` rounds at the configured (eps, q) would
        cost — bit-identical to recording ``T`` such events and composing
        (see :meth:`compose`); the campaign engine attaches this as the
        per-round ``eps_spent`` metric. With ``rounds=None`` the curve
        follows the *recorded* log: a heterogeneous log (per-round
        :meth:`record` overrides) composes each prefix exactly, so the
        last point always equals :attr:`eps_spent`.
        """
        acc = accountant or self.accountant
        if acc not in ACCOUNTANTS:
            raise ValueError(
                f"unknown accountant {acc!r}; available: {ACCOUNTANTS}"
            )
        if rounds is None and not self._homogeneous:
            ev = self._events
            return np.asarray(
                [self.compose(acc, ev[:k])[0] for k in range(1, len(ev) + 1)]
            )
        T = self.rounds if rounds is None else int(rounds)
        t = np.arange(1, T + 1, dtype=np.float64)
        eps = self.eps_per_round_raw
        if acc == "advanced":
            return strong_composition(
                t * (eps * eps), t * (eps * math.expm1(eps)), self.delta_slack
            )
        if acc == "renyi":
            if eps <= 0.0:
                return np.zeros_like(t)
            # t copies of one RDP curve compose to t * rdp (fsum of equal
            # terms is the float product, matching compose() bit-for-bit).
            rdp_t = t[:, None] * rr_renyi_divergence(eps, _ALPHA_GRID)[None, :]
            return np.asarray(renyi_epsilon(rdp_t, self.delta_slack, eps * t))
        per = amplified_epsilon(eps, self.q) if acc == "subsampled" else eps
        return per * t

    def report(self) -> dict[str, dict[str, float]]:
        """All four accountants evaluated on the same event log."""
        out = {}
        for acc in ACCOUNTANTS:
            eps, delta = self.compose(acc)
            out[acc] = {"eps": eps, "delta": delta}
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"PrivacyLedger(eps_per_round={self.eps_per_round_raw}, q={self.q}, "
            f"accountant={self.accountant!r}, rounds={self.rounds}, "
            f"eps_spent={self.eps_spent:.6g})"
        )
