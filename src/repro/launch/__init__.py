"""Launch layer: production mesh, multi-pod dry-run, training driver.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
dedicated process (python -m repro.launch.dryrun).
"""

from .mesh import make_production_mesh, make_host_mesh
from .fl_step import DistFLConfig, make_fl_train_step

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "DistFLConfig",
    "make_fl_train_step",
]
