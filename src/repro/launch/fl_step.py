"""Distributed PRoBit+ FL round for the production mesh (pjit path).

Cluster-simulated cross-silo FL (DESIGN.md §3): the global model is
FSDP+TP-sharded over ("data", "model"); a ``lax.scan`` multiplexes clients
in time, while the "pod" axis (when present) runs client groups in space.
Per scan step each pod trains ONE client (its batch data-parallel over
"data"), compresses its per-leaf delta through the shared packed wire,
and folds the packed codes into int32 vote counts. After the scan the
Eq.-13 ML estimate updates the global model and the dynamic-b controller
consumes the clients' one-bit loss votes.

Wire contract (per parameter leaf)
----------------------------------
Nothing quantization-related is re-implemented here: the client at cohort
position ``g`` compresses leaf ``l`` with the shared ``ClientCompressor``
(``build_pipeline("probit_plus", rand_bits=...)``) keyed
``fold_in(fold_in(round_key, l), g)`` — the
:mod:`repro.fl.pytree_wire` schedule — so the mesh path, the CPU
simulation (``fl/rounds.py``), the pytree simulation wire, and the Pallas
kernels all emit bit-for-bit the same ``PackedWire`` rows:
``padded_dim(d_l)/8`` uint8 bytes per leaf per client, **1 bit per
parameter on the uplink** (the paper's 32x saving vs f32; leaves with
``size % 8 != 0`` pad with deterministic 0 bits that ``finalize`` slices
off). ``rand_bits=16`` selects the uint16-draw wire (same schedule,
half the RNG memory; see :func:`repro.core.quantizer.threshold_u16` —
saturated |delta| >= b votes stay certain, the sign-flip bug the shared
path regression-guards).

Count-dtype policy
------------------
The uint8 claim applies to the packed *wire rows only*. Vote counts
accumulate in **int32** (matching ``ServerAggregator.init_counts``) —
exact for cohorts up to 2**31 clients; a uint8 accumulator silently
wraps mod 256 past 255 clients (the bug this rewrite fixes). Cross-pod
traffic is the psum of the int32 count pytree induced by the sum over
the pod axis.

State
-----
This step is stateless round-to-round (params, b) -> (params, b): EF
residuals and top-k masks need a per-client per-parameter buffer, which
lives in :class:`repro.fl.pytree_wire.PytreeWireState` on the stateful
simulation path — the mesh step runs the EF-off, dense-packed wire.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import build_pipeline
from ..core.bcontrol import BControlConfig, BState, update_b_from_vote
from ..distributed import current_mesh
from ..fl.pytree_wire import leaf_key
from ..models import train_loss
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DistFLConfig:
    clients_per_round: int = 16  # total across pods; must be divisible by n_pods
    local_steps: int = 1
    lr: float = 0.01
    lam: float = 0.2
    b_up: float = 1.01
    b_down: float = 0.98
    # aggregator: "probit_plus" (paper, 1-bit votes) or "fedavg_fp32"
    # (full-precision baseline — what the paper's 32x claim compares against)
    aggregator: str = "probit_plus"
    # quantizer randomness width: 16-bit draws halve the uniform-draw
    # memory vs f32 at a 2^-16 probability granularity (§Perf lever)
    rand_bits: int = 32


def bcontrol_config(fl: DistFLConfig) -> BControlConfig:
    """The b-controller config this step shares with ``fl/rounds.py``."""
    return BControlConfig(mode="dynamic", up=fl.b_up, down=fl.b_down)


def update_b_dist(b: jax.Array, vote: jax.Array, fl: DistFLConfig) -> jax.Array:
    """One controller step from the summed loss-bit vote.

    Routed through :func:`repro.core.bcontrol.update_b_from_vote` — the
    same function the simulation rounds call — so tie-vote handling
    (vote == 0 contracts by ``down``) can never drift between the mesh
    path and ``fl/rounds.py``.
    """
    state = update_b_from_vote(
        BState(b=b, prev_vote=jnp.float32(0.0)), vote, bcontrol_config(fl)
    )
    return state.b


def _n_pods() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get("pod", 1)


def _constrain_clients(tree, leaf_specs):
    """Constrain a (n_pods, ...)-leading pytree: leading dim over "pod"."""
    mesh = current_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return tree

    def one(x, spec):
        return jax.lax.with_sharding_constraint(x, P("pod", *spec))

    return jax.tree.map(one, tree, leaf_specs)


def _constrain_pod(tree):
    """Constrain wire/count leaves (n_pods, ...): leading dim over "pod"."""
    mesh = current_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return tree
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, P("pod")), tree
    )


def make_fl_train_step(cfg: ModelConfig, fl: DistFLConfig, param_specs):
    """Returns train_step(params, b, batch, key) -> (params, b, metrics).

    batch leaves: (m_seq, n_pods, local_steps, per_batch, ...) where
    m_seq * n_pods = clients_per_round. Metrics include the per-round
    uplink ``wire_bytes`` (packed, as shipped) next to the
    ``wire_bytes_int8`` / ``wire_bytes_f32`` baselines.
    """

    # The full shared pipeline: Eq.-5 compressor (client half) and the
    # count-accumulate -> Eq.-13 server half — the same objects the CPU
    # simulation and the kernels dispatch through.
    pipeline = build_pipeline("probit_plus", rand_bits=fl.rand_bits)
    compressor, server = pipeline.compressor, pipeline.server

    def train_step(params, b, batch, key):
        m_seq = jax.tree.leaves(batch)[0].shape[0]
        n_pods = jax.tree.leaves(batch)[0].shape[1]
        m_total = m_seq * n_pods
        probit = fl.aggregator == "probit_plus"

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        dims = [int(w.size) for w in p_leaves]
        pbytes = [compressor.wire_bytes(d) for d in dims]

        def one_client(client_batch, gidx):
            """client_batch leaves: (local_steps, per_batch, ...); ``gidx``
            is the client's cohort position — it keys the quantizer rows."""

            def lstep(local, sb):
                loss, g = jax.value_and_grad(train_loss)(local, sb, cfg)
                new = jax.tree.map(
                    lambda w, gg, w0: (
                        w - fl.lr * (gg.astype(jnp.float32) + fl.lam * (w - w0).astype(jnp.float32))
                    ).astype(w.dtype),
                    local,
                    g,
                    params,
                )
                return new, loss

            local, losses = jax.lax.scan(lstep, params, client_batch)
            delta = jax.tree.map(lambda a, c: a - c, local, params)
            if probit:
                d_leaves = jax.tree.leaves(delta)
                out = [
                    compressor.compress(
                        leaf_key(key, i),
                        dl.reshape(1, d).astype(jnp.float32),
                        b,
                        jnp.zeros((), jnp.float32),  # EF off on the mesh path
                        row_offset=gidx,
                    )[0].packed
                    for i, (dl, d) in enumerate(zip(d_leaves, dims))
                ]
            else:
                out = delta  # full-precision upload (FedAvg baseline)
            return out, (losses[0], losses[-1])

        def client_chunk(carry, xs):
            """Per-pod partial accumulation: the (n_pods, ...) accumulator
            stays sharded over "pod", so the client loop is collective-free
            across pods; ONE deferred psum happens after the scan. The
            uplink itself is the packed uint8 wire (1 bit/param/client);
            what crosses pods is the int32 count pytree."""
            acc, votes = carry
            cb, s = xs  # leaves (n_pods, local_steps, pb, ...); s = scan step
            gidx = s * n_pods + jnp.arange(n_pods)
            contrib, (l0, l1) = jax.vmap(one_client)(cb, gidx)
            if probit:
                # contrib: per-leaf packed (n_pods, 1, P_i) uint8 wire rows
                contrib = _constrain_pod(contrib)
                acc = [
                    jax.vmap(server.accumulate_counts)(a, w)
                    for a, w in zip(acc, contrib)
                ]
            else:
                contrib = _constrain_clients(contrib, param_specs)
                acc = jax.tree.map(
                    lambda c, d: c + d.astype(jnp.float32), acc, contrib
                )
            votes = votes + jnp.sum(jnp.where(l1 < l0, 1, -1))
            return (acc, votes), (jnp.mean(l0), jnp.mean(l1))

        if probit:
            # per-leaf int32 vote-count carries, one row per pod
            acc0 = [
                jnp.tile(server.init_counts(p)[None], (n_pods, 1))
                for p in pbytes
            ]
            acc0 = _constrain_pod(acc0)
        else:
            acc0 = jax.tree.map(
                lambda w: jnp.zeros((n_pods,) + w.shape, jnp.float32), params
            )
            acc0 = _constrain_clients(acc0, param_specs)
        (acc, votes), (loss0, loss1) = jax.lax.scan(
            client_chunk, (acc0, jnp.int32(0)), (batch, jnp.arange(m_seq))
        )
        # the single cross-pod reduction: int32 counts (exact up to 2**31
        # clients — NOT the uint8 wire dtype) / f32 delta sums
        if probit:
            acc = [jnp.sum(a, axis=0, dtype=jnp.int32) for a in acc]

            # Eq. 13 ML estimate per leaf from the exact vote counts
            new_leaves = [
                (
                    w.astype(jnp.float32)
                    + server.finalize(cnt, m_total, compressor.b_vector(d, b)).reshape(w.shape)
                ).astype(w.dtype)
                for w, cnt, d in zip(p_leaves, acc, dims)
            ]
            new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
            wire_row_bytes = sum(pbytes)
        else:
            acc = jax.tree.map(lambda a: jnp.sum(a, axis=0), acc)
            new_params = jax.tree.map(
                lambda s, w: (w.astype(jnp.float32) + s / m_total).astype(w.dtype),
                acc,
                params,
            )
            wire_row_bytes = 4 * sum(dims)

        b_new = update_b_dist(b, votes, fl)
        metrics = {
            "loss_first": jnp.mean(loss0),
            "loss_last": jnp.mean(loss1),
            "b": b_new,
            # f32 round-trips ~7 digits; exact ints come from
            # fl.pytree_wire.pytree_wire_bytes (static, outside the jit)
            "wire_bytes": jnp.float32(m_total * wire_row_bytes),
            "wire_bytes_int8": jnp.float32(m_total * sum(dims)),
            "wire_bytes_f32": jnp.float32(m_total * 4 * sum(dims)),
        }
        return new_params, b_new, metrics

    return train_step
