"""Distributed PRoBit+ FL round for the production mesh (pjit path).

Cluster-simulated cross-silo FL (DESIGN.md §3): the global model is
FSDP+TP-sharded over ("data", "model"); a ``lax.scan`` multiplexes clients
in time, while the "pod" axis (when present) runs client groups in space.
Per scan step each pod trains ONE client (its batch data-parallel over
"data"), quantizes ``delta`` with the Eq.-5 compressor, and accumulates
uint8 vote counts. Cross-pod traffic is the psum of the count pytree —
1 byte/param instead of 4 (fp32 FedAvg), the paper's insight at the
slowest-link level. After the scan the Eq.-13 ML estimate updates the
global model, and the dynamic-b controller consumes the clients' one-bit
loss votes.

The quantize probability and the count->theta estimate are NOT
re-implemented here: both come from the shared aggregation pipeline
(``repro.core.build_pipeline("probit_plus")``) so the mesh path speaks
the same wire protocol as the simulation and the Pallas kernels.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import build_pipeline
from ..distributed import current_mesh, spec_for
from ..models import train_loss
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DistFLConfig:
    clients_per_round: int = 16  # total across pods; must be divisible by n_pods
    local_steps: int = 1
    lr: float = 0.01
    lam: float = 0.2
    b_up: float = 1.01
    b_down: float = 0.98
    # aggregator: "probit_plus" (paper, 1-bit votes) or "fedavg_fp32"
    # (full-precision baseline — what the paper's 32x claim compares against)
    aggregator: str = "probit_plus"
    # quantizer randomness width: 16-bit thresholds halve the uniform-draw
    # memory vs f32 at a 2^-16 probability granularity (§Perf lever)
    rand_bits: int = 32


def _n_pods() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get("pod", 1)


def _constrain_clients(tree, leaf_specs):
    """Constrain a (n_pods, ...)-leading pytree: leading dim over "pod"."""
    mesh = current_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return tree

    def one(x, spec):
        return jax.lax.with_sharding_constraint(x, P("pod", *spec))

    return jax.tree.map(one, tree, leaf_specs)


def make_fl_train_step(cfg: ModelConfig, fl: DistFLConfig, param_specs):
    """Returns train_step(params, b, batch, key) -> (params, b, metrics).

    batch leaves: (m_seq, n_pods, local_steps, per_batch, ...) where
    m_seq * n_pods = clients_per_round.
    """

    # Shared pipeline pieces: Eq.-5 bit probability (client half) and the
    # Eq.-13 count->theta estimate (server half) — same objects the CPU
    # simulation and kernels dispatch through.
    pipeline = build_pipeline("probit_plus")

    def quantize_leaf(key, delta, b):
        p = pipeline.compressor.bit_probability(delta, b)
        if fl.rand_bits == 16:
            # 16-bit threshold compare: halves random-draw memory; the
            # probability granularity of 2^-16 adds relative bias < 1.6e-5.
            thresh = (p * 65536.0).astype(jnp.uint16)
            u = jax.random.bits(key, delta.shape, jnp.uint16)
            return u < thresh
        u = jax.random.uniform(key, delta.shape, jnp.float32)
        return u < p  # one-bit code; True <=> +1

    def train_step(params, b, batch, key):
        m_seq = jax.tree.leaves(batch)[0].shape[0]
        n_pods = jax.tree.leaves(batch)[0].shape[1]
        m_total = m_seq * n_pods
        probit = fl.aggregator == "probit_plus"

        def one_client(client_batch, ckey):
            """client_batch leaves: (local_steps, per_batch, ...)."""

            def lstep(local, sb):
                loss, g = jax.value_and_grad(train_loss)(local, sb, cfg)
                new = jax.tree.map(
                    lambda w, gg, w0: (
                        w - fl.lr * (gg.astype(jnp.float32) + fl.lam * (w - w0).astype(jnp.float32))
                    ).astype(w.dtype),
                    local,
                    g,
                    params,
                )
                return new, loss

            local, losses = jax.lax.scan(lstep, params, client_batch)
            delta = jax.tree.map(lambda a, c: a - c, local, params)
            if probit:
                leaves, treedef = jax.tree_util.tree_flatten(delta)
                out = jax.tree_util.tree_unflatten(
                    treedef,
                    [
                        quantize_leaf(jax.random.fold_in(ckey, i), leaf, b)
                        for i, leaf in enumerate(leaves)
                    ],
                )
            else:
                out = delta  # full-precision upload (FedAvg baseline)
            return out, (losses[0], losses[-1])

        def client_chunk(carry, xs):
            """Per-pod partial accumulation: the (n_pods, ...) accumulator
            stays sharded over "pod", so the client loop is collective-free
            across pods; ONE deferred uint8 psum happens after the scan —
            that psum IS the paper's one-bit aggregation on the wire
            (1 byte/param of counts vs 4 bytes/param of fp32 deltas)."""
            acc, votes = carry
            cb, ck = xs  # leaves (n_pods, local_steps, pb, ...)
            contrib, (l0, l1) = jax.vmap(one_client)(cb, ck)
            contrib = _constrain_clients(contrib, param_specs)
            if probit:
                acc = jax.tree.map(
                    lambda c, bits: c + bits.astype(jnp.uint8), acc, contrib
                )
            else:
                acc = jax.tree.map(
                    lambda c, d: c + d.astype(jnp.float32), acc, contrib
                )
            votes = votes + jnp.sum(jnp.where(l1 < l0, 1, -1))
            return (acc, votes), (jnp.mean(l0), jnp.mean(l1))

        acc0 = jax.tree.map(
            lambda w: jnp.zeros((n_pods,) + w.shape, jnp.uint8 if probit else jnp.float32),
            params,
        )
        acc0 = _constrain_clients(acc0, param_specs)
        keys = jax.random.split(key, m_seq * n_pods).reshape(m_seq, n_pods, 2)
        (acc, votes), (loss0, loss1) = jax.lax.scan(
            client_chunk, (acc0, jnp.int32(0)), (batch, keys)
        )
        # the single cross-pod aggregation psum (uint8 counts / f32 deltas)
        acc = jax.tree.map(
            lambda a: jnp.sum(a, axis=0, dtype=a.dtype), acc
        )

        if probit:
            # Eq. 13 ML estimate; counts are exact vote totals across pods
            # (the psum over "pod" is induced by the sum over the client dim)
            def upd(cnt, w):
                theta = pipeline.server.from_counts(cnt, m_total, b)
                return (w.astype(jnp.float32) + theta).astype(w.dtype)
        else:

            def upd(s, w):
                return (w.astype(jnp.float32) + s / m_total).astype(w.dtype)

        new_params = jax.tree.map(upd, acc, params)
        b_new = jnp.where(votes > 0, b * fl.b_up, b * fl.b_down)
        metrics = {"loss_first": jnp.mean(loss0), "loss_last": jnp.mean(loss1), "b": b_new}
        return new_params, b_new, metrics

    return train_step
