"""End-to-end distributed FL training driver.

Runs REAL training (not a dry-run) of any ``--arch`` on synthetic LM data
using the distributed PRoBit+ round from fl_step.py. On this CPU container
it is used with ``--reduced`` (family-preserving small variant, 1-device
mesh); on a TPU fleet the same entry point drives the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --rounds 5 --clients 4 --seq 128 --per-batch 2

Flags beyond the basics:
  --aggregator {probit_plus,fedavg_fp32}  packed one-bit wire (default)
      vs the full-precision FedAvg baseline the 32x claim compares to
  --rand-bits {32,16}   quantizer draw width (16 halves RNG memory)
  --json-out PATH       write per-round metrics + wire-byte report JSON
  --smoke               exit nonzero unless every round's losses are
      finite and the wire-byte report is nonzero (CI gate)
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpoint import save_checkpoint
from ..core import build_pipeline
from ..data import make_lm_streams
from ..fl.pytree_wire import pytree_wire_bytes
from ..models import build_specs, sample_batch
from ..models.spec import init_params, param_pspecs, count_params
from .fl_step import DistFLConfig, make_fl_train_step
from ..distributed import set_mesh
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--per-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=0.2)
    ap.add_argument("--b-init", type=float, default=0.01)
    ap.add_argument(
        "--aggregator", default="probit_plus",
        choices=["probit_plus", "fedavg_fp32"],
    )
    ap.add_argument("--rand-bits", type=int, default=32, choices=[16, 32])
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    if cfg.encoder_only or cfg.frontend != "none":
        print(f"note: {args.arch} uses the {cfg.frontend or 'encoder'} input path")

    mesh = (
        make_production_mesh() if args.production_mesh else make_host_mesh()
    )
    with set_mesh(mesh):
        specs = build_specs(cfg)
        pspecs = param_pspecs(specs, fsdp_axis="data")
        params = init_params(specs, jax.random.PRNGKey(0))
        print(f"{cfg.name}: {count_params(specs)/1e6:.1f}M params, mesh={mesh.shape}")

        fl = DistFLConfig(
            clients_per_round=args.clients,
            local_steps=args.local_steps,
            lr=args.lr,
            lam=args.lam,
            aggregator=args.aggregator,
            rand_bits=args.rand_bits,
        )
        step = jax.jit(make_fl_train_step(cfg, fl, pspecs))
        b = jnp.float32(args.b_init)

        # Exact static per-round uplink accounting (the jitted metric is
        # the same number in f32): packed wire vs int8 vs f32 baselines.
        wire_pipeline = build_pipeline(
            "probit_plus" if args.aggregator == "probit_plus" else "fedavg",
            rand_bits=args.rand_bits,
        )
        wire = pytree_wire_bytes(wire_pipeline, params, args.clients)
        print(
            f"uplink/round: {wire['wire_bytes']/1e6:.3f} MB packed "
            f"(ideal {wire['wire_bytes_ideal']/1e6:.3f}) vs "
            f"{wire['wire_bytes_int8']/1e6:.3f} MB int8 ({wire['wire_bytes_int8']/max(wire['wire_bytes_ideal'],1):.1f}x) / "
            f"{wire['wire_bytes_f32']/1e6:.3f} MB f32 ({wire['wire_bytes_f32']/max(wire['wire_bytes_ideal'],1):.1f}x)"
        )

        streams = make_lm_streams(
            0, args.clients, cfg.vocab, args.seq + 1,
            args.local_steps * args.per_batch * args.rounds,
        )
        key = jax.random.PRNGKey(1)
        history = []
        for r in range(args.rounds):
            t0 = time.time()
            # batch leaves: (m_seq=clients, n_pods=1, local_steps, pb, ...)
            toks = np.stack(
                [
                    s[r * args.local_steps * args.per_batch : (r + 1) * args.local_steps * args.per_batch]
                    .reshape(args.local_steps, args.per_batch, args.seq + 1)
                    for s in streams
                ]
            )[:, None]
            batch = {
                "tokens": jnp.asarray(toks[..., :-1]),
                "labels": jnp.asarray(toks[..., 1:]),
            }
            if cfg.frontend == "vision":
                b_shape = toks.shape[:4]
                p = cfg.frontend_tokens
                batch = {
                    "patches": 0.02 * jnp.ones(b_shape + (p, cfg.d_model), jnp.bfloat16),
                    "tokens": batch["tokens"],
                    "labels": batch["labels"],
                }
            elif cfg.frontend == "audio":
                b_shape = toks.shape[:4]
                batch = {
                    "feats": 0.02 * jnp.ones(b_shape + (args.seq, cfg.d_model), jnp.bfloat16),
                    "labels": jnp.asarray(toks[..., :-1] % cfg.vocab),
                    "mask": jnp.ones(b_shape + (args.seq,), bool),
                }
            key, kr = jax.random.split(key)
            params, b, metrics = step(params, b, batch, kr)
            history.append(
                {
                    "round": r,
                    "loss_first": float(metrics["loss_first"]),
                    "loss_last": float(metrics["loss_last"]),
                    "b": float(b),
                    "wire_bytes": float(metrics["wire_bytes"]),
                    "seconds": time.time() - t0,
                }
            )
            print(
                f"round {r}: loss {history[-1]['loss_first']:.4f} -> "
                f"{history[-1]['loss_last']:.4f}  b={float(b):.5f}  "
                f"wire={history[-1]['wire_bytes']/1e6:.3f}MB  "
                f"({history[-1]['seconds']:.1f}s)"
            )
        if args.ckpt_dir:
            path = save_checkpoint(args.ckpt_dir, args.rounds, params, {"arch": cfg.name})
            print("checkpoint:", path)
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(
                    {
                        "arch": cfg.name,
                        "aggregator": args.aggregator,
                        "rand_bits": args.rand_bits,
                        "clients": args.clients,
                        "wire": wire,
                        "rounds": history,
                    },
                    f,
                    indent=2,
                )
            print("json:", args.json_out)
        if args.smoke:
            finite = all(
                np.isfinite(h["loss_first"]) and np.isfinite(h["loss_last"])
                for h in history
            )
            wired = all(h["wire_bytes"] > 0 for h in history) and wire["wire_bytes"] > 0
            if not (finite and wired):
                print(f"SMOKE FAIL: finite={finite} wired={wired}", file=sys.stderr)
                sys.exit(1)
            print("SMOKE OK")


if __name__ == "__main__":
    main()
