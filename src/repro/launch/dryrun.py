import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 512-chip production mesh
# out of host placeholder devices; smoke tests / benches see 1 CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
combination on the production mesh and extract roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out reports/
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..distributed import set_mesh, spec_for, use_batch_axes, use_rules
from ..models import (
    SHAPES,
    abstract_params,
    build_specs,
    cache_logical,
    init_cache,
    input_logical,
    input_specs,
    prefill,
    serve_step,
)
from ..models.config import ModelConfig, ShapeConfig
from ..models.spec import param_pspecs
from .analysis import roofline_terms
from .flopcount import count_fn
from .fl_step import DistFLConfig, make_fl_train_step
from .mesh import make_production_mesh

SKIPS: dict[tuple[str, str], str] = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no autoregressive decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no autoregressive decode step",
}

# long_500k window variant for full-attention archs (DESIGN.md §5)
LONG_WINDOW = 8192


def cache_plan(cfg: ModelConfig, shape: ShapeConfig) -> tuple[int, int]:
    """(cache_len, ring_window) for decode shapes."""
    if "attn" not in cfg.pattern:
        return 8, 0  # no attention cache; minimal placeholder length
    if cfg.sliding_window and shape.seq_len > cfg.sliding_window:
        return cfg.sliding_window, cfg.sliding_window
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        return LONG_WINDOW, LONG_WINDOW
    return shape.seq_len, 0


def _sds(shape, dtype, spec, mesh):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _abstract_tree_with_sharding(abs_tree, logical_tree, mesh):
    def one(a, logical):
        spec = spec_for(tuple(logical), a.shape)
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(
        one, abs_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh, fl_clients: int = 16, fl_agg: str = "probit_plus", rand_bits: int = 32, fsdp: bool = True):
    """Returns (fn, abstract_args) ready for jit(...).lower(*args)."""
    n_pods = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("pod", 1)
    specs = build_specs(cfg)
    pspecs = param_pspecs(specs, fsdp_axis="data" if fsdp else None)
    params_abs = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        abstract_params(specs),
        pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    if shape.kind == "train":
        m_seq = fl_clients // n_pods
        pb = shape.global_batch // fl_clients
        assert pb >= 1, (shape.name, fl_clients)
        struct = input_specs(cfg, pb, shape.seq_len, "train")
        logical = input_logical(cfg, pb, shape.seq_len, "train")

        def expand(a, log):
            sh = (m_seq, n_pods, 1) + a.shape  # (clients_seq, pods, local_steps, ...)
            spec = spec_for(("clients",) + tuple(log), (n_pods,) + a.shape)
            entries = list(spec) + [None] * (1 + len(a.shape) - len(spec))
            full = P(None, entries[0], None, *entries[1:])
            return jax.ShapeDtypeStruct(sh, a.dtype, sharding=NamedSharding(mesh, full))

        batch_abs = jax.tree.map(
            expand, struct, logical,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        b_abs = _sds((), jnp.float32, P(), mesh)
        key_abs = _sds((2,), jnp.uint32, P(), mesh)
        step = make_fl_train_step(
            cfg,
            DistFLConfig(clients_per_round=fl_clients, aggregator=fl_agg, rand_bits=rand_bits),
            pspecs,
        )
        return step, (params_abs, b_abs, batch_abs, key_abs)

    if shape.kind == "prefill":
        struct = input_specs(cfg, shape.global_batch, shape.seq_len, "prefill")
        logical = input_logical(cfg, shape.global_batch, shape.seq_len, "prefill")
        batch_abs = _abstract_tree_with_sharding(struct, logical, mesh)
        fn = lambda params, batch: prefill(params, batch, cfg)
        return fn, (params_abs, batch_abs)

    # decode
    cache_len, window = cache_plan(cfg, shape)
    struct = input_specs(cfg, shape.global_batch, shape.seq_len, "decode")
    logical = input_logical(cfg, shape.global_batch, shape.seq_len, "decode")
    batch_abs = _abstract_tree_with_sharding(struct, logical, mesh)
    cache_abs_raw = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch, cache_len))
    clog = cache_logical(cfg)
    cache_abs = _abstract_tree_with_sharding(cache_abs_raw, clog, mesh)
    pos_abs = _sds((), jnp.int32, P(), mesh)

    def fn(params, cache, batch, pos):
        return serve_step(params, cache, batch, pos, cfg, window)

    return fn, (params_abs, cache_abs, batch_abs, pos_abs)


def run_case(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    fl_clients: int = 16,
    indexed: bool = False,
    tag: str = "",
    fl_agg: str = "probit_plus",
    rand_bits: int = 32,
    serve_2d: bool = False,
    layer_remat: bool = False,
    remat: str = "full",
    ssm_dtype: str = "float32",
    pure_dp: bool = False,
) -> dict:
    from ..models.model import indexed_params, inner_remat, remat_policy
    from ..models.ssm import ssm_state_dtype

    shape = SHAPES[shape_name]
    report: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": tag or ("indexed" if indexed else "baseline"),
    }
    if (arch, shape_name) in SKIPS:
        report["status"] = "skipped"
        report["reason"] = SKIPS[(arch, shape_name)]
        return report
    cfg = configs.get_config(arch)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        import contextlib
        if pure_dp:
            # small-model layout: no tensor parallelism at all — weights
            # replicated, clients/batch over data (+pod); the only
            # collective left is the per-client gradient all-reduce.
            rules_ctx = use_rules(
                ff=(), heads=(), kv=(), vocab=(), seq=(), experts=(),
            )
            batch_ax = ("pod", "data") if (multi_pod and shape.kind != "train") else ("data",)
            fsdp = False
        elif serve_2d and shape.kind == "decode":
            # 2D weight-stationary serving: weights sharded over BOTH axes
            # (no per-token FSDP re-gather); decode activations are tiny, so
            # resharding them between the batch-parallel attention (cache
            # stays batch@data, seq@model) and the weight-sharded matmuls
            # is cheap.
            rules_ctx = use_rules(
                ff=("model", "data"),
                vocab=("model", "data"),
                experts=("model",),
            )
            batch_ax: tuple = ("data",)
            fsdp = False
        else:
            rules_ctx = contextlib.nullcontext()
            batch_ax = ("pod", "data") if (multi_pod and shape.kind != "train") else ("data",)
            fsdp = True
        with set_mesh(mesh), indexed_params(indexed), rules_ctx, \
                inner_remat(layer_remat), remat_policy(remat), ssm_state_dtype(ssm_dtype):
            with use_batch_axes(*batch_ax):
                fn, args = build_lowerable(cfg, shape, mesh, fl_clients, fl_agg, rand_bits, fsdp)
                lowered = jax.jit(fn).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                cost = compiled.cost_analysis()
                try:
                    mem = compiled.memory_analysis()
                except Exception:
                    mem = None
                jaxpr_counts = count_fn(fn, *args)
                n_dev = mesh.size
                terms = roofline_terms(
                    cost, mem, compiled.as_text(), jaxpr_counts, n_dev
                )
        report.update(terms)
        report["status"] = "ok"
        report["t_lower_s"] = round(t_lower, 1)
        report["t_compile_s"] = round(t_compile, 1)
        report["n_params"] = cfg.n_params()
        report["n_active_params"] = cfg.n_active_params()
        if mem is not None:
            print(f"[{arch} x {shape_name} x {report['mesh']}] memory_analysis: "
                  f"args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB per device")
        print(f"[{arch} x {shape_name} x {report['mesh']}] cost_analysis: "
              f"flops/dev={terms['flops_per_device']:.3e} "
              f"bytes/dev={terms['bytes_per_device']:.3e} "
              f"coll={terms['collective_link_bytes']:.3e}B "
              f"bottleneck={terms['bottleneck']}")
    except Exception as e:  # a failure here is a bug in our sharding config
        report["status"] = "error"
        report["error"] = f"{type(e).__name__}: {e}"[:2000]
        report["traceback"] = traceback.format_exc()[-4000:]
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fl-clients", type=int, default=16)
    ap.add_argument("--fl-agg", default="probit_plus", choices=["probit_plus", "fedavg_fp32"])
    ap.add_argument("--serve-2d", action="store_true", help="2D weight-stationary decode layout (perf variant)")
    ap.add_argument("--layer-remat", action="store_true", help="nested per-layer remat inside the pattern unit (perf variant)")
    ap.add_argument("--remat", default="full", choices=["full", "dots"], help="remat policy for the unit scan")
    ap.add_argument("--ssm-dtype", default="float32", choices=["float32", "bfloat16"], help="SSM chunk-state dtype (perf variant)")
    ap.add_argument("--pure-dp", action="store_true", help="no tensor parallelism: replicated weights, data/client parallelism only (small-model perf variant)")
    ap.add_argument("--rand-bits", type=int, default=32, choices=[16, 32])
    ap.add_argument("--indexed-params", action="store_true",
                    help="per-iteration param gather inside the layer scan (perf variant)")
    ap.add_argument("--tag", default="", help="variant tag for the report filename")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    args = ap.parse_args()

    cases = (
        [(a, s) for a in configs.ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    results = []
    for arch, shape in cases:
        rep = run_case(
            arch, shape, args.multi_pod, args.fl_clients,
            indexed=args.indexed_params, tag=args.tag,
            fl_agg=args.fl_agg, rand_bits=args.rand_bits, serve_2d=args.serve_2d,
            layer_remat=args.layer_remat, remat=args.remat, ssm_dtype=args.ssm_dtype,
            pure_dp=args.pure_dp,
        )
        results.append(rep)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = f"__{args.tag}" if args.tag else ""
            tag = f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}{suffix}.json"
            with open(os.path.join(args.out, tag), "w") as f:
                json.dump(rep, f, indent=1, default=str)
        status = rep["status"]
        print(f"== {arch} x {shape}: {status} "
              f"{'(' + rep.get('reason', rep.get('error', ''))[:120] + ')' if status != 'ok' else ''}")
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n{len(results)} cases: {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
