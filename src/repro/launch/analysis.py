"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` gives per-device HLO FLOPs / bytes; collective
traffic is NOT included there, so we parse the post-SPMD optimized HLO and
sum link-byte estimates for every collective op using standard ring-
algorithm formulas:

  all-reduce       2 * bytes * (n-1)/n
  all-gather       bytes_out * (n-1)/n
  reduce-scatter   bytes_in  * (n-1)/n      (result-type reported: *(n-1))
  all-to-all       bytes * (n-1)/n
  collective-permute  bytes (point-to-point)

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?P<ret>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[([0-9,]+)\])?(?:T\(([0-9,]+)\))?"
)
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")

POD_STRIDE = 256  # device id = pod*256 + data*16 + model on the 2x16x16 mesh


def _iota_first_group(g: int, n: int, reshape: str | None, transpose: str | None):
    """Reconstruct the first replica group of an iota replica_groups attr."""
    import numpy as np

    total = g * n
    ids = np.arange(total)
    if reshape:
        dims = [int(x) for x in reshape.split(",")]
        ids = ids.reshape(dims)
        if transpose:
            ids = ids.transpose([int(x) for x in transpose.split(",")])
        ids = ids.reshape(g, n)
    else:
        ids = ids.reshape(g, n)
    return ids[0]


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: float
    group_size: int
    spans_pods: bool = False

    @property
    def link_bytes(self) -> float:
        n = max(self.group_size, 2)
        frac = (n - 1) / n
        if self.kind == "all-reduce":
            return 2.0 * self.result_bytes * frac
        if self.kind == "all-gather":
            return self.result_bytes * frac
        if self.kind == "reduce-scatter":
            # result is the scattered shard; input was n x larger
            return self.result_bytes * (n - 1)
        if self.kind == "all-to-all":
            return self.result_bytes * frac
        return self.result_bytes  # collective-permute


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> list[Collective]:
    out = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        gs, spans = 1, False
        gm = _GROUPS_RE.search(line)
        if gm:
            members = [int(t) for t in gm.group(1).split(",") if t.strip() != ""]
            gs = len(members)
            spans = bool(members) and (max(members) // POD_STRIDE != min(members) // POD_STRIDE)
        else:
            gm2 = _GROUPS_IOTA_RE.search(line)
            if gm2:
                g, n = int(gm2.group(1)), int(gm2.group(2))
                gs = n
                if g * n > POD_STRIDE:
                    try:
                        grp = _iota_first_group(g, n, gm2.group(3), gm2.group(4))
                        spans = int(grp.max()) // POD_STRIDE != int(grp.min()) // POD_STRIDE
                    except Exception:
                        spans = True  # conservative
        pm = _PERMUTE_PAIRS_RE.search(line)
        if pm and not spans:
            a, b = int(pm.group(1)), int(pm.group(2))
            spans = a // POD_STRIDE != b // POD_STRIDE
        out.append(Collective(m.group("op"), _shape_bytes(m.group("ret")), gs, spans))
    return out


def roofline_terms(cost: dict, mem, hlo_text: str, jaxpr_counts: dict | None = None, n_devices: int = 256) -> dict:
    """Per-device roofline terms (seconds) + raw quantities.

    HLO cost analysis counts while-loop bodies once, so when loop-aware
    jaxpr counts are supplied they provide the compute/memory terms and
    their ratio to the HLO numbers loop-corrects the HLO-parsed collective
    bytes (see flopcount.py).
    """
    colls = parse_collectives(hlo_text)
    coll_bytes = sum(c.link_bytes for c in colls)
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    flops, bytes_accessed = hlo_flops, hlo_bytes
    rho = 1.0
    if jaxpr_counts is not None:
        flops = jaxpr_counts["flops_total"] / n_devices
        if hlo_flops > 0:
            rho = max(flops / hlo_flops, 1.0)
        # memory term: post-fusion HLO bytes, loop-corrected. (The raw jaxpr
        # byte count is pre-fusion/logical and overstates HBM traffic.)
        bytes_accessed = hlo_bytes * rho
        coll_bytes *= rho
    by_kind: dict[str, float] = {}
    for c in colls:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.link_bytes
    cross_pod = sum(c.link_bytes for c in colls if c.spans_pods) * rho
    terms = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "loop_correction_rho": rho,
        "collective_link_bytes": coll_bytes,
        "cross_pod_link_bytes": cross_pod,
        "n_collectives": len(colls),
        "collectives_by_kind": {k: v * rho for k, v in by_kind.items()},
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": bytes_accessed / HBM_BW,
        "t_collective_s": coll_bytes / LINK_BW,
    }
    dom = max(
        ("compute", terms["t_compute_s"]),
        ("memory", terms["t_memory_s"]),
        ("collective", terms["t_collective_s"]),
        key=lambda kv: kv[1],
    )
    terms["bottleneck"] = dom[0]
    if mem is not None:
        terms["arg_bytes_per_device"] = int(mem.argument_size_in_bytes)
        terms["temp_bytes_per_device"] = int(mem.temp_size_in_bytes)
        terms["output_bytes_per_device"] = int(mem.output_size_in_bytes)
        terms["peak_bytes_per_device"] = int(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes
        )
    return terms
