"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real (1-device) CPU.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the API supports them
    (jax>=0.5); plain make_mesh on jax 0.4."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod doubles along a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate 1-device mesh for CPU integration tests of the
    distributed code path (same axis names as production)."""
    return make_mesh((1, model), ("data", "model"))


def make_campaign_mesh(n_devices: int | None = None):
    """1-D data mesh for campaign batch sharding.

    The campaign executor (``repro.sim.campaign``) lays each plan group's
    (cell, seed) batch axis on this mesh — cells are embarrassingly
    parallel, so a pure data mesh over all local devices is the right
    placement. Cross-host campaigns would swap this for a slice of
    :func:`make_production_mesh`'s "data" axis (ROADMAP follow-on).
    """
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh((n,), ("data",))
