"""Loop-aware FLOP / logical-byte counting from jaxprs.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scanned programs (layer scans, client scans, chunked
attention) by orders of magnitude. This module walks the jaxpr instead,
multiplying through ``lax.scan`` trip counts (and shard_map device counts),
giving exact totals for dot/conv plus elementwise traffic.

Used by the dry-run to produce the roofline's compute/memory terms; the
ratio jaxpr_flops / hlo_flops also serves as the loop-correction factor for
HLO-parsed collective bytes (collectives live in the same loops as the
flops to first order; documented in EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "add_any", "sub", "mul", "div", "max", "min", "pow", "rem",
    "exp", "log", "log1p", "tanh", "logistic", "rsqrt", "sqrt", "erf",
    "neg", "abs", "sign", "floor", "ceil", "round", "cos", "sin",
    "integer_pow", "select_n", "clamp", "cumsum", "cummax", "cumprod",
    "cumlogsumexp", "and", "or", "not", "xor", "eq", "ne", "lt", "le",
    "gt", "ge", "nextafter", "squeeze", "expand_dims",
}

_DATA_MOVEMENT = {
    "convert_element_type", "reshape", "transpose", "broadcast_in_dim",
    "concatenate", "slice", "dynamic_slice", "dynamic_update_slice",
    "gather", "scatter", "scatter-add", "scatter_add", "pad", "rev",
    "iota", "reduce_sum", "reduce_max", "reduce_min", "reduce_and",
    "reduce_or", "reduce_prod", "argmax", "argmin", "sort", "top_k",
}

_CALL_PARAM_NAMES = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _aval_size(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


class Counter:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.unknown_while = 0

    def count(self, jaxpr, mult: float = 1.0):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            in_b = sum(
                _aval_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval")
            )

            if name == "dot_general":
                dnums = eqn.params["dimension_numbers"]
                (lc, rc), (lb, rb) = dnums
                lhs = eqn.invars[0].aval
                k = math.prod(lhs.shape[i] for i in lc) if lc else 1
                out_sz = sum(_aval_size(v.aval) for v in eqn.outvars)
                self.flops += mult * 2.0 * out_sz * k
                self.bytes += mult * (in_b + out_b)
            elif name == "conv_general_dilated":
                rhs = eqn.invars[1].aval  # kernel
                out = eqn.outvars[0].aval
                groups = eqn.params.get("feature_group_count", 1)
                kernel_elems = math.prod(rhs.shape)  # spatial*in*out
                out_spatial_batch = _aval_size(out)
                # flops = 2 * out_elems * (kernel_size * in_ch / groups):
                # kernel_elems / out_ch = spatial * in_ch_per_group
                dn = eqn.params["dimension_numbers"]
                out_ch = rhs.shape[dn.rhs_spec[0]]
                self.flops += mult * 2.0 * out_spatial_batch * (kernel_elems / out_ch)
                self.bytes += mult * (in_b + out_b)
            elif name == "scan":
                length = eqn.params["length"]
                inner = eqn.params["jaxpr"].jaxpr
                self.count(inner, mult * length)
            elif name == "while":
                # no static trip count: count body once and record
                self.unknown_while += 1
                self.count(eqn.params["body_jaxpr"].jaxpr, mult)
            elif name == "cond":
                branches = eqn.params["branches"]
                if branches:
                    self.count(branches[0].jaxpr, mult)  # assume branch 0 cost
            elif name == "shard_map":
                mesh = eqn.params.get("mesh")
                n = 1
                if mesh is not None:
                    n = int(np.prod(list(mesh.shape.values())))
                self.count(eqn.params["jaxpr"], mult * n)
            elif name in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
                sub = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
                if sub is not None:
                    self.count(getattr(sub, "jaxpr", sub), mult)
            elif name in ("pjit", "closed_call", "core_call", "xla_call", "remat_call", "checkpoint", "remat", "remat2"):
                sub = None
                for key in _CALL_PARAM_NAMES:
                    if key in eqn.params:
                        sub = eqn.params[key]
                        break
                if sub is not None:
                    self.count(getattr(sub, "jaxpr", sub), mult)
            elif name in _ELEMENTWISE:
                out_sz = sum(_aval_size(v.aval) for v in eqn.outvars)
                self.flops += mult * out_sz
                self.bytes += mult * (in_b + out_b)
            elif name in _DATA_MOVEMENT:
                self.bytes += mult * (in_b + out_b)
            else:
                # unknown primitive: count data movement only
                self.bytes += mult * (in_b + out_b)


def count_fn(fn, *abstract_args) -> dict:
    """Trace ``fn`` and return loop-aware global flop/byte totals."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    c = Counter()
    c.count(closed.jaxpr)
    return {
        "flops_total": c.flops,
        "bytes_total": c.bytes,
        "unknown_while_loops": c.unknown_while,
    }
