"""Unified architecture configuration for the 10 assigned architectures
plus the paper's own FL models.

A model is a repeated *pattern unit* of blocks. Each block has a mixer
(attn | mamba | mlstm | slstm) and an FFN (dense | moe | none). The pattern
abstraction lets one scan-based forward cover dense, MoE, SSM, and hybrid
(Jamba-style 1:7 interleave) architectures with stacked per-position
parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mamba", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention
    rope: bool = True
    rope_theta: float = 1e6
    qkv_bias: bool = False
    causal: bool = True
    sliding_window: int = 0  # 0 = full attention; >0 native window

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # layer l uses MoE iff n_experts>0 and l % moe_every == moe_every-1
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # pattern of mixers, tiled to n_layers (len must divide n_layers)
    pattern: tuple[str, ...] = ("attn",)

    ffn_act: str = "swiglu"  # swiglu | gelu
    encoder_only: bool = False
    frontend: str = "none"  # none | audio | vision
    frontend_tokens: int = 0  # patches (vlm) / all frames (audio)

    # SSM (mamba)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # xLSTM
    proj_factor: float = 2.0

    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def reps(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.name, self.pattern)
        return self.n_layers // len(self.pattern)

    @property
    def unit(self) -> int:
        return len(self.pattern)

    def mixer_at(self, pos: int) -> str:
        return self.pattern[pos]

    def ffn_at(self, pos: int) -> str:
        """FFN kind at pattern position (consistent across reps because
        unit % moe_every == 0 is asserted for MoE models)."""
        if self.d_ff == 0 and self.moe_d_ff == 0:
            return "none"
        if self.n_experts > 0:
            assert self.unit % self.moe_every == 0 or self.moe_every % self.unit == 0
            if pos % self.moe_every == self.moe_every - 1:
                return "moe"
            return "dense" if self.d_ff > 0 else "none"
        return "dense"

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d  # embedding
        if not self.tie_embeddings and not self.encoder_only:
            total += d * self.vocab  # head
        if self.encoder_only:
            total += d * self.vocab  # classifier
        for l in range(self.n_layers):
            pos = l % self.unit
            mix = self.mixer_at(pos)
            if mix == "attn":
                total += d * (self.n_heads * hd) * 2  # wq, wo
                total += d * (self.n_kv_heads * hd) * 2  # wk, wv
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif mix == "mamba":
                din = self.expand * d
                dtr = max(d // 16, 1)
                total += d * 2 * din + self.d_conv * din + din
                total += din * (dtr + 2 * self.d_state) + dtr * din + din
                total += din * self.d_state + din + din * d
            elif mix == "mlstm":
                dup = int(self.proj_factor * d)
                total += d * 2 * dup + self.d_conv * dup
                total += 3 * dup * dup + 3 * dup  # q,k,v + gates
                total += dup * d
            elif mix == "slstm":
                total += 4 * d * d + 4 * d  # i,f,z,o proj
                total += 4 * d * (d // max(self.n_heads, 1))  # recurrent per head
                total += d * d
            f = self.ffn_at(pos)
            if f == "dense":
                mult = 3 if self.ffn_act == "swiglu" else 2
                total += mult * d * self.d_ff
            elif f == "moe":
                mult = 3 if self.ffn_act == "swiglu" else 2
                total += self.n_experts * mult * d * self.moe_d_ff + d * self.n_experts
                if self.shared_expert:
                    total += mult * d * self.moe_d_ff
            total += 2 * d  # norms
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts only."""
        if self.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        mult = 3 if self.ffn_act == "swiglu" else 2
        n_moe_layers = sum(
            1 for l in range(self.n_layers) if self.ffn_at(l % self.unit) == "moe"
        )
        inactive = n_moe_layers * (self.n_experts - self.top_k) * mult * self.d_model * self.moe_d_ff
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
