"""xLSTM mixers [arXiv:2405.04517]: mLSTM (matrix memory, chunked-parallel)
and sLSTM (scalar memory, strictly sequential exponential gating).

The mLSTM cell is run in *chunkwise-parallel* form — the linear-attention-
with-decay trick: within a chunk all timesteps are computed with dense
einsums (MXU-friendly); a lax.scan carries the stabilized matrix state
(C_hat, n_hat, m) across chunks. The log-space stabilizer m follows the
xLSTM paper's max-trick. The sLSTM cell has a true sequential dependency
(exponential gating on a scalar memory with recurrent weights), so it runs
under lax.scan over time; xLSTM-350m places sLSTM in 1 of every 8 blocks.

Decode for both cells is an O(1) state update, making long_500k native.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed import shard
from .config import ModelConfig
from .layers import causal_conv1d
from .spec import LeafSpec


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    dup = int(cfg.proj_factor * cfg.d_model)
    return dup, dup // cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dup, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    return {
        "w_up": LeafSpec((d, 2 * dup), (None, "ff")),
        "conv_w": LeafSpec((cfg.d_conv, dup), (None, "ff"), scale=0.5),
        "conv_b": LeafSpec((dup,), ("ff",), "zeros"),
        "wq": LeafSpec((dup, dup), (None, "ff")),
        "wk": LeafSpec((dup, dup), (None, "ff")),
        "wv": LeafSpec((dup, dup), (None, "ff")),
        "wi": LeafSpec((dup, h), (None, None), scale=0.01),
        "bi": LeafSpec((h,), (None,), "zeros"),
        "wf": LeafSpec((dup, h), (None, None), scale=0.01),
        "bf": LeafSpec((h,), (None,), "ones"),  # bias toward remembering
        "w_down": LeafSpec((dup, d), ("ff", None)),
    }


def _mlstm_qkvg(p: dict, x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    dup, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    ug = jnp.einsum("bsd,de->bse", x, p["w_up"])
    ug = shard(ug, "batch", None, "ff")
    u, g = jnp.split(ug, 2, axis=-1)
    u = jax.nn.silu(causal_conv1d(u, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(b, s, h, hd) * hd**-0.5
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(b, s, h, hd)
    li = (jnp.einsum("bse,eh->bsh", u, p["wi"]) + p["bi"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bse,eh->bsh", u, p["wf"]) + p["bf"]).astype(jnp.float32)
    )
    return q, k, v, li, lf, g


def _mlstm_chunk(carry, args):
    """One chunk of the stabilized chunkwise-parallel mLSTM cell.

    carry: C_hat (B,H,hd,hd), n_hat (B,H,hd), m (B,H)
    args:  q,k,v (B,c,H,hd); li,lf (B,c,H)
    """
    c_hat, n_hat, m = carry
    q, k, v, li, lf = args
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    bcum = jnp.cumsum(lf, axis=1)  # (B,c,H) inclusive decay from chunk start
    btot = bcum[:, -1]  # (B,H)
    s_t = li - bcum  # log weight of step t relative to chunk end (+btot)

    # ---- state update (to chunk end) ----
    m_new = jnp.maximum(m + btot, btot + jnp.max(s_t, axis=1))
    w_end = jnp.exp(btot[:, None] + s_t - m_new[:, None])  # (B,c,H)
    decay_old = jnp.exp(m + btot - m_new)  # (B,H)
    c_new = decay_old[..., None, None] * c_hat + jnp.einsum(
        "bch,bchk,bchv->bhkv", w_end, kf, vf
    )
    n_new = decay_old[..., None] * n_hat + jnp.einsum("bch,bchk->bhk", w_end, kf)

    # ---- outputs within chunk ----
    run_max = jax.lax.cummax(s_t, axis=1)  # (B,c,H): max_{s<=t} s_s
    m_t = jnp.maximum(m[:, None] + bcum, bcum + run_max)  # (B,c,H)
    inter_scale = jnp.exp(m[:, None] + bcum - m_t)  # (B,c,H)
    inter_y = jnp.einsum("bchk,bhkv->bchv", qf, c_hat) * inter_scale[..., None]
    inter_n = jnp.einsum("bchk,bhk->bch", qf, n_hat) * inter_scale

    # intra-chunk: D[t,s] = exp(b_t + s_s - m_t) for s <= t
    cl = q.shape[1]
    logd = bcum[:, :, None, :] + s_t[:, None, :, :] - m_t[:, :, None, :]
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    dmat = jnp.where(causal[None, :, :, None], jnp.exp(logd), 0.0)  # (B,c,c,H)
    qk = jnp.einsum("bchk,bshk->bcsh", qf, kf)  # (B,c,c,H)
    intra_y = jnp.einsum("bcsh,bcsh,bshv->bchv", qk, dmat, vf)
    intra_n = jnp.einsum("bcsh,bcsh->bch", qk, dmat)

    denom = jnp.maximum(jnp.abs(inter_n + intra_n), jnp.exp(-m_t))
    h_out = (inter_y + intra_y) / denom[..., None]
    return (c_new, n_new, m_new), h_out.astype(q.dtype)


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig, chunk: int = 256) -> jax.Array:
    b, s, d = x.shape
    dup, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    q, k, v, li, lf, g = _mlstm_qkvg(p, x, cfg)
    c = min(chunk, s)
    assert s % c == 0
    n = s // c

    def to_chunks(t):
        return t.reshape(b, n, c, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    carry0 = (
        jnp.zeros((b, h, hd, hd), jnp.float32),
        jnp.zeros((b, h, hd), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(
        _mlstm_chunk, carry0, tuple(map(to_chunks, (q, k, v, li, lf)))
    )
    hseq = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, dup)
    out = jnp.einsum("bse,ed->bsd", hseq * jax.nn.silu(g), p["w_down"])
    return shard(out, "batch", None, None)


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    dup, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, dup), jnp.bfloat16),
    }


def mlstm_cache_logical() -> dict:
    return {
        "c": ("batch", None, "ff", None),
        "n": ("batch", None, "ff"),
        "m": ("batch", None),
        "conv": ("batch", None, "ff"),
    }


def mlstm_decode_step(
    p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    dup, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    ug = jnp.einsum("bsd,de->bse", x, p["w_up"])
    u, g = jnp.split(ug, 2, axis=-1)
    conv_in = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
    u1 = jax.nn.silu(causal_conv1d(conv_in, p["conv_w"], p["conv_b"])[:, -1:, :])
    q = jnp.einsum("bse,ef->bsf", u1, p["wq"]).reshape(b, h, hd)
    k = jnp.einsum("bse,ef->bsf", u1, p["wk"]).reshape(b, h, hd) * hd**-0.5
    v = jnp.einsum("bse,ef->bsf", u1, p["wv"]).reshape(b, h, hd)
    li = (jnp.einsum("be,eh->bh", u1[:, 0], p["wi"]) + p["bi"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("be,eh->bh", u1[:, 0], p["wf"]) + p["bf"]).astype(jnp.float32)
    )
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(cache["m"] + lf, li)
    decay = jnp.exp(cache["m"] + lf - m_new)
    inj = jnp.exp(li - m_new)
    c_new = decay[..., None, None] * cache["c"] + inj[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n_new = decay[..., None] * cache["n"] + inj[..., None] * kf
    y = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new)), jnp.exp(-m_new))
    hvec = (y / denom[..., None]).reshape(b, 1, dup).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", hvec * jax.nn.silu(g), p["w_down"])
    new_cache = {
        "c": c_new,
        "n": n_new,
        "m": m_new,
        "conv": conv_in[:, 1:, :].astype(jnp.bfloat16),
    }
    return shard(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "w_in": LeafSpec((d, 4 * d), (None, "ff")),  # i,f,z,o stacked
        "b_in": LeafSpec((4 * d,), ("ff",), "zeros"),
        "r": LeafSpec((4, h, hd, hd), (None, None, None, None), scale=0.01),
        "out_proj": LeafSpec((d, d), (None, None)),
    }


def _slstm_cell(carry, gates, r):
    """carry: (c, n, m, h) each (B,H,hd); gates: (B,4,H,hd) pre-activation
    from the input projection; r: (4,H,hd,hd) recurrent weights."""
    c, n, m, h = carry
    rec = jnp.einsum("bhe,ghek->bghk", h, r)  # (B,4,H,hd)
    gi, gf, gz, go = [gates[:, j] + rec[:, j] for j in range(4)]
    gi = gi.astype(jnp.float32)
    gf = gf.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(lf + m - m_new)
    c_new = f * c + i * jnp.tanh(gz.astype(jnp.float32))
    n_new = f * n + i
    h_new = jax.nn.sigmoid(go.astype(jnp.float32)) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    gates = (jnp.einsum("bsd,dg->bsg", x, p["w_in"]) + p["b_in"]).reshape(
        b, s, 4, h, hd
    )

    def step(carry, g_t):
        return _slstm_cell(carry, g_t, p["r"])

    carry0 = tuple(jnp.zeros((b, h, hd), jnp.float32) for _ in range(3)) + (
        jnp.zeros((b, h, hd), jnp.float32),
    )
    carry0 = (carry0[0], carry0[1], jnp.full((b, h, hd), -1e30, jnp.float32), carry0[3])
    _, hs = jax.lax.scan(step, carry0, gates.transpose(1, 0, 2, 3, 4))
    hseq = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", hseq, p["out_proj"])
    return shard(out, "batch", None, None)


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, hd), -1e30, jnp.float32), "h": z}


def slstm_cache_logical() -> dict:
    return {k: ("batch", None, None) for k in ("c", "n", "m", "h")}


def slstm_decode_step(
    p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    h = cfg.n_heads
    hd = cfg.d_model // h
    gates = (jnp.einsum("bsd,dg->bsg", x, p["w_in"]) + p["b_in"]).reshape(
        b, 4, h, hd
    )
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    (c, n, m, hh), h_new = _slstm_cell(carry, gates, p["r"])
    out = jnp.einsum("bsd,de->bse", h_new.reshape(b, 1, cfg.d_model).astype(x.dtype), p["out_proj"])
    return shard(out, "batch", None, None), {"c": c, "n": n, "m": m, "h": hh}
