"""Mamba-1 selective-SSM mixer (for xLSTM-family hybrids see xlstm.py).

Training/prefill uses a chunked associative scan: the sequence is cut into
chunks (lax.scan carries the SSM state across chunks; within a chunk a
parallel ``associative_scan`` runs on the time axis). This keeps the
(B, chunk, d_inner, d_state) working set bounded while exposing
MXU-friendly parallelism — the TPU-native adaptation of the CUDA selective
scan. ``d_inner`` is TP-sharded (logical "ff").

Decode carries (conv_state, ssm_state) and is O(1) per token — this is what
makes ``long_500k`` native for SSM/hybrid archs.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp

# §Perf lever: dtype of the chunked selective-scan state tensors
# (adt/drive/h are the dominant HBM traffic of mamba layers). f32 is the
# numerically safe default; bf16 halves the traffic (decays are in (0,1],
# so products stay representable; validated against the f32 path in tests).
_SSM_STATE_DTYPE: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_ssm_state_dtype", default="float32"
)


@contextlib.contextmanager
def ssm_state_dtype(name: str):
    tok = _SSM_STATE_DTYPE.set(name)
    try:
        yield
    finally:
        _SSM_STATE_DTYPE.reset(tok)

from ..distributed import shard
from .config import ModelConfig
from .layers import causal_conv1d
from .spec import LeafSpec


def _dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_in = cfg.expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return d_in, dt_rank, cfg.d_state


def mamba_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, dt_rank, ds = _dims(cfg)
    return {
        "in_proj": LeafSpec((d, 2 * d_in), (None, "ff")),
        "conv_w": LeafSpec((cfg.d_conv, d_in), (None, "ff"), scale=0.5),
        "conv_b": LeafSpec((d_in,), ("ff",), "zeros"),
        "x_proj": LeafSpec((d_in, dt_rank + 2 * ds), ("ff", None)),
        "dt_proj": LeafSpec((dt_rank, d_in), (None, "ff")),
        "dt_bias": LeafSpec((d_in,), ("ff",), "zeros"),
        "a_log": LeafSpec((d_in, ds), ("ff", None), "ones"),
        "d_skip": LeafSpec((d_in,), ("ff",), "ones"),
        "out_proj": LeafSpec((d_in, d), ("ff", None)),
    }


def _ssm_inputs(p: dict, x: jax.Array, cfg: ModelConfig):
    """Shared pre-scan computation. x: (B, S, d) -> (u, dt, Bc, Cc, z)."""
    d_in, dt_rank, ds = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shard(xz, "batch", None, "ff")
    u, z = jnp.split(xz, 2, axis=-1)
    return u, z


def _ssm_params(p: dict, u: jax.Array, cfg: ModelConfig):
    d_in, dt_rank, ds = _dims(cfg)
    dbc = jnp.einsum("bse,ef->bsf", u, p["x_proj"])
    dt, bc, cc = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)  # (B,S,d_in)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (d_in, ds)
    return dt, bc.astype(jnp.float32), cc.astype(jnp.float32), a


def mamba_block(
    p: dict, x: jax.Array, cfg: ModelConfig, chunk: int = 256
) -> jax.Array:
    """Full-sequence forward. x: (B, S, d)."""
    b, s, _ = x.shape
    d_in, dt_rank, ds = _dims(cfg)
    u, z = _ssm_inputs(p, x, cfg)
    u = jax.nn.silu(causal_conv1d(u, p["conv_w"], p["conv_b"]))
    dt, bc, cc, a = _ssm_params(p, u, cfg)

    uf = u.astype(jnp.float32)
    # decay and drive per step: adt (B,S,d_in,ds), drive (B,S,d_in,ds)
    c = min(chunk, s)
    assert s % c == 0
    nchunks = s // c

    sdt = jnp.dtype(_SSM_STATE_DTYPE.get())

    def chunk_body(h0, args):
        dt_c, bc_c, cc_c, u_c = args  # (B,c,...)
        adt = jnp.exp(dt_c[..., None] * a).astype(sdt)  # (B,c,d_in,ds)
        drive = (dt_c[..., None] * u_c[..., None] * bc_c[:, :, None, :]).astype(sdt)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(op, (adt, drive), axis=1)
        h = a_cum * h0[:, None].astype(sdt) + b_cum  # (B,c,d_in,ds)
        y = jnp.einsum("bcds,bcs->bcd", h.astype(jnp.float32), cc_c)
        return h[:, -1].astype(jnp.float32), y

    dt_s = dt.reshape(b, nchunks, c, d_in).transpose(1, 0, 2, 3)
    bc_s = bc.reshape(b, nchunks, c, ds).transpose(1, 0, 2, 3)
    cc_s = cc.reshape(b, nchunks, c, ds).transpose(1, 0, 2, 3)
    u_s = uf.reshape(b, nchunks, c, d_in).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((b, d_in, ds), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, (dt_s, bc_s, cc_s, u_s))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d_in)
    y = y + uf * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return shard(out, "batch", None, None)


# -- decode -------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    d_in, _, ds = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), jnp.bfloat16),
        "ssm": jnp.zeros((batch, d_in, ds), jnp.float32),
    }


def mamba_cache_logical() -> dict:
    return {"conv": ("batch", None, "ff"), "ssm": ("batch", "ff", None)}


def mamba_decode_step(
    p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """x: (B, 1, d); O(1) state update."""
    b = x.shape[0]
    d_in, dt_rank, ds = _dims(cfg)
    u, z = _ssm_inputs(p, x, cfg)  # (B,1,d_in)
    conv_in = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
    u1 = causal_conv1d(conv_in, p["conv_w"], p["conv_b"])[:, -1:, :]
    u1 = jax.nn.silu(u1)
    dt, bc, cc, a = _ssm_params(p, u1, cfg)
    adt = jnp.exp(dt[:, 0, :, None] * a)  # (B,d_in,ds)
    drive = dt[:, 0, :, None] * u1.astype(jnp.float32)[:, 0, :, None] * bc[:, 0, None, :]
    h = adt * cache["ssm"] + drive
    y = jnp.einsum("bds,bs->bd", h, cc[:, 0])[:, None, :]
    y = y + u1.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = {"conv": conv_in[:, 1:, :].astype(jnp.bfloat16), "ssm": h}
    return shard(out, "batch", None, None), new_cache
