"""The paper's own FL models: MLP / CNN (FMNIST, §VI-A) and a compact
ResNet (CIFAR-10). Pure-JAX; parameters are plain pytrees so the PRoBit+
pipeline (ravel → quantize → aggregate) applies unchanged.

The container is CPU-only, so the benchmark harness defaults to the MLP /
small-CNN variants; the ResNet matches the paper's ResNet-18 block layout
at reduced width (full width selectable via ``width=64``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _dense_init(key, shape, scale=None):
    scale = scale or shape[0] ** -0.5
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# MLP (fast CPU experiments)
# ---------------------------------------------------------------------------

def init_mlp(key, in_dim=784, hidden=128, classes=10):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, (in_dim, hidden)),
        "b1": jnp.zeros(hidden),
        "w2": _dense_init(k2, (hidden, hidden)),
        "b2": jnp.zeros(hidden),
        "w3": _dense_init(k3, (hidden, classes)),
        "b3": jnp.zeros(classes),
    }


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


# ---------------------------------------------------------------------------
# CNN (paper's FMNIST model)
# ---------------------------------------------------------------------------

def init_cnn(key, in_ch=1, classes=10, width=16, img=28):
    ks = jax.random.split(key, 4)
    flat = (img // 4) ** 2 * 2 * width
    return {
        "c1": _dense_init(ks[0], (3, 3, in_ch, width), scale=0.1),
        "c2": _dense_init(ks[1], (3, 3, width, 2 * width), scale=0.1),
        "w1": _dense_init(ks[2], (flat, 128)),
        "b1": jnp.zeros(128),
        "w2": _dense_init(ks[3], (128, classes)),
        "b2": jnp.zeros(classes),
    }


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_logits(params, x):
    """x: (B, H, W, C)."""
    h = _pool(jax.nn.relu(_conv(x, params["c1"])))
    h = _pool(jax.nn.relu(_conv(h, params["c2"])))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


# ---------------------------------------------------------------------------
# ResNet (paper's CIFAR-10 model, compact)
# ---------------------------------------------------------------------------

def init_resnet(key, classes=10, width=16, blocks=(2, 2, 2, 2), in_ch=3):
    """ResNet-18 block layout; width=64 recovers the paper's scale."""
    params: dict = {}
    k = iter(jax.random.split(key, 64))
    params["stem"] = _dense_init(next(k), (3, 3, in_ch, width), scale=0.1)
    ch = width
    for si, n in enumerate(blocks):
        out_ch = width * (2**si)
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = {
                "c1": _dense_init(next(k), (3, 3, ch, out_ch), scale=0.1),
                "c2": _dense_init(next(k), (3, 3, out_ch, out_ch), scale=0.1),
                "g1": jnp.ones(out_ch),
                "b1": jnp.zeros(out_ch),
                "g2": jnp.ones(out_ch),
                "b2": jnp.zeros(out_ch),
            }
            if stride != 1 or ch != out_ch:
                blk["proj"] = _dense_init(next(k), (1, 1, ch, out_ch), scale=0.1)
            params[f"s{si}b{bi}"] = blk
            ch = out_ch
    params["head_w"] = _dense_init(next(k), (ch, classes))
    params["head_b"] = jnp.zeros(classes)
    return params


def _groupnorm(x, g, b, groups=8):
    n, h, w, c = x.shape
    groups = min(groups, c)
    xg = x.reshape(n, h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(n, h, w, c) * g + b


def resnet_logits(params, x, blocks=(2, 2, 2, 2)):
    h = jax.nn.relu(_conv(x, params["stem"]))
    for si, n in enumerate(blocks):
        for bi in range(n):
            blk = params[f"s{si}b{bi}"]
            stride = 2 if (bi == 0 and si > 0) else 1
            r = _conv(h, blk["c1"], stride)
            r = jax.nn.relu(_groupnorm(r, blk["g1"], blk["b1"]))
            r = _conv(r, blk["c2"])
            r = _groupnorm(r, blk["g2"], blk["b2"])
            sc = h if "proj" not in blk else _conv(h, blk["proj"], stride)
            h = jax.nn.relu(r + sc)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head_w"] + params["head_b"]


MODELS = {
    "mlp": (init_mlp, mlp_logits),
    "cnn": (init_cnn, cnn_logits),
    "resnet": (init_resnet, resnet_logits),
}


def xent_loss(logits_fn, params, batch):
    logits = logits_fn(params, batch["x"])
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def accuracy(logits_fn, params, batch):
    logits = logits_fn(params, batch["x"])
    return jnp.mean(jnp.argmax(logits, -1) == batch["y"])
