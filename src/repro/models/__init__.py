"""Model zoo: unified pattern-based architectures (dense GQA, MoE, Mamba,
xLSTM, hybrid, encoder-only, VLM/audio backbones)."""

from .config import ModelConfig, ShapeConfig, SHAPES
from .spec import LeafSpec, abstract_params, init_params, param_pspecs, count_params
from .model import (
    build_specs,
    train_loss,
    prefill,
    serve_step,
    init_cache,
    cache_logical,
    backbone,
)
from .inputs import input_specs, input_logical, sample_batch, batch_structure

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "LeafSpec",
    "abstract_params", "init_params", "param_pspecs", "count_params",
    "build_specs", "train_loss", "prefill", "serve_step", "init_cache",
    "cache_logical", "backbone", "input_specs", "input_logical",
    "sample_batch", "batch_structure",
]
