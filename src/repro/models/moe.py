"""Mixture-of-Experts FFN with capacity-bounded gather routing and
expert-parallel execution via ``shard_map``.

Routing (per token): top-k softmax gates over E experts. Execution: each
``model``-axis shard owns E/|model| experts; tokens are *replicated* across
the model axis (they already are, post-attention), every shard gathers the
top-C tokens routed to each of its local experts, computes them, scatter-
adds the gated outputs, and a ``psum`` over ``model`` combines shards.

This baseline trades an all-to-all for one psum of the (tokens, d) output —
simple and robust across expert counts (128 for qwen3-moe, 16 for jamba /
llama4). §Perf iterates on it.

Without an active mesh (CPU smoke tests) the same inner routine runs over
ALL experts locally — identical semantics, zero collectives.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed import batch_axes, current_mesh, shard
from .config import ModelConfig
from .spec import LeafSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    # the "ff" logical on the per-expert hidden dim is inert under the
    # default rules ("model" is consumed by "experts") but lets the 2D
    # weight-stationary serving layout shard expert FFNs over "data" too.
    s: dict = {
        "router": LeafSpec((d, e), (None, None), dtype=jnp.float32),
        "w1": LeafSpec((e, d, f), ("experts", None, "ff")),
        "w3": LeafSpec((e, d, f), ("experts", None, "ff")),
        "w2": LeafSpec((e, f, d), ("experts", "ff", None)),
    }
    if cfg.shared_expert:
        s["sw1"] = LeafSpec((d, f), (None, "ff"))
        s["sw3"] = LeafSpec((d, f), (None, "ff"))
        s["sw2"] = LeafSpec((f, d), ("ff", None))
    return s


def _route(x2d: jax.Array, router: jax.Array, top_k: int):
    """x2d: (T, d) -> gates (T, k) f32, idx (T, k) int32."""
    logits = x2d.astype(jnp.float32) @ router  # (T, E)
    gate_vals, idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    return gates, idx


def _expert_compute(
    x2d: jax.Array,
    gates: jax.Array,
    idx: jax.Array,
    w1: jax.Array,
    w3: jax.Array,
    w2: jax.Array,
    e_offset: int | jax.Array,
    capacity: int,
) -> jax.Array:
    """Compute the local experts' contribution for (T, d) tokens.

    w1/w3: (E_local, d, f); w2: (E_local, f, d). Tokens routed to local
    expert ``e`` beyond ``capacity`` are dropped (standard capacity rule).
    """
    t, d = x2d.shape
    e_local = w1.shape[0]

    def one_expert(we1, we3, we2, e_local_idx):
        e_global = e_offset + e_local_idx
        routed = idx == e_global  # (T, k)
        gate_e = jnp.sum(jnp.where(routed, gates, 0.0), axis=-1)  # (T,)
        score = jnp.where(gate_e > 0, gate_e, -1.0)
        top_score, top_idx = jax.lax.top_k(score, capacity)  # (C,)
        sel = jnp.maximum(top_score, 0.0)  # 0 for non-routed padding slots
        xe = jnp.take(x2d, top_idx, axis=0)  # (C, d)
        h = jax.nn.silu(xe @ we1) * (xe @ we3)  # (C, f_local)
        ye = (h @ we2) * sel[:, None].astype(x2d.dtype)  # (C, d) (partial if f sharded)
        return jnp.zeros((t, d), x2d.dtype).at[top_idx].add(ye)

    contribs = jax.vmap(one_expert, in_axes=(0, 0, 0, 0))(
        w1, w3, w2, jnp.arange(e_local)
    )
    return jnp.sum(contribs, axis=0)


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    capacity = max(
        int(b * s * cfg.top_k / cfg.n_experts * cfg.capacity_factor), 8
    )
    capacity = min(capacity, b * s)
    mesh = current_mesh()
    gates, idx = _route(x2d, p["router"], cfg.top_k)

    from ..distributed import spec_for

    def _axes(entry) -> tuple:
        if entry is None:
            return ()
        return entry if isinstance(entry, tuple) else (entry,)

    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh is not None else {}
    w1spec = spec_for(("experts", None, "ff"), p["w1"].shape) if mesh else None
    e_axes = _axes(w1spec[0]) if w1spec and len(w1spec) > 0 else ()
    f_axes = _axes(w1spec[2]) if w1spec and len(w1spec) > 2 else ()

    if mesh is not None and len(e_axes) == 1 and cfg.n_experts % sizes[e_axes[0]] == 0:
        e_axis = e_axes[0]
        e_local = cfg.n_experts // sizes[e_axis]
        psum_axes = (e_axis,) + tuple(f_axes)
        # tokens stay sharded along batch axes not consumed by the weights;
        # tiny token counts (decode) fall back to replicated tokens.
        baxes = tuple(
            a for a in batch_axes() if a in sizes and a not in psum_axes
        )
        tok_shards = 1
        for a in baxes:
            tok_shards *= sizes[a]
        t = b * s
        if baxes and t % tok_shards == 0 and t // tok_shards >= 8:
            cap_local = min(max(capacity // tok_shards, 8), t // tok_shards)
            tok_spec = P(baxes if len(baxes) != 1 else baxes[0], None)
        else:
            cap_local = min(capacity, t)
            tok_spec = P(None, None)
        ew1 = P(*w1spec)
        ew2 = P(*spec_for(("experts", "ff", None), p["w2"].shape))

        def local_fn(x2d_l, gates_l, idx_l, w1_l, w3_l, w2_l):
            eidx = jax.lax.axis_index(e_axis)
            out = _expert_compute(
                x2d_l, gates_l, idx_l, w1_l, w3_l, w2_l,
                eidx * e_local, cap_local,
            )
            return jax.lax.psum(out, psum_axes)

        in_specs = (tok_spec, tok_spec, tok_spec, ew1, ew1, ew2)
        if hasattr(jax, "shard_map"):
            smapped = jax.shard_map(
                local_fn, mesh=mesh, in_specs=in_specs, out_specs=tok_spec,
                check_vma=False,
            )
        else:  # jax<=0.4: experimental API, check_rep instead of check_vma
            from jax.experimental.shard_map import shard_map as _shard_map

            smapped = _shard_map(
                local_fn, mesh=mesh, in_specs=in_specs, out_specs=tok_spec,
                check_rep=False,
            )
        out2d = smapped(x2d, gates, idx, p["w1"], p["w3"], p["w2"])
    else:
        out2d = _expert_compute(
            x2d, gates, idx, p["w1"], p["w3"], p["w2"], 0, capacity
        )

    out = out2d.reshape(b, s, d)
    if "sw1" in p:
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["sw1"])) * jnp.einsum(
            "bsd,df->bsf", x, p["sw3"]
        )
        out = out + jnp.einsum("bsf,fd->bsd", h, p["sw2"])
    return shard(out, "batch", None, None)


def router_aux_loss(x2d: jax.Array, router: jax.Array, top_k: int, n_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (importance * load)."""
    logits = x2d.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    importance = jnp.mean(probs, axis=0)
    _, idx = jax.lax.top_k(logits, top_k)
    load = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, n_experts), axis=1), axis=0
    )
    return n_experts * jnp.sum(importance * load)
