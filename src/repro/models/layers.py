"""Shared neural layers: norms, RoPE, chunked (flash-style) attention,
decode attention over (optionally ring-buffer) KV caches, dense FFN.

All forwards are pure functions of (params, inputs); parameter structures
are declared by the ``*_specs`` builders as LeafSpec trees.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..distributed import shard
from .config import ModelConfig
from .spec import LeafSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": LeafSpec((d,), (None,), "ones"), "b": LeafSpec((d,), (None,), "zeros")}
    return {"w": LeafSpec((d,), (None,), "ones")}


def apply_norm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "b" in p:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["w"].astype(jnp.float32) + p[
            "b"
        ].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["w"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    s: dict = {
        "wq": LeafSpec((d, cfg.n_heads, hd), (None, "heads", None)),
        "wk": LeafSpec((d, cfg.n_kv_heads, hd), (None, "kv", None)),
        "wv": LeafSpec((d, cfg.n_kv_heads, hd), (None, "kv", None)),
        "wo": LeafSpec((cfg.n_heads, hd, d), ("heads", None, None)),
    }
    if cfg.qkv_bias:
        s["bq"] = LeafSpec((cfg.n_heads, hd), ("heads", None), "zeros")
        s["bk"] = LeafSpec((cfg.n_kv_heads, hd), ("kv", None), "zeros")
        s["bv"] = LeafSpec((cfg.n_kv_heads, hd), ("kv", None), "zeros")
    return s


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv", None)
    v = shard(v, "batch", None, "kv", None)
    return q, k, v


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
) -> jax.Array:
    """Online-softmax attention in O(S * chunk) memory (flash-style).

    q: (B, S, H, hd);  k, v: (B, S, KV, hd).  GQA via H = KV * G grouping.
    ``window > 0`` restricts keys to ``(i - window, i]``.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    cq = min(chunk_q, S)
    ck = min(chunk_kv, S)
    assert S % cq == 0 and S % ck == 0, (S, cq, ck)
    nq, nk = S // cq, S // ck

    qs = q.reshape(B, nq, cq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, KV, hd).transpose(1, 0, 2, 3, 4)

    def q_block(qi, qc):
        q0 = qi * cq
        qpos = q0 + jnp.arange(cq)

        def kv_body(carry, inp):
            acc, mx, lse = carry
            ki, kc, vc = inp
            k0 = ki * ck
            kpos = k0 + jnp.arange(ck)
            logits = (
                jnp.einsum(
                    "bqkgh,bckh->bqkgc",
                    qc.astype(jnp.float32),
                    kc.astype(jnp.float32),
                )
                * scale
            )
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
            new_mx = jnp.maximum(mx, jnp.max(logits, axis=-1))
            alpha = jnp.exp(mx - new_mx)
            p_exp = jnp.exp(logits - new_mx[..., None])
            lse = lse * alpha + jnp.sum(p_exp, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p_exp, vc.astype(jnp.float32)
            )
            return (acc, new_mx, lse), None

        acc0 = jnp.zeros((B, cq, KV, G, hd), jnp.float32)
        mx0 = jnp.full((B, cq, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, KV, G), jnp.float32)
        (acc, _, lse), _ = jax.lax.scan(
            kv_body, (acc0, mx0, l0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(lse[..., None], 1e-30)
        return out  # (B, cq, KV, G, hd)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def attention_block(
    p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array
) -> jax.Array:
    q, k, v = _qkv(p, x, cfg, positions)
    out = chunked_attention(
        q, k, v, causal=cfg.causal, window=cfg.sliding_window
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(out, "batch", None, None)


# -- decode ------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    hd = cfg.head_dim
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, jnp.bfloat16),
        "v": jnp.zeros(shape, jnp.bfloat16),
    }


def attn_cache_logical() -> dict:
    return {"k": ("batch", "seq", "kv", None), "v": ("batch", "seq", "kv", None)}


def decode_attention_block(
    p: dict,
    x: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    pos: jax.Array,
    window: int,
) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d). ``window>0`` = ring-buffer cache of
    that size (slot = pos % window); otherwise linear cache of full length.
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)  # (B,1,H,hd), (B,1,KV,hd)
    cache_len = cache["k"].shape[1]
    slot = pos % window if window > 0 else pos  # window is static
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    new_k = shard(new_k, "batch", "seq", "kv", None)
    new_v = shard(new_v, "batch", "seq", "kv", None)

    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    hd = cfg.head_dim
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum(
        "bkgh,bskh->bkgs", qg.astype(jnp.float32), new_k.astype(jnp.float32)
    ) / math.sqrt(hd)
    idx = jnp.arange(cache_len)
    if window <= 0:
        valid = idx <= pos
    else:
        # ring buffer: every slot valid once the window has wrapped
        valid = idx < jnp.minimum(pos + 1, cache_len)
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, new_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.n_heads, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, "batch", None, None), {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def ffn_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.ffn_act == "swiglu":
        return {
            "w1": LeafSpec((d, f), (None, "ff")),
            "w3": LeafSpec((d, f), (None, "ff")),
            "w2": LeafSpec((f, d), ("ff", None)),
        }
    return {
        "w1": LeafSpec((d, f), (None, "ff")),
        "w2": LeafSpec((f, d), ("ff", None)),
    }


def ffn_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    h = shard(h, "batch", None, "ff")
    if "w3" in p:
        h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x, p["w3"])
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return shard(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    s = {"embed": LeafSpec((cfg.vocab, cfg.d_model), ("vocab", None), scale=1.0)}
    if not cfg.tie_embeddings:
        s["head"] = LeafSpec((cfg.d_model, cfg.vocab), (None, "vocab"))
    return s


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return shard(p["embed"][tokens], "batch", None, None)


def lm_logits(p: dict, x: jax.Array) -> jax.Array:
    head = p.get("head")
    if head is None:
        head = p["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", None, "vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean cross-entropy; logits (B,S,V) f32, labels (B,S) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    if b is not None:
        out = out + b[None, None, :]
    return out
