"""Input specifications: ShapeDtypeStruct stand-ins for the dry-run (no
allocation) and concrete random batches for smoke tests.

For the [audio]/[vlm] architectures the modality frontend is a stub per the
harness carve-out: ``input_specs`` yields precomputed frame/patch
embeddings of the right shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, ShapeConfig


def batch_structure(cfg: ModelConfig, batch: int, seq: int, kind: str) -> dict:
    """Returns {name: (shape, dtype, logical)} for one step's model inputs."""
    if kind == "decode":
        return {"tokens": ((batch, 1), jnp.int32, ("batch", None))}
    if cfg.frontend == "audio":
        return {
            "feats": ((batch, seq, cfg.d_model), jnp.bfloat16, ("batch", None, None)),
            "labels": ((batch, seq), jnp.int32, ("batch", None)),
            "mask": ((batch, seq), jnp.bool_, ("batch", None)),
        }
    if cfg.frontend == "vision":
        p = cfg.frontend_tokens
        text = seq - p
        assert text > 0
        d: dict = {
            "patches": ((batch, p, cfg.d_model), jnp.bfloat16, ("batch", None, None)),
            "tokens": ((batch, text), jnp.int32, ("batch", None)),
        }
        if kind == "train":
            d["labels"] = ((batch, text), jnp.int32, ("batch", None))
        return d
    d = {"tokens": ((batch, seq), jnp.int32, ("batch", None))}
    if kind == "train":
        d["labels"] = ((batch, seq), jnp.int32, ("batch", None))
    return d


def input_specs(cfg: ModelConfig, batch: int, seq: int, kind: str) -> dict:
    """ShapeDtypeStruct pytree (weak-type-correct, no allocation)."""
    return {
        k: jax.ShapeDtypeStruct(shape, dtype)
        for k, (shape, dtype, _) in batch_structure(cfg, batch, seq, kind).items()
    }


def input_logical(cfg: ModelConfig, batch: int, seq: int, kind: str) -> dict:
    return {
        k: logical
        for k, (_, __, logical) in batch_structure(cfg, batch, seq, kind).items()
    }


def sample_batch(cfg: ModelConfig, batch: int, seq: int, kind: str, seed: int = 0) -> dict:
    """Concrete random batch for CPU smoke tests."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (shape, dtype, _) in batch_structure(cfg, batch, seq, kind).items():
        if dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
        elif dtype == jnp.bool_:
            out[k] = jnp.asarray(rng.random(shape) < 0.3)
        else:
            out[k] = jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)
    return out
