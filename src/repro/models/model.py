"""Model assembly: pattern-unit scan over heterogeneous blocks.

A model is ``reps`` repetitions of a pattern unit (e.g. Jamba's
[mamba ×3, attn, mamba ×4] with alternating dense/MoE FFNs). Parameters for
each pattern *position* are stacked over ``reps`` and the forward runs
``lax.scan`` over reps, applying the unit's positions in order — one
compiled block body regardless of depth (72-layer Jamba compiles the same
HLO size as a 8-layer toy).

Three entry points:
  * ``train_loss``   — forward + loss (next-token / masked-frame)
  * ``prefill``      — full-sequence logits
  * ``serve_step``   — one-token decode with per-mixer caches
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Any

import jax
import jax.numpy as jnp

# §Perf lever (hillclimb A): when True, the layer scan indexes the stacked
# parameter tree with dynamic_index_in_dim INSIDE the body instead of
# passing it as scan xs. With FSDP-sharded params, xs-mode lets GSPMD hoist
# the all-gather of the WHOLE stacked tree out of the loop (params/TP_shards
# bytes of temp — 50 GiB/device for Jamba-398B); indexed mode gathers one
# pattern unit per iteration (reps× less peak).
_INDEXED_PARAMS: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_indexed_params", default=False
)

# §Perf lever (hillclimb A, change 3): remat each LAYER inside the pattern
# unit (nested under the per-unit checkpoint). Without it, the unit's
# backward holds every layer's gathered weights + grad intermediates live
# at once — for Jamba's 8-layer unit that is the 60 GiB peak.
_INNER_REMAT: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_inner_remat", default=False
)

# §Perf lever (hillclimb A, change 4): remat policy for the unit scan.
# "full" recomputes the whole unit forward in the backward (cheapest
# memory, +1 forward of FLOPs); "dots" saves matmul outputs and only
# recomputes elementwise ops (kills the recompute FLOPs and the weight
# re-reads at the cost of storing activations).
_REMAT_POLICY: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_remat_policy", default="full"
)


@contextlib.contextmanager
def remat_policy(name: str):
    tok = _REMAT_POLICY.set(name)
    try:
        yield
    finally:
        _REMAT_POLICY.reset(tok)


def _checkpoint(fn):
    pol = _REMAT_POLICY.get()
    if pol == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


@contextlib.contextmanager
def indexed_params(on: bool = True):
    tok = _INDEXED_PARAMS.set(on)
    try:
        yield
    finally:
        _INDEXED_PARAMS.reset(tok)


@contextlib.contextmanager
def inner_remat(on: bool = True):
    tok = _INNER_REMAT.set(on)
    try:
        yield
    finally:
        _INNER_REMAT.reset(tok)

from ..distributed import shard
from .config import ModelConfig
from . import layers, moe, ssm, xlstm
from .spec import LeafSpec, stack_specs

Params = Any


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _mixer_specs(cfg: ModelConfig, kind: str) -> dict:
    return {
        "attn": layers.attn_specs,
        "mamba": ssm.mamba_specs,
        "mlstm": xlstm.mlstm_specs,
        "slstm": xlstm.slstm_specs,
    }[kind](cfg)


def build_specs(cfg: ModelConfig) -> dict:
    """Full parameter LeafSpec tree for an architecture."""
    blocks = []
    for pos in range(cfg.unit):
        unit: dict = {
            "norm1": layers.norm_specs(cfg),
            "mixer": _mixer_specs(cfg, cfg.mixer_at(pos)),
        }
        f = cfg.ffn_at(pos)
        if f == "dense":
            unit["norm2"] = layers.norm_specs(cfg)
            unit["ffn"] = layers.ffn_specs(cfg)
        elif f == "moe":
            unit["norm2"] = layers.norm_specs(cfg)
            unit["ffn"] = moe.moe_specs(cfg)
        blocks.append(stack_specs(unit, cfg.reps))

    tree: dict = {
        "embed": layers.embed_specs(cfg),
        "blocks": blocks,
        "final_norm": layers.norm_specs(cfg),
    }
    if cfg.encoder_only:
        tree["classifier"] = LeafSpec((cfg.d_model, cfg.vocab), (None, "vocab"))
        tree["mask_token"] = LeafSpec((cfg.d_model,), (None,), scale=0.02)
        del tree["embed"]["head"]
    if cfg.frontend == "vision":
        # learned projector applied to the (stubbed) patch embeddings
        tree["projector"] = LeafSpec((cfg.d_model, cfg.d_model), (None, None))
    return tree


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_layer(
    p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array, pos: int
) -> jax.Array:
    mix = cfg.mixer_at(pos)
    h = layers.apply_norm(p["norm1"], x, cfg.norm_eps)
    if mix == "attn":
        h = layers.attention_block(p["mixer"], h, cfg, positions)
    elif mix == "mamba":
        h = ssm.mamba_block(p["mixer"], h, cfg)
    elif mix == "mlstm":
        h = xlstm.mlstm_block(p["mixer"], h, cfg)
    else:
        h = xlstm.slstm_block(p["mixer"], h, cfg)
    x = x + h
    f = cfg.ffn_at(pos)
    if f != "none":
        h = layers.apply_norm(p["norm2"], x, cfg.norm_eps)
        if f == "dense":
            h = layers.ffn_block(p["ffn"], h, cfg)
        else:
            h = moe.moe_block(p["ffn"], h, cfg)
        x = x + h
    return x


def _apply_unit(
    unit_params: list[dict],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
) -> jax.Array:
    nested = _INNER_REMAT.get()
    for pos, p in enumerate(unit_params):
        if nested:
            x = _checkpoint(
                functools.partial(_apply_layer, cfg=cfg, positions=positions, pos=pos)
            )(p, x)
        else:
            x = _apply_layer(p, x, cfg, positions, pos)
    return x


def backbone(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    remat: bool = True,
) -> jax.Array:
    if _INDEXED_PARAMS.get():
        blocks = params["blocks"]

        def body(carry, r):
            unit = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False),
                blocks,
            )
            out = _apply_unit(unit, carry, cfg, positions)
            return out, None

        if remat:
            body = _checkpoint(body)
        x, _ = jax.lax.scan(body, x, jnp.arange(cfg.reps))
    else:

        def body(carry, unit_params):
            out = _apply_unit(unit_params, carry, cfg, positions)
            return out, None

        if remat:
            body = _checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    return layers.apply_norm(params["final_norm"], x, cfg.norm_eps)


def _embed_inputs(params: Params, batch: dict, cfg: ModelConfig):
    """Returns (x (B,S,d), positions (B,S), loss_labels, loss_mask)."""
    if cfg.frontend == "audio":
        feats = batch["feats"]
        mask = batch["mask"]
        x = jnp.where(
            mask[..., None], params["mask_token"].astype(feats.dtype), feats
        )
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x, positions, batch.get("labels"), mask
    if cfg.frontend == "vision":
        patches = jnp.einsum("bpd,de->bpe", batch["patches"], params["projector"])
        tok_emb = layers.embed_tokens(params["embed"], batch["tokens"])
        x = jnp.concatenate([patches.astype(tok_emb.dtype), tok_emb], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        npatch = patches.shape[1]
        mask = jnp.concatenate(
            [
                jnp.zeros((b, npatch), bool),
                jnp.ones((b, tok_emb.shape[1]), bool),
            ],
            axis=1,
        )
        labels = batch.get("labels")
        if labels is not None:
            # pad labels over the patch prefix (ignored via mask)
            labels = jnp.concatenate(
                [jnp.zeros((b, npatch), labels.dtype), labels], axis=1
            )
        return x, positions, labels, mask
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed_tokens(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions, batch.get("labels"), None


def train_loss(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    x, positions, labels, mask = _embed_inputs(params, batch, cfg)
    x = backbone(params, x, cfg, positions)
    if cfg.encoder_only:
        logits = jnp.einsum("bsd,dv->bsv", x, params["classifier"]).astype(
            jnp.float32
        )
        return layers.softmax_xent(logits, labels, mask)
    logits = layers.lm_logits(params["embed"], x)
    shifted = jnp.roll(labels, -1, axis=1)
    if mask is None:
        mask = jnp.ones_like(labels, bool)
    mask = mask.at[:, -1].set(False)  # last position has no next token
    return layers.softmax_xent(logits, shifted, mask)


def prefill(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    x, positions, _, _ = _embed_inputs(params, batch, cfg)
    x = backbone(params, x, cfg, positions)
    if cfg.encoder_only:
        return jnp.einsum("bsd,dv->bsv", x, params["classifier"]).astype(jnp.float32)
    return layers.lm_logits(params["embed"], x)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> list:
    """Cache pytree: one entry per pattern position, leaves stacked (reps,…).

    ``cache_len`` is the KV-cache length for attention positions (the ring
    window when the sliding variant is active); recurrent mixers carry O(1)
    state.
    """
    caches = []
    for pos in range(cfg.unit):
        mix = cfg.mixer_at(pos)
        if mix == "attn":
            c = layers.init_attn_cache(cfg, batch, cache_len)
        elif mix == "mamba":
            c = ssm.init_mamba_cache(cfg, batch)
        elif mix == "mlstm":
            c = xlstm.init_mlstm_cache(cfg, batch)
        else:
            c = xlstm.init_slstm_cache(cfg, batch)
        caches.append(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.reps,) + a.shape), c)
        )
    return caches


def cache_logical(cfg: ModelConfig) -> list:
    out = []
    for pos in range(cfg.unit):
        mix = cfg.mixer_at(pos)
        log = {
            "attn": layers.attn_cache_logical,
            "mamba": ssm.mamba_cache_logical,
            "mlstm": xlstm.mlstm_cache_logical,
            "slstm": xlstm.slstm_cache_logical,
        }[mix]()
        out.append(jax.tree.map(lambda l: (None,) + tuple(l), log, is_leaf=lambda v: isinstance(v, tuple)))
    return out


def serve_step(
    params: Params,
    cache: list,
    batch: dict,
    pos: jax.Array,
    cfg: ModelConfig,
    window: int = 0,
) -> tuple[jax.Array, list]:
    """Decode ONE token. batch: {"tokens": (B, 1)}; pos: scalar int32.

    ``window > 0`` activates the ring-buffer sliding-window cache (the
    long_500k variant for full-attention archs).
    """
    tokens = batch["tokens"]
    x = layers.embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        xx = carry
        unit_params, unit_cache = xs
        new_caches = []
        for upos in range(cfg.unit):
            mix = cfg.mixer_at(upos)
            p, c = unit_params[upos], unit_cache[upos]
            h = layers.apply_norm(p["norm1"], xx, cfg.norm_eps)
            if mix == "attn":
                h, c_new = layers.decode_attention_block(
                    p["mixer"], h, c, cfg, pos, window
                )
            elif mix == "mamba":
                h, c_new = ssm.mamba_decode_step(p["mixer"], h, c, cfg)
            elif mix == "mlstm":
                h, c_new = xlstm.mlstm_decode_step(p["mixer"], h, c, cfg)
            else:
                h, c_new = xlstm.slstm_decode_step(p["mixer"], h, c, cfg)
            xx = xx + h
            f = cfg.ffn_at(upos)
            if f != "none":
                h = layers.apply_norm(p["norm2"], xx, cfg.norm_eps)
                h = (
                    layers.ffn_block(p["ffn"], h, cfg)
                    if f == "dense"
                    else moe.moe_block(p["ffn"], h, cfg)
                )
                xx = xx + h
            new_caches.append(c_new)
        return xx, new_caches

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.lm_logits(params["embed"], x)[:, 0]
    return logits, new_cache
