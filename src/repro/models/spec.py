"""Parameter-spec trees: one definition drives init, abstract shapes
(for the allocation-free dry-run) and sharding.

A model's parameters are described as a pytree of :class:`LeafSpec`; from
it we derive (a) ``jax.ShapeDtypeStruct`` trees, (b) NamedShardings via the
logical-axis rules in :mod:`repro.distributed`, and (c) materialized
initial values.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .. import distributed


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = -1.0  # -1 -> 1/sqrt(fan_in) with fan_in = shape[-2] or [-1]
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stack_specs(tree, reps: int):
    """Prepend a layer-stacking dim (replicated) to every LeafSpec."""
    return jax.tree.map(
        lambda s: LeafSpec((reps,) + s.shape, (None,) + s.logical, s.init, s.scale, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )


def abstract_params(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )


def param_pspecs(tree, fsdp_axis: str | None = None):
    """PartitionSpec tree (requires an active mesh via jax.set_mesh).

    ``fsdp_axis``: additionally shard each leaf's largest still-replicated
    dim over that mesh axis (ZeRO-3 style) when divisible — required for
    the 398B-class configs to fit HBM. GSPMD then inserts the per-layer
    all-gathers / reduce-scatters automatically.
    """
    base = jax.tree.map(
        lambda s: distributed.spec_for(s.logical, s.shape),
        tree,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    if fsdp_axis is None:
        return base
    mesh = distributed.current_mesh()
    if mesh is None or fsdp_axis not in mesh.axis_names:
        return base
    axis_size = dict(zip(mesh.axis_names, mesh.axis_sizes))[fsdp_axis]

    def add_fsdp(s: LeafSpec, spec):
        entries = list(spec) + [None] * (len(s.shape) - len(spec))
        # pick the largest unsharded dim divisible by the axis size
        cand = [
            (dim, i)
            for i, (dim, e) in enumerate(zip(s.shape, entries))
            if e is None and dim % axis_size == 0 and dim >= axis_size
        ]
        if not cand:
            return spec
        _, i = max(cand)
        entries[i] = fsdp_axis
        from jax.sharding import PartitionSpec as P

        return P(*entries)

    return jax.tree.map(
        add_fsdp,
        tree,
        base,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )


def init_params(tree, key: jax.Array):
    """Materialize initial parameter values (per-leaf folded keys)."""
    leaves = jax.tree_util.tree_leaves_with_path(
        tree, is_leaf=lambda x: isinstance(x, LeafSpec)
    )

    def make(i: int, s: LeafSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = s.scale if s.scale > 0 else fan_in ** -0.5
        k = jax.random.fold_in(key, i)
        return (scale * jax.random.normal(k, s.shape, jnp.float32)).astype(s.dtype)

    vals = [make(i, s) for i, (_, s) in enumerate(leaves)]
    treedef = jax.tree_util.tree_structure(
        tree, is_leaf=lambda x: isinstance(x, LeafSpec)
    )
    return jax.tree_util.tree_unflatten(treedef, vals)


def count_params(tree) -> int:
    sizes = jax.tree.map(
        lambda s: int(jnp.prod(jnp.array(s.shape))) if isinstance(s, LeafSpec) else 0,
        tree,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    return sum(jax.tree_util.tree_leaves(sizes))
