"""xLSTM-350M [arXiv:2405.04517] — 7:1 mLSTM:sLSTM blocks, no separate FFN
(the blocks carry their own up/down projections)."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        rope=False,
        pattern=("mlstm",) * 7 + ("slstm",),
        proj_factor=2.0,
    )
