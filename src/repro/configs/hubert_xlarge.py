"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only backbone (same arch as
wav2vec2); conv feature extractor is a STUB per the harness carve-out:
input_specs() provides precomputed frame embeddings. Masked-frame cluster
prediction over 504 k-means targets. RoPE substitutes the conv positional
embedding (positional information only; noted in DESIGN.md)."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab=504,
        rope=True,
        rope_theta=1e4,
        causal=False,
        encoder_only=True,
        frontend="audio",
        ffn_act="gelu",
        norm="layernorm",
    )
