"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family card] — dense MHA decoder
(n_kv_heads == n_heads), QKV bias."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_ff=6912,
        vocab=151936,
        rope_theta=1e6,
        qkv_bias=True,
    )
