"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — Mistral-NeMo-style decoder
consuming ViT patch embeddings (vision encoder is a STUB per the harness
carve-out; a learned projector maps stubbed patch embeddings into the
backbone). 1024 patch tokens prefix the text sequence (early fusion)."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab=131072,
        rope_theta=1e6,
        frontend="vision",
        frontend_tokens=1024,
    )
