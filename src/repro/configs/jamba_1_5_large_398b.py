"""Jamba-1.5-Large (398B) [arXiv:2403.19887] — hybrid Mamba+attention at a
1:7 attn:mamba interleave (1 attention layer per 8-layer unit), MoE (16
experts, top-2) on every other layer, dense FFN elsewhere."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        rope=False,  # Jamba attention layers are NoPE
        pattern=(
            "mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba",
        ),
        n_experts=16,
        top_k=2,
        moe_d_ff=24576,
        moe_every=2,
        d_state=16,
    )
