"""Minitron-8B [arXiv:2407.14679] — width-pruned Nemotron-4; dense GQA."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
        rope_theta=1e4,
    )
