"""StarCoder2-3B [arXiv:2402.19173] — dense GQA decoder, RoPE, native
sliding-window attention (4096), GELU MLP, learned biases."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab=49152,
        rope=True,
        rope_theta=1e5,
        qkv_bias=True,
        sliding_window=4096,
        ffn_act="gelu",
        norm="layernorm",
    )
