"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE with 16
routed experts (top-1) + shared expert on every layer; early-fusion
multimodal in the original (text backbone here; the harness assigns the
[moe] type). Native attention is chunked-8k on most layers; we model full
attention with the sliding-window variant available for long_500k."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=0,
        vocab=202048,
        rope_theta=5e5,
        n_experts=16,
        top_k=1,
        moe_d_ff=8192,
        moe_every=1,
        shared_expert=True,
    )
