"""Qwen2-1.5B [arXiv:2407.10671] — dense GQA decoder with QKV bias."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        rope_theta=1e6,
        qkv_bias=True,
    )
