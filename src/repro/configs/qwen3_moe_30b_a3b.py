"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — fine-grained MoE: 128 experts,
top-8, expert FFN width 768, every layer MoE, head_dim 128."""

from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=0,
        vocab=151936,
        rope_theta=1e6,
        n_experts=128,
        top_k=8,
        moe_d_ff=768,
        moe_every=1,
    )
