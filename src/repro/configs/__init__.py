"""Architecture registry: ``--arch <id>`` resolution, reduced smoke
variants, and the paper's own FL models.

Every assigned architecture has one module here citing its source; the
registry also exposes ``reduced(cfg)`` — the family-preserving small
variant used by CPU smoke tests (<=2 pattern units, d_model<=512,
<=4 experts).
"""

from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, SHAPES, ShapeConfig
from . import (
    starcoder2_3b,
    xlstm_350m,
    hubert_xlarge,
    pixtral_12b,
    qwen2_1_5b,
    minitron_8b,
    jamba_1_5_large_398b,
    qwen3_moe_30b_a3b,
    llama4_scout_17b_a16e,
    qwen1_5_4b,
)

_MODULES = {
    "starcoder2-3b": starcoder2_3b,
    "xlstm-350m": xlstm_350m,
    "hubert-xlarge": hubert_xlarge,
    "pixtral-12b": pixtral_12b,
    "qwen2-1.5b": qwen2_1_5b,
    "minitron-8b": minitron_8b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "qwen1.5-4b": qwen1_5_4b,
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].get_config()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving smoke-scale variant of an architecture."""
    if cfg.pattern == ("attn",):
        pattern = ("attn",)
        n_layers = 2
    elif "mamba" in cfg.pattern:  # jamba: keep hybrid character
        pattern = ("mamba", "attn")
        n_layers = 2
    else:  # xlstm
        pattern = ("mlstm", "slstm")
        n_layers = 2
    moe = cfg.n_experts > 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=256,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=64,
        d_ff=512 if cfg.d_ff > 0 else 0,
        vocab=512,
        pattern=pattern,
        n_experts=4 if moe else 0,
        top_k=min(cfg.top_k, 2) if moe else 0,
        moe_d_ff=128 if moe else 0,
        moe_every=min(cfg.moe_every, len(pattern)) if moe else 1,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        frontend_tokens=16 if cfg.frontend == "vision" else 0,
    )


__all__ = ["ARCH_IDS", "get_config", "reduced", "SHAPES", "ShapeConfig"]
