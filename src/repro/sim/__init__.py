"""Scenario-campaign engine: vmapped grids of FL runs with statistics.

Declare a grid with :class:`CampaignSpec` (base FLConfig + cell overrides
+ seeds), execute it with :func:`run_campaign`, and read per-cell
trajectories with mean ± CI from the returned :class:`CampaignResult`.
See ``benchmarks/table1_byzantine.py`` for the canonical usage."""

from .campaign import (
    ACCOUNTING_FIELDS,
    VMAP_FIELDS,
    CampaignSpec,
    CellSpec,
    Task,
    group_signature,
    run_campaign,
)
from .metrics import CampaignResult, CellResult, mean_ci

__all__ = [
    "ACCOUNTING_FIELDS",
    "VMAP_FIELDS",
    "CampaignSpec",
    "CellSpec",
    "Task",
    "group_signature",
    "run_campaign",
    "CampaignResult",
    "CellResult",
    "mean_ci",
]
