"""Scenario-campaign engine: vmapped grids of FL runs with statistics.

Declare a grid with :class:`CampaignSpec` (base FLConfig + cell overrides
+ seeds), execute it with :func:`run_campaign` (which lowers the spec
through :func:`plan_campaign` into a :class:`CampaignPlan` — fused
heterogeneous-M groups, AOT-compile caching, overlapped dispatch, device
sharding), and read per-cell trajectories with mean ± CI from the
returned :class:`CampaignResult`. See ``benchmarks/table1_byzantine.py``
and ``benchmarks/fig4_clients_privacy.py`` for the canonical usage."""

from .campaign import (
    ACCOUNTING_FIELDS,
    VMAP_FIELDS,
    CampaignSpec,
    CellSpec,
    Task,
    group_signature,
    run_campaign,
)
from .metrics import CampaignResult, CellResult, mean_ci
from .plan import (
    CampaignPlan,
    CompileCache,
    PlanGroup,
    default_compile_cache,
    fusable,
    fused_signature,
    plan_campaign,
)

__all__ = [
    "ACCOUNTING_FIELDS",
    "VMAP_FIELDS",
    "CampaignSpec",
    "CellSpec",
    "Task",
    "group_signature",
    "run_campaign",
    "CampaignResult",
    "CellResult",
    "mean_ci",
    "CampaignPlan",
    "PlanGroup",
    "CompileCache",
    "default_compile_cache",
    "fusable",
    "fused_signature",
    "plan_campaign",
]
