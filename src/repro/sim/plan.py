"""Campaign planner: lower a :class:`CampaignSpec` into a ``CampaignPlan`` IR.

The planner decides *how* a scenario grid executes before anything is
traced; the executor (:func:`repro.sim.campaign.run_campaign`) walks the
plan. Three decisions are encoded per group:

1. **Bucketing.** Cells sharing a static trace signature share one XLA
   program (as before). Cells that additionally satisfy :func:`fusable`
   are bucketed by :func:`fused_signature` — the static signature *minus*
   ``n_clients`` — so a whole M-sweep lands in one bucket.
2. **Fusion.** A bucket spanning several ``n_clients`` values becomes a
   *fused* group: the client axis is padded to the group max
   (``PlanGroup.m_pad``) and each cell's real client count rides the
   traced ``CellParams.m_active``; the 0/1 active-client mask folds into
   the Eq.-13 vote counts through the weighted-count path (PR 3), so the
   wire format is unchanged and **M moves from a static shape to a traced
   value**. The O(1/M) claim's most important sweep axis thus compiles
   once instead of once per M. A bucket with a single M executes exactly
   the pre-planner unmasked program.
3. **Placement.** ``shard=True`` makes device placement a plan property:
   the (cell, seed) batch axis of every group is laid out on a 1-D
   ``launch/mesh`` data mesh over all local devices.

Fusion requirements (checked per cell by :func:`fusable`): synchronous
rounds at full participation with no Byzantine cohort, dense wires, and a
non-oracle ``b`` — i.e. every knob whose *shape semantics* depend on M
must be off. Everything else (lr/momentum/lam/b_init/attack-id axes,
seeds, DP, error feedback, kernels) fuses freely.

Compilation is cached in a :class:`CompileCache`: executables are AOT
compiled via ``jit(fn).lower(*args).compile()`` and keyed by the plan
group's signature plus the input avals, so re-running a spec (benchmark
loops, repeated campaigns in one process) skips every lowering. The cache
counts ``lowerings`` and ``hits`` — tests assert a second identical run
triggers zero new lowerings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

from ..fl import FLConfig

__all__ = [
    "fusable",
    "fused_signature",
    "PlanGroup",
    "CampaignPlan",
    "plan_campaign",
    "CompileCache",
    "default_compile_cache",
    "STREAM_M_THRESHOLD",
    "STREAM_CHUNK",
]

# Above this padded client count, a fusable group's rounds execute
# streamed (lax.scan over client chunks) instead of dense: the full
# (M, d_pad/8) wire would dominate memory while the chunked scan keeps it
# at O(STREAM_CHUNK * d/8). Below it, dense vmapped rounds are faster
# (no scan overhead) and memory is irrelevant. Fusable cells are always
# safe to stream: byz_frac == 0 (no colluding-attack restriction),
# participation == 1, synchronous, non-oracle b.
STREAM_M_THRESHOLD = 4096

# The client-chunk size the planner picks when it streams a group.
STREAM_CHUNK = 1024


def fusable(cfg: FLConfig) -> bool:
    """Can this cell join a fused heterogeneous-M group?

    True iff nothing about the cell's program depends on M other than
    array *sizes*: synchronous rounds (the async buffer keys slots to
    client identity), full participation (the cohort draw's shape is the
    cohort), no Byzantine rows (``n_byz = int(M * byz_frac)`` is a static
    slice bound), dense wires (SparseWire has no weighted count path), and
    non-oracle ``b`` (the oracle maxes over the padded client axis).
    """
    return (
        cfg.async_buffer == 0
        and cfg.participation >= 1.0
        and cfg.byz_frac == 0.0
        and cfg.topk_frac >= 1.0
        and cfg.b_mode != "oracle"
        # Tree rounds slice the cohort into static per-edge spans, so the
        # client axis cannot pad to a group max (an edge would straddle
        # real and padded rows with a traced boundary).
        and cfg.tree_edges == 0
    )


def fused_signature(cfg: FLConfig) -> tuple:
    """The static trace signature with the client axis removed.

    Cells sharing it — and individually :func:`fusable` — share one
    *fused* program at the padded client count; ``n_clients`` itself rides
    the traced ``CellParams.m_active``.
    """
    from .campaign import ACCOUNTING_FIELDS, VMAP_FIELDS

    skip = VMAP_FIELDS | ACCOUNTING_FIELDS | {"n_clients"}
    return tuple(
        getattr(cfg, f.name)
        for f in dataclasses.fields(FLConfig)
        if f.name not in skip
    )


@dataclasses.dataclass(frozen=True)
class PlanGroup:
    """One executable unit of a campaign: one compiled program.

    ``cell_idx`` indexes into the spec's cells; ``m_pad`` is the padded
    client-axis size (the max ``n_clients`` over members — equal to every
    member's when ``fused`` is False). ``fused`` marks heterogeneous-M
    groups that thread the active-client mask.
    """

    signature: tuple
    cell_idx: tuple[int, ...]
    m_pad: int
    fused: bool
    # Planner-chosen streaming chunk: > 0 makes the executor run the
    # group's rounds under the chunked client scan (stream_fl_round) with
    # this chunk size. 0 = dense rounds, or the members already request a
    # chunk through FLConfig.client_chunk (which joins the signature and
    # is never overridden here).
    client_chunk: int = 0

    @property
    def n_cells(self) -> int:
        return len(self.cell_idx)


@dataclasses.dataclass(frozen=True)
class CampaignPlan:
    """Lowered form of a :class:`CampaignSpec`: what compiles and where.

    ``shard`` records the placement decision (batch axis on a 1-D device
    mesh); the executor resolves the actual device count at run time and
    reports it per group.
    """

    spec: Any  # CampaignSpec (kept untyped to avoid a circular import)
    groups: tuple[PlanGroup, ...]
    fuse_m: bool
    shard: bool

    @property
    def n_programs(self) -> int:
        return len(self.groups)

    @property
    def n_fused(self) -> int:
        return sum(1 for g in self.groups if g.fused)

    def describe(self) -> str:
        """Human-readable plan summary (one line per group)."""
        from ..kernels import resolve_engine

        lines = [
            f"CampaignPlan: {len(self.spec.cells)} cells x "
            f"{len(self.spec.seeds)} seeds -> {self.n_programs} programs "
            f"({self.n_fused} fused, shard={self.shard}, "
            f"backend={jax.default_backend()}, "
            f"kernel_engine={resolve_engine()})"
        ]
        for g in self.groups:
            kind = f"fused@M<={g.m_pad}" if g.fused else f"M={g.m_pad}"
            if g.client_chunk:
                kind += f", stream@{g.client_chunk}"
            g_cfg = self.spec.config(self.spec.cells[g.cell_idx[0]])
            if g_cfg.tree_edges:
                kind += f", tree@{g_cfg.tree_edges}"
                if g_cfg.edge_buffer:
                    kind += f"/buf{g_cfg.edge_buffer}"
            names = ", ".join(self.spec.cells[i].name for i in g.cell_idx)
            lines.append(f"  [{kind}] {g.n_cells} cells: {names}")
        return "\n".join(lines)


def plan_campaign(
    spec,
    *,
    fuse_m: bool = True,
    shard: bool = False,
    stream_threshold: int = STREAM_M_THRESHOLD,
    stream_chunk: int = STREAM_CHUNK,
) -> CampaignPlan:
    """Lower a spec into a :class:`CampaignPlan`.

    Grouping preserves the old engine's buckets exactly for non-fusable
    cells (static signature); fusable cells bucket by
    :func:`fused_signature` instead, merging an M-sweep into one program.
    ``fuse_m=False`` reproduces the pre-planner per-signature grouping for
    every cell (the parity baseline the fused path is tested against).

    Streaming is the plan's third decision: a fusable-keyed bucket whose
    padded client count exceeds ``stream_threshold`` gets
    ``client_chunk = stream_chunk`` — its rounds execute as the chunked
    client scan with O(stream_chunk * d/8) wire memory instead of
    materializing the (m_pad, d_pad/8) matrix. Cells that set
    ``FLConfig.client_chunk`` themselves keep their explicit chunk (it is
    part of the trace signature and never overridden).
    """
    from .campaign import group_signature

    cfgs = spec.configs()
    buckets: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(cfgs):
        if fuse_m and fusable(cfg):
            key = ("fused", *fused_signature(cfg))
        else:
            key = ("static", *group_signature(cfg))
        buckets.setdefault(key, []).append(i)

    groups = []
    for key, idxs in buckets.items():
        m_values = {cfgs[i].n_clients for i in idxs}
        m_pad = max(m_values)
        stream = (
            key[0] == "fused"
            and stream_chunk > 0
            and m_pad > stream_threshold
            and cfgs[idxs[0]].client_chunk == 0
        )
        groups.append(
            PlanGroup(
                signature=key,
                cell_idx=tuple(idxs),
                m_pad=m_pad,
                # A single-M bucket runs the exact unmasked program even
                # when it bucketed by fused signature — masking would only
                # add traced-M overhead for nothing.
                fused=len(m_values) > 1,
                client_chunk=min(stream_chunk, m_pad) if stream else 0,
            )
        )
    return CampaignPlan(
        spec=spec, groups=tuple(groups), fuse_m=fuse_m, shard=shard
    )


class CompileCache:
    """AOT-compile cache: ``(plan signature, input avals) -> executable``.

    ``compile(key, fn, args)`` lowers and compiles ``jit(fn)`` for the
    concrete ``args`` on a miss and returns the cached executable on a
    hit. The key must carry everything that shapes the program *besides*
    the argument avals (which are derived from ``args``): the plan group's
    static signature, execution flags, and a fingerprint of the task
    constants baked into the trace.

    Task constants are fingerprinted by object identity
    (:func:`task_fingerprint`); each cache entry keeps a strong reference
    to the objects behind its fingerprint (``keepalive``), so an id can
    never be recycled into a stale hit while the entry lives. Repeatedly
    running the same spec with a memoized task provider (the benchmark
    harness pattern) therefore triggers zero new lowerings after the
    first run; a genuinely new task object conservatively recompiles.

    The cache is LRU-bounded (``maxsize`` entries, default 128): a
    non-memoized task provider that rebuilds its arrays every call misses
    the id fingerprint each time, and without eviction a long-lived
    process would pin every old executable *and* dataset forever.
    Evicting an entry drops its keepalive references with it.
    """

    def __init__(self, maxsize: int = 128):
        self._entries: dict = {}  # insertion-ordered: LRU via re-insert
        self.maxsize = maxsize
        self.lowerings = 0
        self.hits = 0

    @staticmethod
    def _avals(args) -> tuple:
        return tuple(
            (tuple(x.shape), str(x.dtype))
            for x in jax.tree_util.tree_leaves(args)
        )

    @classmethod
    def _fingerprint_one(cls, obj: Any) -> tuple:
        """Structural identity of one trace constant.

        ``functools.partial`` wrappers are unwrapped into the identities of
        their target and bound arguments — task providers typically build a
        fresh ``partial(loss, model)`` per call around stable underlying
        functions and cached arrays, and the fresh wrapper must not defeat
        the cache. Everything else fingerprints by ``id`` (module-level
        functions and memoized arrays are stable; a genuinely new object
        conservatively recompiles).
        """
        import functools

        if isinstance(obj, functools.partial):
            return (
                "partial",
                cls._fingerprint_one(obj.func),
                tuple(cls._fingerprint_one(a) for a in obj.args),
                tuple(
                    (k, cls._fingerprint_one(v))
                    for k, v in sorted(obj.keywords.items())
                ),
            )
        return ("id", id(obj))

    def task_fingerprint(self, task_objs: Sequence[Any]) -> tuple:
        """Identity fingerprint of trace constants.

        The caller must pass the same objects to :meth:`compile` as
        ``keepalive`` so their ids stay valid for the entry's lifetime.
        """
        return tuple(self._fingerprint_one(o) for o in task_objs)

    def compile(
        self, key: tuple, fn: Callable, args: tuple, keepalive: Sequence[Any] = ()
    ):
        full_key = (key, self._avals(args))
        entry = self._entries.pop(full_key, None)
        if entry is None:
            self.lowerings += 1
            entry = (jax.jit(fn).lower(*args).compile(), tuple(keepalive))
            while len(self._entries) >= self.maxsize:
                self._entries.pop(next(iter(self._entries)))
        else:
            self.hits += 1
        self._entries[full_key] = entry  # re-insert: most recently used last
        return entry[0]

    @property
    def size(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.lowerings = 0
        self.hits = 0


_DEFAULT_CACHE = CompileCache()


def default_compile_cache() -> CompileCache:
    """The process-wide cache ``run_campaign`` uses unless handed one."""
    return _DEFAULT_CACHE
