"""Campaign result containers: per-cell trajectories, CIs, JSON artifacts.

A campaign run produces, per (cell, seed), the full per-round metric
trajectories recorded by :func:`repro.fl.rounds.run_rounds` — test
accuracy, mean local loss, the dynamic-b value, and ``theta_mse`` (the
aggregation error against the true mean of the uploaded updates, the
quantity Theorem 1 bounds at O(1/M)) — plus the host-side ``eps_spent``
trajectory: the cumulative DP budget after each round under the cell's
``dp_accountant`` (:class:`repro.core.PrivacyLedger`; seed-independent,
tiled across the seed axis, so it rides the same CellResult/JSON paths
as every measured metric). :class:`CampaignResult` groups them
by cell, summarizes across seeds as mean ± normal-approximation CI, and
serializes to the same JSON artifact structure ``benchmarks/run.py``
writes (so CI jobs can upload campaign JSON next to benchmark JSON);
:meth:`CampaignResult.emit_rows` yields ``(name, us_per_round, derived)``
rows for :func:`benchmarks.common.emit`.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Iterator

import numpy as np

__all__ = ["mean_ci", "CellResult", "CampaignResult"]

_Z95 = 1.96


def mean_ci(a: np.ndarray, axis: int = 0, z: float = _Z95) -> tuple[np.ndarray, np.ndarray]:
    """Mean and z*SEM half-width along ``axis`` (0-width for one sample)."""
    a = np.asarray(a, np.float64)
    n = a.shape[axis]
    mean = a.mean(axis=axis)
    if n < 2:
        return mean, np.zeros_like(mean)
    half = z * a.std(axis=axis, ddof=1) / np.sqrt(n)
    return mean, half


@dataclasses.dataclass
class CellResult:
    """One scenario cell: metric trajectories over seeds.

    ``metrics[name]`` has shape ``(n_seeds, rounds)``.
    """

    name: str
    overrides: dict
    metrics: dict[str, np.ndarray]

    @property
    def rounds(self) -> int:
        return next(iter(self.metrics.values())).shape[1]

    def final(self, metric: str = "acc") -> tuple[float, float]:
        """(mean, ci_half_width) of the last-round value across seeds."""
        mean, half = mean_ci(self.metrics[metric][:, -1])
        return float(mean), float(half)

    def trajectory(self, metric: str = "acc") -> tuple[np.ndarray, np.ndarray]:
        """Per-round (mean, ci_half_width) across seeds."""
        return mean_ci(self.metrics[metric], axis=0)

    def eps_spent(self) -> float:
        """Cumulative DP budget at the last round (0.0 for non-DP cells or
        results predating the privacy ledger)."""
        if "eps_spent" not in self.metrics:
            return 0.0
        return self.final("eps_spent")[0]

    def mean_over_rounds(self, metric: str, tail: int | None = None) -> float:
        """Seed-and-round mean of a metric (optionally last ``tail`` rounds)."""
        a = self.metrics[metric]
        if tail:
            a = a[:, -tail:]
        return float(np.mean(a))


@dataclasses.dataclass
class CampaignResult:
    """All cells of a campaign plus execution accounting.

    ``groups`` records how the planner batched the grid: one entry per
    compiled program with its member cells, wall/compile seconds, compile-
    cache hit flag, fused/m_pad, ``n_devices``, ``cells_per_sec``, and the
    padded-vs-real (cell, seed) element counts.
    """

    cells: list[CellResult]
    seeds: tuple[int, ...]
    groups: list[dict]
    wall_s: float

    @property
    def cells_per_sec(self) -> float:
        """Real (cell, seed) elements per campaign wall-second."""
        n = len(self.cells) * len(self.seeds)
        return n / self.wall_s if self.wall_s > 0 else float("inf")

    @property
    def n_devices(self) -> int:
        """Devices the widest group ran on (1 when unsharded)."""
        return max((g.get("n_devices", 1) for g in self.groups), default=1)

    def cell(self, name: str) -> CellResult:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"no cell named {name!r}; have {[c.name for c in self.cells]}")

    def final(self, metric: str = "acc") -> dict[str, tuple[float, float]]:
        return {c.name: c.final(metric) for c in self.cells}

    def emit_rows(self, prefix: str = "campaign") -> Iterator[tuple[str, float, str]]:
        """Rows for :func:`benchmarks.common.emit`: per-cell amortized cost.

        ``us_per_round`` divides each group's wall-clock evenly over its
        (cell, seed, round) work items — the apples-to-apples number
        against the sequential driver's per-round cost.
        """
        per_cell_us: dict[str, float] = {}
        for g in self.groups:
            work = sum(self.cell(n).rounds for n in g["cells"]) * len(self.seeds)
            us = g["wall_s"] / max(work, 1) * 1e6
            for n in g["cells"]:
                per_cell_us[n] = us
        for c in self.cells:
            # campaigns run with with_acc=False have no "acc" trajectory
            metric = "acc" if "acc" in c.metrics else next(iter(c.metrics))
            mean, half = c.final(metric)
            yield (
                f"{prefix}_{c.name}",
                per_cell_us[c.name],
                f"{metric}={mean:.4f}±{half:.4f}",
            )

    def to_json(self) -> dict:
        return {
            "seeds": list(self.seeds),
            "wall_s": self.wall_s,
            "cells_per_sec": self.cells_per_sec,
            "n_devices": self.n_devices,
            # Full execution accounting per compiled program: wall/compile
            # seconds, cache hit, fused/m_pad, n_devices, cells_per_sec,
            # and padded-vs-real element counts.
            "groups": [
                {k: _jsonable(v) for k, v in g.items()} for g in self.groups
            ],
            "cells": {
                c.name: {
                    "overrides": {k: _jsonable(v) for k, v in c.overrides.items()},
                    "final": {m: c.final(m) for m in c.metrics},
                    "trajectory_mean": {
                        m: np.asarray(c.trajectory(m)[0]).tolist() for m in c.metrics
                    },
                    # z*SEM half-width per round, so plots rendered from
                    # the JSON artifact on disk keep their CI bands
                    # (benchmarks/plots.py reads this; zeros for a single
                    # seed).
                    "trajectory_ci": {
                        m: np.asarray(c.trajectory(m)[1]).tolist() for m in c.metrics
                    },
                }
                for c in self.cells
            },
        }

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path


def _jsonable(v: Any):
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    return v
