"""Vectorized scenario-campaign engine: whole grids as one computation.

A :class:`CampaignSpec` declares a grid of FL scenarios — a base
:class:`~repro.fl.FLConfig` plus per-cell overrides and a seed list — and
:func:`run_campaign` executes the entire grid through the functional round
core (:mod:`repro.fl.rounds`) instead of sequential Python-looped
:class:`~repro.fl.FLSimulation` runs:

1. Cells are **grouped** by their static trace signature (every FLConfig
   field that shapes the compiled program: client count, aggregator,
   participation, DP, b-mode, rounds, ...). One group == one XLA program.
2. Within a group, the engine **vmaps** over all (cell, seed) pairs at
   once. Cells may differ in the *traced* scenario fields
   (:data:`VMAP_FIELDS`): learning rate, momentum, prox weight, b_init,
   the seed, the async arrival latency and staleness decay, and the
   attack — delta-level attacks dispatch through ``lax.switch`` on a
   traced id, and the ``bit_flip`` wire adversary and the ``straggler``
   timing adversary are traced gates, so a full attack axis (timing
   included) rides a single vmapped batch.
3. Groups whose shapes or static fields differ (e.g. an M-sweep changing
   ``n_clients``) **fall back to grouped execution**: one compiled
   program per group, still scanned over rounds and vmapped over seeds.
4. With ``shard=True`` and more than one device, the (cell, seed) batch
   axis is sharded across devices via the ``launch/mesh`` utilities —
   campaign cells are embarrassingly parallel.

At a fixed seed each cell reproduces ``FLSimulation`` exactly (same RNG
schedule, same per-round math — see ``tests/test_campaign.py``), so grids
previously run as benchmark loops are drop-in replaceable.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import is_timing_attack, is_wire_attack
from ..fl import FLConfig
from ..fl import rounds as R
from .metrics import CampaignResult, CellResult

__all__ = [
    "VMAP_FIELDS",
    "ACCOUNTING_FIELDS",
    "Task",
    "CellSpec",
    "CampaignSpec",
    "group_signature",
    "run_campaign",
]

# FLConfig fields that enter the compiled program only as traced values —
# cells differing solely in these (plus the seed) share one vmapped trace.
# The attack axis covers timing adversaries too: a ``straggler+payload``
# cell rides the same program as its payload-only neighbour (the timing
# gate is a traced bool). ``async_buffer`` is deliberately NOT here — it
# shapes the buffer, so sync and async cells compile separate programs,
# but both kinds group and run inside one ``run_campaign`` call.
VMAP_FIELDS = frozenset(
    {"lr", "momentum", "lam", "b_init", "attack", "seed",
     "async_latency", "staleness_decay"}
)

# FLConfig fields that never enter the compiled program at all — pure
# host-side bookkeeping (the DP accountant only shapes the reported
# eps_spent trajectory). Cells differing solely here share one program.
ACCOUNTING_FIELDS = frozenset({"dp_accountant"})


@dataclasses.dataclass(frozen=True)
class Task:
    """The learning task a campaign cell runs on (data + model + metrics)."""

    init_params: Any
    loss_fn: Callable
    acc_fn: Callable
    client_x: Any  # (n_clients, per_client, ...)
    client_y: Any  # (n_clients, per_client)
    test: dict


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One scenario cell: a name plus FLConfig field overrides."""

    name: str
    overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """A scenario grid: base config, cells, seeds.

    ``base`` holds FLConfig kwargs shared by every cell; each cell's
    overrides are applied on top. ``seeds`` drive the training RNG
    (``FLConfig.seed`` in base/overrides is ignored — the campaign owns
    the seed axis).
    """

    base: Mapping[str, Any]
    cells: tuple[CellSpec, ...]
    seeds: tuple[int, ...] = (0,)

    def config(self, cell: CellSpec) -> FLConfig:
        return FLConfig(**{**dict(self.base), **dict(cell.overrides)})

    def configs(self) -> list[FLConfig]:
        return [self.config(c) for c in self.cells]

    @staticmethod
    def from_grid(
        base: Mapping[str, Any],
        axes: Mapping[str, Sequence[Any]],
        seeds: Sequence[int] = (0,),
    ) -> "CampaignSpec":
        """Cartesian product over ``axes`` (dict field -> values).

        Cell names are ``field=value`` pairs joined with ``|`` in axis
        order, e.g. ``attack=gaussian|aggregator=rsa``.
        """
        names = list(axes)
        cells = []
        for combo in itertools.product(*(axes[n] for n in names)):
            overrides = dict(zip(names, combo))
            cells.append(
                CellSpec("|".join(f"{k}={v}" for k, v in overrides.items()), overrides)
            )
        return CampaignSpec(base=dict(base), cells=tuple(cells), seeds=tuple(seeds))


def group_signature(cfg: FLConfig) -> tuple:
    """The static trace signature — cells sharing it share one program."""
    return tuple(
        getattr(cfg, f.name)
        for f in dataclasses.fields(FLConfig)
        if f.name not in VMAP_FIELDS and f.name not in ACCOUNTING_FIELDS
    )


def _batched_inputs(ctx, cfgs: list[FLConfig], seeds: Sequence[int]):
    """Stack per-(cell, seed) CellParams, PRNG keys, and initial states."""
    elems = [(cfg, s) for cfg in cfgs for s in seeds]
    params = R.CellParams(
        lr=jnp.asarray([c.lr for c, _ in elems], jnp.float32),
        momentum=jnp.asarray([c.momentum for c, _ in elems], jnp.float32),
        lam=jnp.asarray([c.lam for c, _ in elems], jnp.float32),
        attack_id=jnp.asarray(
            [R.cell_params(c).attack_id for c, _ in elems], jnp.int32
        ),
        flip_gate=jnp.asarray(
            [is_wire_attack(c.attack) for c, _ in elems], jnp.bool_
        ),
        latency=jnp.asarray([c.async_latency for c, _ in elems], jnp.float32),
        staleness_decay=jnp.asarray(
            [c.staleness_decay for c, _ in elems], jnp.float32
        ),
        straggler_gate=jnp.asarray(
            [is_timing_attack(c.attack) for c, _ in elems], jnp.bool_
        ),
    )
    keys = jnp.stack([jax.random.PRNGKey(s) for _, s in elems])
    b_inits = jnp.asarray([c.b_init for c, _ in elems], jnp.float32)
    states = jax.vmap(lambda b0: R.init_run_state(ctx, b0))(b_inits)
    return params, keys, states


def _shard_over_devices(trees, n: int):
    """Shard the leading (cell, seed) axis over all local devices.

    Returns (possibly padded) trees plus the padded size; a no-op on a
    single device. Padding repeats the last element — padded results are
    sliced away by the caller.
    """
    devices = jax.devices()
    if len(devices) <= 1:
        return trees, n
    from ..launch.mesh import make_mesh

    n_dev = len(devices)
    n_pad = ((n + n_dev - 1) // n_dev) * n_dev
    mesh = make_mesh((n_dev,), ("data",))
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data")
    )

    def pad_leaf(x):
        if n_pad > n:
            x = jnp.concatenate([x, jnp.repeat(x[-1:], n_pad - n, axis=0)])
        return jax.device_put(x, sharding)

    return jax.tree.map(pad_leaf, trees), n_pad


def run_campaign(
    spec: CampaignSpec,
    task_fn: Callable[[FLConfig], Task],
    *,
    shard: bool = False,
    with_acc: bool = True,
    verbose: bool = False,
) -> CampaignResult:
    """Execute a campaign grid; returns per-cell trajectories + timings.

    ``task_fn(cfg)`` supplies the task for a cell's config (called once
    per group with a representative config — memoize inside if building
    data is expensive). Group wall-clock includes compilation: that is the
    honest comparison against sequential drivers, which also jit per run.
    """
    cfgs = spec.configs()
    groups: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(cfgs):
        groups.setdefault(group_signature(cfg), []).append(i)

    t_start = time.perf_counter()
    cell_results: dict[int, CellResult] = {}
    group_stats: list[dict] = []
    for idxs in groups.values():
        group_cfgs = [cfgs[i] for i in idxs]
        cfg0 = group_cfgs[0]
        task = task_fn(cfg0)
        wire_flip = any(is_wire_attack(c.attack) for c in group_cfgs)
        ctx = R.make_context(
            cfg0,
            task.init_params,
            task.loss_fn,
            task.acc_fn,
            task.client_x,
            task.client_y,
            task.test,
            wire_flip=wire_flip,
        )
        params, keys, states = _batched_inputs(ctx, group_cfgs, spec.seeds)
        n = len(group_cfgs) * len(spec.seeds)
        if shard:
            (params, keys, states), _ = _shard_over_devices((params, keys, states), n)

        runner = jax.jit(
            jax.vmap(lambda p, k, s: R.run_rounds(ctx, p, k, s, with_acc=with_acc)[1])
        )
        t0 = time.perf_counter()
        traj = jax.block_until_ready(runner(params, keys, states))
        wall = time.perf_counter() - t0

        traj = {m: np.asarray(v)[:n] for m, v in traj.items()}
        n_seeds = len(spec.seeds)
        for j, i in enumerate(idxs):
            metrics = {
                m: v[j * n_seeds : (j + 1) * n_seeds] for m, v in traj.items()
            }
            # Cumulative DP budget under the cell's accountant — closed
            # form on the host (accounting never enters the trace), seed-
            # independent, so the trajectory is tiled across the seed axis
            # like any other first-class metric.
            eps_traj = cfgs[i].ledger().trajectory(cfgs[i].rounds)
            metrics["eps_spent"] = np.tile(eps_traj[None, :], (n_seeds, 1))
            cell_results[i] = CellResult(
                name=spec.cells[i].name,
                overrides=dict(spec.cells[i].overrides),
                metrics=metrics,
            )
        group_stats.append(
            {"cells": [spec.cells[i].name for i in idxs], "wall_s": wall}
        )
        if verbose:
            print(
                f"[campaign] group of {len(idxs)} cells x {n_seeds} seeds: "
                f"{wall:.2f}s ({', '.join(spec.cells[i].name for i in idxs)})"
            )

    return CampaignResult(
        cells=[cell_results[i] for i in range(len(cfgs))],
        seeds=spec.seeds,
        groups=group_stats,
        wall_s=time.perf_counter() - t_start,
    )
