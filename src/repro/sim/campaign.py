"""Scenario-campaign engine: plan, then execute.

A :class:`CampaignSpec` declares a grid of FL scenarios — a base
:class:`~repro.fl.FLConfig` plus per-cell overrides and a seed list. Since
the planner/executor split, execution is two explicit stages:

**Plan** (:func:`repro.sim.plan.plan_campaign`) lowers the spec into a
:class:`~repro.sim.plan.CampaignPlan` IR — one :class:`PlanGroup` per
compiled program:

1. Cells bucket by their **static trace signature** (every FLConfig field
   that shapes the compiled program). Cells differing only in *traced*
   scenario fields (:data:`VMAP_FIELDS` — lr, momentum, prox weight,
   b_init, seed, async latency/decay, and the attack, incl. the traced
   bit_flip / straggler gates) ride one vmapped batch.
2. Cells that are :func:`~repro.sim.plan.fusable` additionally **fuse
   across differing** ``n_clients``: the client axis pads to the group max
   and each cell's real M rides the traced ``CellParams.m_active``; the
   0/1 active-client mask folds into the Eq.-13 vote counts via the
   weighted-count path, wire format unchanged. An M-sweep — the paper's
   O(1/M) axis — is then ONE program instead of one per M.
3. ``shard=True`` makes placement a plan property: each group's
   (cell, seed) batch axis is laid out on a 1-D ``launch/mesh`` data mesh
   over all local devices (campaign cells are embarrassingly parallel).

**Execute** (:func:`run_campaign`) walks the plan:

* programs are AOT-compiled through a process-wide
  :class:`~repro.sim.plan.CompileCache` keyed by (signature, shapes) via
  ``jit(...).lower().compile()`` — repeated campaigns skip recompiles;
* dispatch is **overlapped**: every group's computation launches before
  the first ``block_until_ready``, so host lowering and device compute
  pipeline instead of serializing;
* per-group execution accounting lands in ``CampaignResult.groups`` (and
  its JSON): wall/compile seconds, cache hit, ``n_devices``,
  ``cells_per_sec``, and padded-vs-real element counts.

At a fixed seed each cell reproduces ``FLSimulation`` exactly (same RNG
schedule, same per-round math — see ``tests/test_campaign.py``); fused
and per-group execution agree to jit tolerance (``tests/test_plan.py``).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
import warnings
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import is_timing_attack, is_wire_attack
from ..fl import FLConfig
from ..fl import rounds as R
from ..kernels import resolve_engine
from .metrics import CampaignResult, CellResult
from .plan import (
    CampaignPlan,
    CompileCache,
    PlanGroup,
    default_compile_cache,
    plan_campaign,
)

__all__ = [
    "VMAP_FIELDS",
    "ACCOUNTING_FIELDS",
    "Task",
    "CellSpec",
    "CampaignSpec",
    "group_signature",
    "run_campaign",
]

# FLConfig fields that enter the compiled program only as traced values —
# cells differing solely in these (plus the seed) share one vmapped trace.
# The attack axis covers timing adversaries too: a ``straggler+payload``
# cell rides the same program as its payload-only neighbour (the timing
# gate is a traced bool). ``async_buffer`` is deliberately NOT here — it
# shapes the buffer, so sync and async cells compile separate programs,
# but both kinds group and run inside one ``run_campaign`` call.
# ``n_clients`` is not here either: it is a *shape* — but the planner can
# still fuse an M-sweep by padding + masking (see repro.sim.plan).
VMAP_FIELDS = frozenset(
    {"lr", "momentum", "lam", "b_init", "attack", "seed",
     "async_latency", "staleness_decay"}
)

# FLConfig fields that never enter the compiled program at all — pure
# host-side bookkeeping (the DP accountant only shapes the reported
# eps_spent trajectory). Cells differing solely here share one program.
ACCOUNTING_FIELDS = frozenset({"dp_accountant"})


@dataclasses.dataclass(frozen=True)
class Task:
    """The learning task a campaign cell runs on (data + model + metrics)."""

    init_params: Any
    loss_fn: Callable
    acc_fn: Callable
    client_x: Any  # (n_clients, per_client, ...)
    client_y: Any  # (n_clients, per_client)
    test: dict


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One scenario cell: a name plus FLConfig field overrides."""

    name: str
    overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """A scenario grid: base config, cells, seeds.

    ``base`` holds FLConfig kwargs shared by every cell; each cell's
    overrides are applied on top. ``seeds`` drive the training RNG
    (``FLConfig.seed`` in base/overrides is ignored — the campaign owns
    the seed axis).
    """

    base: Mapping[str, Any]
    cells: tuple[CellSpec, ...]
    seeds: tuple[int, ...] = (0,)

    def config(self, cell: CellSpec) -> FLConfig:
        return FLConfig(**{**dict(self.base), **dict(cell.overrides)})

    def configs(self) -> list[FLConfig]:
        return [self.config(c) for c in self.cells]

    @staticmethod
    def from_grid(
        base: Mapping[str, Any],
        axes: Mapping[str, Sequence[Any]],
        seeds: Sequence[int] = (0,),
    ) -> "CampaignSpec":
        """Cartesian product over ``axes`` (dict field -> values).

        Cell names are ``field=value`` pairs joined with ``|`` in axis
        order, e.g. ``attack=gaussian|aggregator=rsa``.
        """
        names = list(axes)
        cells = []
        for combo in itertools.product(*(axes[n] for n in names)):
            overrides = dict(zip(names, combo))
            cells.append(
                CellSpec("|".join(f"{k}={v}" for k, v in overrides.items()), overrides)
            )
        return CampaignSpec(base=dict(base), cells=tuple(cells), seeds=tuple(seeds))


def group_signature(cfg: FLConfig) -> tuple:
    """The static trace signature — cells sharing it share one program."""
    return tuple(
        getattr(cfg, f.name)
        for f in dataclasses.fields(FLConfig)
        if f.name not in VMAP_FIELDS and f.name not in ACCOUNTING_FIELDS
    )


def _batched_inputs(ctx, cfgs: list[FLConfig], seeds: Sequence[int], *, masked: bool = False):
    """Stack per-(cell, seed) CellParams, PRNG keys, and initial states."""
    elems = [(cfg, s) for cfg in cfgs for s in seeds]
    params = R.CellParams(
        lr=jnp.asarray([c.lr for c, _ in elems], jnp.float32),
        momentum=jnp.asarray([c.momentum for c, _ in elems], jnp.float32),
        lam=jnp.asarray([c.lam for c, _ in elems], jnp.float32),
        attack_id=jnp.asarray(
            [R.cell_params(c).attack_id for c, _ in elems], jnp.int32
        ),
        flip_gate=jnp.asarray(
            [is_wire_attack(c.attack) for c, _ in elems], jnp.bool_
        ),
        latency=jnp.asarray([c.async_latency for c, _ in elems], jnp.float32),
        staleness_decay=jnp.asarray(
            [c.staleness_decay for c, _ in elems], jnp.float32
        ),
        straggler_gate=jnp.asarray(
            [is_timing_attack(c.attack) for c, _ in elems], jnp.bool_
        ),
        # Real (unpadded) client count; only masked (fused) programs read
        # it. None keeps the unmasked CellParams pytree structure.
        m_active=(
            jnp.asarray([c.n_active for c, _ in elems], jnp.int32)
            if masked
            else None
        ),
    )
    keys = jnp.stack([jax.random.PRNGKey(s) for _, s in elems])
    b_inits = jnp.asarray([c.b_init for c, _ in elems], jnp.float32)
    states = jax.vmap(lambda b0: R.init_run_state(ctx, b0))(b_inits)
    return params, keys, states


_WARNED_SINGLE_DEVICE = False


def _shard_over_devices(trees, n: int):
    """Shard the leading (cell, seed) axis over all local devices.

    Returns (possibly padded) trees plus the padded size and the device
    count. On a single device sharding cannot do anything — that case
    warns once per process (it usually means the
    ``--xla_force_host_platform_device_count`` flag the caller expected is
    not set) and returns the inputs untouched; the executor still reports
    ``n_devices=1`` in the group stats. Padding repeats the last element —
    padded results are sliced away by the caller.
    """
    global _WARNED_SINGLE_DEVICE
    devices = jax.devices()
    n_dev = len(devices)
    if n_dev <= 1:
        if not _WARNED_SINGLE_DEVICE:
            _WARNED_SINGLE_DEVICE = True
            warnings.warn(
                "run_campaign(shard=True) is a no-op: only one local device "
                "is visible. For CPU scaling runs set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N before importing "
                "jax (see benchmarks/fig_campaign_throughput.py).",
                RuntimeWarning,
                stacklevel=3,
            )
        return trees, n, 1, None
    from ..launch.mesh import make_campaign_mesh

    n_pad = ((n + n_dev - 1) // n_dev) * n_dev
    mesh = make_campaign_mesh(n_dev)
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data")
    )

    def pad_leaf(x):
        if n_pad > n:
            x = jnp.concatenate([x, jnp.repeat(x[-1:], n_pad - n, axis=0)])
        return jax.device_put(x, sharding)

    return jax.tree.map(pad_leaf, trees), n_pad, n_dev, mesh


def _pad_clients(arr: np.ndarray, m_pad: int) -> np.ndarray:
    """Pad the leading client axis to ``m_pad`` with wrap-around rows.

    Padded clients train on (copies of) real data so every per-row value
    stays finite; the active-client mask keeps them out of the estimate,
    the b-vote, and the metrics, and their w_local/residual rows are never
    read back per cell.
    """
    arr = np.asarray(arr)
    if arr.shape[0] == m_pad:
        return arr
    return arr[np.arange(m_pad) % arr.shape[0]]


def _task_leaves(task: Task, *, with_clients: bool) -> list:
    """The task objects a compiled program bakes in as trace constants."""
    leaves = list(jax.tree_util.tree_leaves(task.init_params))
    leaves += [task.loss_fn, task.acc_fn]
    leaves += list(jax.tree_util.tree_leaves(task.test))
    if with_clients:
        leaves += [task.client_x, task.client_y]
    return leaves


class _GroupFusionError(Exception):
    """A fused group's cells turned out not to share a batchable task."""


def _peak_bytes_est(ctx, n_elems_per_dev: int) -> int:
    """Estimated peak resident bytes of one device's aggregation path.

    Padded wire rows + the server's accumulator, per (cell, seed) element,
    times the elements a device carries. Dense rounds hold all
    ``n_clients`` wire rows; streamed rounds hold one ``client_chunk``-row
    chunk plus the O(d) count/sum carry (fed_gm's buffer kind still holds
    every row — streaming it is a parity fallback, not a memory win).
    Reported per group in the campaign JSON so streaming-vs-dense memory
    is visible without a profiler.
    """
    cfg = ctx.cfg
    d = ctx.d
    rows = cfg.client_chunk or cfg.n_clients
    p_bytes = ctx.pipeline.compressor.wire_bytes(d)
    kind = ctx.pipeline.server.stream_kind
    if p_bytes is None:  # dense wire (FedAvg / Fed-GM)
        if cfg.client_chunk and kind == "buffer":
            rows = cfg.n_clients
        wire = rows * d * 4
        acc = d * 4
    else:
        wire = rows * p_bytes
        acc = 8 * p_bytes * 4  # one int32/f32 vote count per padded bit
        if cfg.tree_edges:
            # Stacked per-edge count tensors at the root, plus the bounded
            # async edge buffer when configured.
            acc += (cfg.tree_edges + cfg.edge_buffer) * 8 * p_bytes * 4
    return n_elems_per_dev * (wire + acc)


def _prepare_group(
    group: PlanGroup,
    cfgs: list[FLConfig],
    spec: CampaignSpec,
    task_fn: Callable[[FLConfig], Task],
    *,
    with_acc: bool,
    shard: bool,
    cache: CompileCache,
):
    """Build (vmapped fn, args, cache key) for one plan group.

    For a fused group the per-cell client datasets are padded to
    ``group.m_pad``, stacked once along a *cell* axis, and gathered inside
    the program through a per-(cell, seed) index — client data becomes a
    broadcast *argument* of the compiled program rather than a baked
    constant (one executable serves every M) and is resident on device
    exactly once regardless of the seed count. The representative cell
    supplies the init params / loss / test set, which a fusable task
    provider must keep M-independent (the benchmark harness does); a
    shape mismatch raises :class:`_GroupFusionError` and the executor
    falls back to per-signature execution for that group.
    """
    group_cfgs = [cfgs[i] for i in group.cell_idx]
    wire_flip = any(is_wire_attack(c.attack) for c in group_cfgs)
    n = len(group_cfgs) * len(spec.seeds)

    if group.fused:
        tasks = [task_fn(c) for c in group_cfgs]
        rep = tasks[0]
        cxs = [_pad_clients(t.client_x, group.m_pad) for t in tasks]
        cys = [_pad_clients(t.client_y, group.m_pad) for t in tasks]
        if len({c.shape for c in cxs}) > 1 or len({c.shape for c in cys}) > 1:
            raise _GroupFusionError(
                f"per-client data shapes differ across the fused M group "
                f"{[spec.cells[i].name for i in group.cell_idx]}"
            )
        ctx_cfg = dataclasses.replace(group_cfgs[0], n_clients=group.m_pad)
        if group.client_chunk and ctx_cfg.client_chunk == 0:
            # Planner-chosen streaming: the padded client axis exceeded
            # the stream threshold, so the group's rounds scan chunks.
            ctx_cfg = dataclasses.replace(
                ctx_cfg, client_chunk=group.client_chunk
            )
        ctx = R.make_context(
            ctx_cfg, rep.init_params, rep.loss_fn, rep.acc_fn,
            cxs[0], cys[0], rep.test, wire_flip=wire_flip, masked=True,
        )
        params, keys, states = _batched_inputs(
            ctx, group_cfgs, spec.seeds, masked=True
        )
        # (n_cells, m_pad, ...) stacks, one row per CELL; each (cell,
        # seed) batch element gathers its row via data_idx.
        cx_all = jnp.asarray(np.stack(cxs))
        cy_all = jnp.asarray(np.stack(cys))
        data_idx = jnp.asarray(
            np.repeat(np.arange(len(group_cfgs)), len(spec.seeds)), jnp.int32
        )

        def cell_fn(p, k, s, di, cx, cy):
            c = dataclasses.replace(ctx, client_x=cx[di], client_y=cy[di])
            return R.run_rounds(c, p, k, s, with_acc=with_acc)[1]

        batched = (params, keys, states, data_idx)
        bcast = (cx_all, cy_all)
        in_axes = (0, 0, 0, 0, None, None)
        task_fp = cache.task_fingerprint(_task_leaves(rep, with_clients=False))
        keepalive = _task_leaves(rep, with_clients=False)
    else:
        task = task_fn(group_cfgs[0])
        ctx_cfg = group_cfgs[0]
        if group.client_chunk and ctx_cfg.client_chunk == 0:
            ctx_cfg = dataclasses.replace(
                ctx_cfg, client_chunk=group.client_chunk
            )
        ctx = R.make_context(
            ctx_cfg, task.init_params, task.loss_fn, task.acc_fn,
            task.client_x, task.client_y, task.test, wire_flip=wire_flip,
        )
        params, keys, states = _batched_inputs(ctx, group_cfgs, spec.seeds)

        def cell_fn(p, k, s):
            return R.run_rounds(ctx, p, k, s, with_acc=with_acc)[1]

        batched = (params, keys, states)
        bcast = ()
        in_axes = (0, 0, 0)
        task_fp = cache.task_fingerprint(_task_leaves(task, with_clients=True))
        keepalive = _task_leaves(task, with_clients=True)

    n_padded, n_dev = n, 1
    if shard:
        batched, n_padded, n_dev, mesh = _shard_over_devices(batched, n)
        if mesh is not None and bcast:
            # The cell-data stacks are not batch-sharded — replicate them.
            replicated = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            )
            bcast = tuple(jax.device_put(x, replicated) for x in bcast)

    key = (
        group.signature, group.m_pad, group.fused, group.client_chunk,
        wire_flip, with_acc, n_dev, task_fp,
    )
    peak_bytes = _peak_bytes_est(ctx, -(-n_padded // n_dev))
    fn = jax.vmap(cell_fn, in_axes=in_axes)
    return fn, batched + bcast, key, keepalive, n, n_padded, n_dev, peak_bytes


def _demote_group(group: PlanGroup, cfgs: list[FLConfig]) -> list[PlanGroup]:
    """Fallback for an unfusable-in-practice fused group: per-signature."""
    sub: dict[tuple, list[int]] = {}
    for i in group.cell_idx:
        sub.setdefault(group_signature(cfgs[i]), []).append(i)
    return [
        PlanGroup(
            signature=("static", *sig),
            cell_idx=tuple(idxs),
            m_pad=cfgs[idxs[0]].n_clients,
            fused=False,
        )
        for sig, idxs in sub.items()
    ]


def run_campaign(
    spec: CampaignSpec,
    task_fn: Callable[[FLConfig], Task],
    *,
    shard: bool | None = None,
    with_acc: bool = True,
    verbose: bool = False,
    fuse_m: bool | None = None,
    plan: CampaignPlan | None = None,
    compile_cache: CompileCache | None = None,
) -> CampaignResult:
    """Plan (unless handed a plan) and execute a campaign grid.

    ``task_fn(cfg)`` supplies the task for a cell's config (called once
    per group member for fused groups, once per group otherwise — memoize
    inside if building data is expensive). ``fuse_m=False`` disables
    heterogeneous-M fusion (the parity baseline); ``compile_cache``
    defaults to the process-wide AOT cache, so repeated campaigns of the
    same spec skip every lowering. When an explicit ``plan`` is handed in
    it owns the ``shard``/``fuse_m`` decisions — passing a conflicting
    flag alongside it is an error, not a silent override.

    Execution is overlapped: all groups are compiled and *dispatched*
    first, then collected in dispatch order. A group's ``wall_s``
    therefore measures dispatch-to-ready (device compute overlaps across
    groups); ``compile_s`` is the host-side lowering cost, zero on a cache
    hit. Both land in ``CampaignResult.groups`` together with
    ``n_devices``, ``cells_per_sec`` (real (cell, seed) elements per
    wall-second), and the padded-vs-real element counts.
    """
    if plan is None:
        plan = plan_campaign(
            spec,
            fuse_m=True if fuse_m is None else fuse_m,
            shard=bool(shard),
        )
    else:
        for name, arg, planned in (
            ("shard", shard, plan.shard), ("fuse_m", fuse_m, plan.fuse_m)
        ):
            if arg is not None and arg != planned:
                raise ValueError(
                    f"run_campaign({name}={arg}) conflicts with the explicit "
                    f"plan ({name}={planned}); set it in plan_campaign() or "
                    "drop the keyword"
                )
    cache = compile_cache if compile_cache is not None else default_compile_cache()
    cfgs = spec.configs()

    t_start = time.perf_counter()
    launched: list[dict] = []
    worklist = list(plan.groups)
    while worklist:
        group = worklist.pop(0)
        try:
            fn, args, key, keepalive, n, n_padded, n_dev, peak_bytes = (
                _prepare_group(
                    group, cfgs, spec, task_fn,
                    with_acc=with_acc, shard=plan.shard, cache=cache,
                )
            )
        except _GroupFusionError as e:
            warnings.warn(
                f"demoting fused campaign group to per-M execution: {e}",
                RuntimeWarning,
                stacklevel=2,
            )
            worklist = _demote_group(group, cfgs) + worklist
            continue
        t0 = time.perf_counter()
        hits_before = cache.hits
        compiled = cache.compile(key, fn, args, keepalive=keepalive)
        t_compile = time.perf_counter() - t0
        t_dispatch = time.perf_counter()
        out = compiled(*args)
        launched.append(
            dict(
                group=group, out=out, n=n, n_padded=n_padded, n_dev=n_dev,
                t_dispatch=t_dispatch, compile_s=t_compile,
                cache_hit=cache.hits > hits_before, peak_bytes=peak_bytes,
            )
        )

    cell_results: dict[int, CellResult] = {}
    group_stats: list[dict] = []
    n_seeds = len(spec.seeds)
    for L in launched:
        group: PlanGroup = L["group"]
        traj = jax.block_until_ready(L["out"])
        wall = time.perf_counter() - L["t_dispatch"]
        traj = {m: np.asarray(v)[: L["n"]] for m, v in traj.items()}
        for j, i in enumerate(group.cell_idx):
            metrics = {
                m: v[j * n_seeds : (j + 1) * n_seeds] for m, v in traj.items()
            }
            # Cumulative DP budget under the cell's accountant — closed
            # form on the host (accounting never enters the trace), seed-
            # independent, so the trajectory is tiled across the seed axis
            # like any other first-class metric.
            eps_traj = cfgs[i].ledger().trajectory(cfgs[i].rounds)
            metrics["eps_spent"] = np.tile(eps_traj[None, :], (n_seeds, 1))
            cell_results[i] = CellResult(
                name=spec.cells[i].name,
                overrides=dict(spec.cells[i].overrides),
                metrics=metrics,
            )
        stats = {
            "cells": [spec.cells[i].name for i in group.cell_idx],
            "wall_s": wall,
            "compile_s": L["compile_s"],
            "cache_hit": L["cache_hit"],
            "fused": group.fused,
            "m_pad": group.m_pad,
            "client_chunk": (
                group.client_chunk or cfgs[group.cell_idx[0]].client_chunk
            ),
            "tree_edges": cfgs[group.cell_idx[0]].tree_edges,
            "peak_bytes_est": L["peak_bytes"],
            "n_devices": L["n_dev"],
            "n_elems": L["n"],
            "n_elems_padded": L["n_padded"],
            "cells_per_sec": L["n"] / wall if wall > 0 else float("inf"),
            # Which engine actually served the packed wire: the dispatch
            # policy (kernels.ops.resolve_engine) picks the winner per
            # backend, so use_kernels=True never lands on interpret-mode
            # Pallas off-TPU (the regression this field makes auditable).
            "backend": jax.default_backend(),
            "kernel_engine": (
                resolve_engine()
                if cfgs[group.cell_idx[0]].use_kernels
                else "jax"
            ),
        }
        group_stats.append(stats)
        if verbose:
            kind = "fused" if group.fused else "static"
            print(
                f"[campaign] {kind} group of {group.n_cells} cells x "
                f"{n_seeds} seeds on {L['n_dev']} device(s): {wall:.2f}s "
                f"exec + {L['compile_s']:.2f}s compile"
                f"{' (cached)' if L['cache_hit'] else ''} "
                f"({stats['cells_per_sec']:.1f} cells/s: "
                f"{', '.join(stats['cells'])})"
            )

    return CampaignResult(
        cells=[cell_results[i] for i in range(len(cfgs))],
        seeds=spec.seeds,
        groups=group_stats,
        wall_s=time.perf_counter() - t_start,
    )
