"""Hierarchical count-tree aggregation: clients -> edge aggregators -> root.

Because packed vote counts are *additive* (PR 6's ``init_counts /
accumulate_counts / finalize`` protocol), a round does not have to funnel
all M clients through one root: the cohort splits into ``tree_edges``
contiguous client slices, each **edge** runs the exact chunked
count-accumulation scan of the flat streaming round
(:func:`repro.fl.rounds._scan_chunks`) over its slice, and ships the root
only

* its ``(8 * p_bytes,)`` f32 count tensor (the per-plane vote histogram),
* its active-mass scalar (the slice's effective cohort weight), and
* the synchronous round-heartbeat sums (b-controller loss-bit vote, loss /
  delta metric sums) that piggyback on every upload wave.

The root merges E count tensors instead of M uploads — the fan-in that
turns a single-host bottleneck into a tree of independent reductions.

**Bit-exactness (zero staleness).** Per-client PRNG is counter-derived
(batches keyed ``fold_in(kb, client_id)``, quantizer rows keyed by global
cohort position via ``row_offset``, streaming attacks keyed by row id), so
an edge reproduces exactly the bits the flat scan drew for its rows.
Edge partial counts are integer-valued f32 sums of 0/1-weighted bits
(exact below 2**24 clients per the count-dtype policy), and ``jnp.sum``
over the stacked edge axis reassociates *integers* — so the merged root
counts, and therefore ``w_global``, ``b``, EF residuals, and personal
models, are **bit-identical** to :func:`repro.fl.rounds.stream_fl_round`
for every count-streaming scheme (PRoBit+ / signSGD-MV / RSA), any edge
count (including ``E`` not dividing M), under participation sampling and
error feedback. Only the f32 *metric* sums (loss, delta mean) reassociate
non-integrally (~1e-6, the PR-3 precedent).

**Async edges (``edge_buffer > 0``).** Reuses the PR-3 buffered-async
semantics one level up: each edge's shipped (counts, mass) pair arrives
with probability ``1/(1 + CellParams.latency)`` into a bounded root
buffer (edge e writes slot ``e mod B``, later edges winning shared
slots), slots age when their edge misses a round, and the root merge
weights slot tensors ``(1 + age) ** (-CellParams.staleness_decay)``
(:func:`repro.core.staleness_weights`). The b-vote and metric heartbeat
stay synchronous, exactly as PR-3 keeps the loss vote and EF write-back
out of the client buffer. Degenerate parity: ``edge_buffer == tree_edges``
at zero latency and zero decay refreshes every slot every round with
weight exactly 1.0 — bit-identical to the unbuffered tree (asserted in
``tests/test_hierarchy.py``).

**Byzantine edges.** A new adversary class (Egger & Bitar, arxiv
2506.09870): the first ``FLConfig.byz_edges`` edges ship corrupted count
tensors (:data:`repro.core.attacks.EDGE_ATTACK_IDS` — per-plane
complement, count saturation, stale replay). The naive additive merge
inherits the full corruption; ``edge_merge="median"`` /
``edge_merge="trimmed"`` instead merge per-coordinate over the edges'
*vote rates* ``N_i / mass`` (median, or the mean of the
``edge_trim``-trimmed order statistics) and rescale by the total mass, so
the root estimate survives any minority of bad edges.

**Device mapping (``tree_shard``).** Edges map onto
:func:`repro.launch.mesh.make_campaign_mesh` devices via ``shard_map``:
device k runs its ``E / n_dev`` edge reductions over its client-data
block and returns the *stacked per-edge tensors* (``out_specs``
sharded over the edge axis) — no ``psum``; the root merge is a single
host-side tree-reduce over the gathered ``(E, 8 * p_bytes)`` stack. This
is the psum-free contrast to ``stream_shard``, whose carries collapse
inside the collective.

Memory: resident state is O(client_chunk * d/8) per edge scan plus
O(E * d/8) for the stacked edge tensors — still independent of M. The
round driver donates the carried round state
(``jax.jit(..., donate_argnums=...)`` in ``FLSimulation`` and the tree
benchmark), so per-round count/buffer planes reuse their buffers instead
of reallocating; ``tests/test_hierarchy.py`` pins peak RSS under the same
RLIMIT_AS harness as the flat streaming round.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import BState, staleness_weights
from ..core.attacks import apply_edge_attack, edge_attack_id
from ..core.bcontrol import update_b_from_vote
from .rounds import (
    CellParams,
    RoundContext,
    RoundState,
    _scan_chunks,
    init_state,
)

__all__ = [
    "EDGE_MERGES",
    "TreeRoundState",
    "edge_slices",
    "init_tree_state",
    "tree_fl_round",
    "tree_shard_devices",
]

# Root merge rules over the stacked (E, 8 * p_bytes) edge count tensors:
# "sum" is the exact additive protocol (bit-identical to flat at zero
# staleness); "median" / "trimmed" are the robust rate-space merges.
EDGE_MERGES: tuple[str, ...] = ("sum", "median", "trimmed")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TreeRoundState:
    """State of a *buffered-async* tree run (``edge_buffer > 0``).

    The first four fields mirror :class:`~repro.fl.rounds.RoundState`
    (drivers read ``w_global`` etc. off either); the buffer planes hold
    the root's bounded per-edge async buffer — shipped count tensors, not
    wire rows, which is what keeps the buffer O(B * d/8) however many
    clients sit behind each edge. Unbuffered trees (``edge_buffer == 0``)
    carry a plain ``RoundState``.
    """

    w_global: jax.Array  # (d,)
    w_locals: jax.Array  # (n_clients, d) personal models
    b: BState  # dynamic-b controller state
    residuals: jax.Array  # (n_clients, d) error-feedback residuals
    edge_counts: jax.Array  # (B, 8 * p_bytes) f32 buffered edge count tensors
    edge_mass: jax.Array  # (B,) f32 buffered active-mass scalars
    edge_age: jax.Array  # (B,) int32 rounds since the slot's edge delivered
    edge_valid: jax.Array  # (B,) bool slot holds a delivery


def edge_slices(n: int, n_edges: int) -> list[tuple[int, int]]:
    """Static ``(row0, n_e)`` cohort slices, one per edge, balanced.

    The first ``n mod E`` edges take ``ceil(n/E)`` rows, the rest
    ``floor(n/E)`` — every edge is non-empty for ``E <= n`` and the sizes
    are Python ints, so each edge's scan compiles with its true static
    length (no wrap padding that could alias another edge's clients).
    """
    q, r = divmod(n, n_edges)
    sizes = [q + 1] * r + [q] * (n_edges - r)
    out, row0 = [], 0
    for n_e in sizes:
        out.append((row0, n_e))
        row0 += n_e
    return out


def init_tree_state(ctx: RoundContext, b_init=None) -> TreeRoundState:
    """Fresh buffered-tree state: empty edge buffer, sync fields as usual."""
    cfg = ctx.cfg
    base = init_state(ctx, b_init)
    n_buf = cfg.edge_buffer
    p_bytes = ctx.pipeline.compressor.wire_bytes(ctx.d)
    return TreeRoundState(
        w_global=base.w_global,
        w_locals=base.w_locals,
        b=base.b,
        residuals=base.residuals,
        edge_counts=jnp.zeros((n_buf, 8 * p_bytes), jnp.float32),
        edge_mass=jnp.zeros((n_buf,), jnp.float32),
        edge_age=jnp.zeros((n_buf,), jnp.int32),
        edge_valid=jnp.zeros((n_buf,), bool),
    )


def tree_shard_devices(ctx: RoundContext) -> int:
    """How many devices the edge reductions spread over (1 = host loop)."""
    cfg = ctx.cfg
    if not cfg.tree_shard:
        return 1
    n_dev = len(jax.devices())
    if n_dev <= 1 or cfg.tree_edges % n_dev or cfg.n_active % cfg.tree_edges:
        return 1
    return n_dev


def _edge_carries(
    ctx: RoundContext,
    params: CellParams,
    kb: jax.Array,
    k_att: jax.Array,
    k_q: jax.Array,
    w_global: jax.Array,
    b_scalar: jax.Array,
    w_locals: jax.Array | None,
    residuals: jax.Array | None,
    sel: jax.Array,
    limit,
    n_byz: int,
) -> tuple[dict, jax.Array | None, jax.Array | None]:
    """Run every edge's chunked reduction; stack the shipped tensors.

    Each edge scans its static cohort slice with ``row0`` pinned to the
    slice start, so per-row PRNG / Byzantine membership / masks key by
    global cohort position exactly as in the flat scan. Stateful planes
    (w_locals / EF residuals) thread edge-to-edge — slices are disjoint,
    so the threading order is immaterial and each client row is written
    once with its flat-scan value. Returns the stacked carry dict
    (leading axis E) and the written-back planes.
    """
    stateless = ctx.cfg.stateless_clients
    outs = []
    for row0, n_e in edge_slices(ctx.cfg.n_active, ctx.cfg.tree_edges):
        carry = _scan_chunks(
            ctx, params, kb, k_att, k_q, w_global, b_scalar,
            w_locals, residuals, sel[row0:row0 + n_e],
            ctx.client_x, ctx.client_y, 0, row0, limit, n_byz, True,
        )
        if not stateless:
            w_locals = carry.pop("w_locals")
            residuals = carry.pop("residuals")
        outs.append(carry)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return stacked, w_locals, residuals


def _sharded_edges(
    ctx: RoundContext,
    params: CellParams,
    kb: jax.Array,
    k_att: jax.Array,
    k_q: jax.Array,
    w_global: jax.Array,
    b_scalar: jax.Array,
    limit,
    n_byz: int,
    n_dev: int,
) -> dict:
    """One edge reduction per device slice, psum-free.

    Device k owns edges ``[k * E/n_dev, (k+1) * E/n_dev)`` — contiguous
    equal slices (``tree_shard`` validation pins ``E | n_active`` and
    participation to 1.0), so its client-data block is exactly its edges'
    rows. ``out_specs`` shards the *edge axis*: the stacked per-edge
    tensors come back whole and the root merge happens outside the
    ``shard_map`` — no cross-device collective in the reduction at all,
    unlike ``stream_shard``'s psum.
    """
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import make_campaign_mesh

    cfg = ctx.cfg
    E = cfg.tree_edges
    n_e = cfg.n_active // E
    e_loc = E // n_dev
    mesh = make_campaign_mesh(n_dev)

    def body(cx, cy, kb_, ka_, kq_, wg, bs, lim, prm):
        k = jax.lax.axis_index("data")
        data_offset = k * (e_loc * n_e)
        outs = []
        for j in range(e_loc):
            row0 = (k * e_loc + j) * n_e
            sel_rows = row0 + jnp.arange(n_e)
            outs.append(
                _scan_chunks(
                    ctx, prm, kb_, ka_, kq_, wg, bs, None, None,
                    sel_rows, cx, cy, data_offset, row0, lim, n_byz, True,
                )
            )
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    in_specs = (P("data"), P("data")) + (P(),) * 7
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=P("data"))
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, check_vma=False, **kwargs)
    else:
        from jax.experimental.shard_map import shard_map

        fn = shard_map(body, check_rep=False, **kwargs)
    return fn(
        ctx.client_x, ctx.client_y, kb, k_att, k_q,
        w_global, b_scalar, jnp.asarray(limit, jnp.int32), params,
    )


def _root_merge(
    cfg, counts_e: jax.Array, mass_e: jax.Array, weights: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Merge the (E', 8 * p_bytes) edge tensors into root (counts, mass).

    ``sum`` is the exact additive protocol — optionally
    staleness-weighted (the buffered-async path), where weight 1.0 rows
    reduce bit-identically to the unweighted sum. The robust merges work
    in *rate* space (per-coordinate vote fraction ``N_i / mass``), where
    every honest edge estimates the same population quantity regardless
    of its slice size, then rescale the consensus rate by the total mass
    so the per-scheme ``finalize`` is unchanged downstream.
    """
    if cfg.edge_merge == "sum":
        if weights is not None:
            counts_e = counts_e * weights[:, None]
            mass_e = mass_e * weights
        return jnp.sum(counts_e, axis=0), jnp.sum(mass_e)
    # Robust merges see fresh tensors only (config validation keeps them
    # out of buffered trees); an all-zero-mass edge contributes rate 0 and
    # is trimmed like any outlier — E is small, so per-coordinate order
    # statistics over the edge axis are cheap.
    rates = counts_e / jnp.maximum(mass_e, 1.0)[:, None]
    if cfg.edge_merge == "median":
        rate = jnp.median(rates, axis=0)
    else:  # "trimmed"
        t = cfg.edge_trim
        rate = jnp.mean(jnp.sort(rates, axis=0)[t:rates.shape[0] - t], axis=0)
    mass = jnp.sum(mass_e)
    return rate * mass, mass


def tree_fl_round(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state,
    batches: dict,
) -> tuple[object, dict]:
    """One hierarchical FL round: edge reductions, root merge, b-control.

    Protocol-identical to :func:`repro.fl.rounds.stream_fl_round` on the
    client side (same participation sampling, RNG schedule, attack
    semantics); the server side replaces the single cohort scan with E
    per-slice scans and a root merge over their shipped count tensors —
    see the module docstring for the exactness / async / Byzantine
    semantics. Extra metrics beyond the flat round: ``edge_mass_min``
    (the lightest edge's shipped mass — load-balance health), and for
    buffered trees the PR-3 ``buf_fill`` / ``mean_age`` pair.
    """
    cfg = ctx.cfg
    n, E, B = cfg.n_active, cfg.tree_edges, cfg.edge_buffer
    d = ctx.d
    server = ctx.pipeline.server
    kb = batches["key"]

    if cfg.participation < 1.0:
        sel = jax.random.choice(
            jax.random.fold_in(key, 99), cfg.n_clients,
            (n,), replace=False,
        )
    else:
        sel = jnp.arange(cfg.n_clients)
    k_att, k_q = jax.random.split(jax.random.fold_in(key, 1))
    n_byz = int(n * cfg.byz_frac)
    limit = jnp.asarray(params.m_active) if ctx.masked else n

    stateless = cfg.stateless_clients
    n_dev = tree_shard_devices(ctx)
    if n_dev > 1:
        edges = _sharded_edges(
            ctx, params, kb, k_att, k_q, state.w_global, state.b.b,
            limit, n_byz, n_dev,
        )
        new_wl, new_res = state.w_locals, state.residuals
    else:
        edges, new_wl, new_res = _edge_carries(
            ctx, params, kb, k_att, k_q, state.w_global, state.b.b,
            None if stateless else state.w_locals,
            None if stateless else state.residuals,
            sel, limit, n_byz,
        )
        if stateless:
            new_wl, new_res = state.w_locals, state.residuals

    counts_f, mass_f = edges["acc"], edges["wsum"]  # (E, 8P), (E,)
    # Synchronous round heartbeat: the b-vote and metric sums ride the
    # upload wave outside the edge buffer (the PR-3 convention), honest
    # regardless of edge attacks (they forge the shipped count tensor).
    vote = jnp.sum(edges["vote"])
    loss_sum = jnp.sum(edges["loss"])
    dsum = jnp.sum(edges["dsum"], axis=0)
    wsum = jnp.sum(mass_f)

    if cfg.byz_edges:
        byz_mask = jnp.arange(E) < cfg.byz_edges
        if B:
            slot_of = jnp.arange(E) % B
            prev_c = state.edge_counts[slot_of]
            prev_m = state.edge_mass[slot_of]
            prev_v = state.edge_valid[slot_of]
        else:
            prev_c = jnp.zeros_like(counts_f)
            prev_m = jnp.zeros_like(mass_f)
            prev_v = jnp.zeros((E,), bool)
        counts_s, mass_s = apply_edge_attack(
            edge_attack_id(cfg.edge_attack),
            counts_f, mass_f, prev_c, prev_m, prev_v, byz_mask,
        )
    else:
        counts_s, mass_s = counts_f, mass_f

    if B:
        # PR-3 buffer semantics, one level up: edge e -> slot e mod B,
        # Bernoulli arrival, later edges win shared slots (unrolled
        # generations), misses age their slot.
        p_arrive = 1.0 / (1.0 + params.latency)
        u = jax.random.uniform(jax.random.fold_in(key, 7), (E,))
        delivered = u < p_arrive
        n_gen = -(-E // B)
        pad = n_gen * B - E
        c_p = jnp.pad(counts_s, ((0, pad), (0, 0)))
        m_p = jnp.pad(mass_s, (0, pad))
        del_p = jnp.pad(delivered, (0, pad))
        buf_c, buf_m = state.edge_counts, state.edge_mass
        hit = jnp.zeros((B,), bool)
        for g in range(n_gen):
            d_g = del_p[g * B:(g + 1) * B]
            buf_c = jnp.where(d_g[:, None], c_p[g * B:(g + 1) * B], buf_c)
            buf_m = jnp.where(d_g, m_p[g * B:(g + 1) * B], buf_m)
            hit = hit | d_g
        age = jnp.where(hit, 0, state.edge_age + 1)
        valid = state.edge_valid | hit
        weights = staleness_weights(age, params.staleness_decay, valid)
        counts_root, mass_root = _root_merge(cfg, buf_c, buf_m, weights)
    else:
        counts_root, mass_root = _root_merge(cfg, counts_s, mass_s, None)

    b_vec = ctx.pipeline.compressor.b_vector(d, state.b.b)
    est = server.finalize(counts_root, jnp.maximum(mass_root, 1e-12), b_vec)
    theta = jnp.where(mass_root > 0, est, 0.0)

    b_new = update_b_from_vote(state.b, vote, cfg.bctrl)
    if B:
        new_state = TreeRoundState(
            w_global=state.w_global + theta,
            w_locals=new_wl,
            b=b_new,
            residuals=new_res,
            edge_counts=buf_c,
            edge_mass=buf_m,
            edge_age=age,
            edge_valid=valid,
        )
    else:
        new_state = RoundState(
            w_global=state.w_global + theta,
            w_locals=new_wl,
            b=b_new,
            residuals=new_res,
        )
    m_eff = jnp.maximum(wsum, 1.0)
    delta_mean = dsum / m_eff
    metrics = {
        "loss": loss_sum / m_eff,
        "b": b_new.b,
        "theta_mse": jnp.mean((theta - delta_mean) ** 2),
        "edge_mass_min": jnp.min(mass_f),
    }
    if B:
        n_valid = jnp.sum(valid.astype(jnp.float32))
        metrics["buf_fill"] = n_valid / B
        metrics["mean_age"] = jnp.sum(
            age.astype(jnp.float32) * valid
        ) / jnp.maximum(n_valid, 1.0)
    return new_state, metrics
