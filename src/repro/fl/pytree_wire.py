"""Packed one-bit wire over a real model parameter pytree (per-layer).

This is the bridge between the flat-vector FL engine (``fl/rounds.py``
operates on raveled ``(M, d)`` cohorts) and the model zoo: it runs the
full ``ClientCompressor``/``ServerAggregator`` protocol — EF residual add
-> top-k -> Eq.-5 stochastic binarize -> uint8 bit-pack -> count
accumulate -> Eq.-13 ML estimate — **per parameter leaf** over a real
pytree, so a transformer fine-tunes through exactly the wire the paper
analyzes.

Wire format (what travels, per layer)
-------------------------------------
Each leaf ``l`` (``jax.tree_util.tree_flatten`` order) is flattened to
``(M, d_l)`` and compressed independently into the canonical
:class:`~repro.core.aggregation.PackedWire`: an
``(M, wire_bits * padded_dim(d_l)/8)`` uint8 matrix of LSB-first packed
codes (``wire_bits`` plane-major one-bit planes; 1 at the paper's wire)
plus the public range vector ``b`` — ``wire_bits`` bits per parameter per
client on the uplink (the top-k variant ships a
:class:`~repro.core.aggregation.SparseWire` of per-client index sets +
packed codes instead). Leaves are never concatenated: resident memory is
O(M * d_l / 8) per layer for the one-shot path and O(C * d_l / 8) for the
client-streamed path; the dense concatenated code tensor (or even a dense
concatenated f32 delta) never materializes.

Key schedule (why chunked == dense, per layer and across layers)
----------------------------------------------------------------
Leaf ``l`` uses quantizer key ``fold_in(round_key, l)``
(:func:`leaf_key`); inside a leaf the compressor applies the existing
counter-derived schedule — client at cohort position ``g`` draws chunk
``j`` uniforms from ``fold_in(fold_in(leaf_key, g), j)``. Under
``jax_threefry_partitionable`` the draws depend only on ``(l, g, j)``,
so any client-chunking (via ``row_offset``), any per-layer processing
order, and a flatten-per-leaf dense reference all produce bit-identical
wires — including leaves with ``size % 8 != 0``, whose pad coordinates
carry deterministic 0 bits that :meth:`ServerAggregator.finalize` slices
off.

State (where EF / top-k live)
-----------------------------
:class:`PytreeWireState` is a per-parameter optimizer-state pytree, like
an Adam moment: ``residuals`` holds one ``(M, *leaf_shape)`` f32 buffer
per parameter (the error-feedback carry; zeros and pass-through when EF
is off). Top-k selection masks are per-round (the ``SparseWire.indices``
of each leaf), not persistent — only the unsent mass persists, inside
the same residual buffer.

Count-dtype policy
------------------
Vote counts accumulate in **int32** (``ServerAggregator.init_counts``;
f32 when per-row weights fold in) — exact for any cohort below 2**31
clients. The uint8 claim applies to the packed *wire rows only*; an
accumulator in uint8 would silently wrap mod 256 past 255 clients.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.aggregation import AggregatorPipeline, Wire
from ..core.quantizer import wire_bytes as _wire_row_bytes

__all__ = [
    "PytreeWireState",
    "leaf_key",
    "init_wire_state",
    "pytree_wire_bytes",
    "compress_pytree",
    "aggregate_pytree",
    "stream_aggregate_pytree",
]


def leaf_key(key: jax.Array, leaf_index: int) -> jax.Array:
    """Quantizer key of parameter leaf ``leaf_index`` (tree_flatten order).

    The one extra fold level on top of the flat-vector schedule: every
    path that compresses leaf ``l`` — one-shot, client-streamed, the mesh
    step in ``launch/fl_step.py``, or a dense per-leaf reference — derives
    its per-client keys from this, which is what makes them all emit the
    same bits.
    """
    return jax.random.fold_in(key, leaf_index)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PytreeWireState:
    """Per-parameter compressor state (the EF 'optimizer buffer' pytree)."""

    residuals: Any  # pytree matching params, leaves (M, *leaf_shape) f32


def init_wire_state(params: Any, m: int) -> PytreeWireState:
    """Zero EF residuals for an ``m``-client cohort over ``params``."""
    res = jax.tree.map(
        lambda w: jnp.zeros((m,) + w.shape, jnp.float32), params
    )
    return PytreeWireState(residuals=res)


def pytree_wire_bytes(
    pipeline: AggregatorPipeline, params: Any, m: int
) -> dict[str, int]:
    """Uplink bytes for an ``m``-client round over ``params``, per format.

    ``wire_bytes`` is what actually travels (packed rows include the
    chunk/lane padding the compressor emits); ``wire_bytes_ideal`` is the
    unpadded ``ceil(d_l/8)`` floor; ``int8``/``f32`` are the quantized- and
    full-precision baselines the 8x/32x savings compare against. Dense
    (FedAvg) pipelines ship f32 for every leaf.
    """
    comp = pipeline.compressor
    bits = getattr(comp, "wire_bits", 1)
    packed = ideal = dim = 0
    for leaf in jax.tree.leaves(params):
        d = int(leaf.size)
        wb = comp.wire_bytes(d)
        if comp.mode != "dense" and comp.topk_frac < 1.0:
            # int32 indices + packed codes; no padding on the sparse wire
            sparse = _wire_row_bytes(d, bits, topk_frac=comp.topk_frac)
            packed += sparse
            ideal += sparse
        else:
            packed += wb if wb is not None else 4 * d
            ideal += _wire_row_bytes(d, bits) if wb is not None else 4 * d
        dim += d
    return {
        "wire_bytes": m * packed,
        "wire_bytes_ideal": m * ideal,
        "wire_bytes_int8": m * dim,
        "wire_bytes_f32": m * 4 * dim,
    }


def compress_pytree(
    pipeline: AggregatorPipeline,
    key: jax.Array,
    deltas: Any,
    b_scalar: jax.Array,
    state: PytreeWireState,
    *,
    row_offset: jax.Array | int = 0,
) -> tuple[list[Wire], PytreeWireState]:
    """Client half per leaf: ``(M, *shape)`` deltas -> one wire per leaf.

    Returns the wires in tree_flatten order plus the advanced EF state.
    ``row_offset`` rebases cohort positions exactly as in
    :meth:`ClientCompressor.compress` — a chunk of clients compressed at
    offset ``g0`` emits the bits rows ``[g0, g0+M)`` of a one-shot
    compress would.
    """
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    res_leaves = jax.tree.leaves(state.residuals)
    m = leaves[0].shape[0]
    wires, new_res = [], []
    for i, (dl, rl) in enumerate(zip(leaves, res_leaves)):
        d = int(dl[0].size)
        wire, r_new = pipeline.compressor.compress(
            leaf_key(key, i),
            dl.reshape(m, d).astype(jnp.float32),
            b_scalar,
            rl.reshape(m, d).astype(jnp.float32),
            row_offset=row_offset,
        )
        wires.append(wire)
        new_res.append(jnp.reshape(r_new, rl.shape))
    return wires, PytreeWireState(
        residuals=jax.tree_util.tree_unflatten(treedef, new_res)
    )


def aggregate_pytree(
    pipeline: AggregatorPipeline,
    key: jax.Array,
    deltas: Any,
    b_scalar: jax.Array,
    state: PytreeWireState,
    *,
    weights: jax.Array | None = None,
) -> tuple[Any, PytreeWireState]:
    """One-shot round over a pytree: compress every leaf, estimate theta.

    Returns ``(theta_tree, state')`` with theta leaves shaped like the
    parameters. ``weights`` (one per client) selects the weighted count
    path of the server — staleness discounts or active-client masks.
    """
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    wires, new_state = compress_pytree(pipeline, key, deltas, b_scalar, state)
    thetas = [
        jnp.reshape(pipeline.estimate(w, weights), dl.shape[1:])
        for w, dl in zip(wires, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, thetas), new_state


def stream_aggregate_pytree(
    pipeline: AggregatorPipeline,
    key: jax.Array,
    deltas: Any,
    b_scalar: jax.Array,
    state: PytreeWireState,
    *,
    client_chunk: int,
) -> tuple[Any, PytreeWireState]:
    """Client-streamed round: scan the cohort in chunks, per leaf.

    Counts are additive over clients, so each leaf folds its cohort
    through ``init_counts -> accumulate_counts -> finalize`` under
    ``lax.scan`` with O(client_chunk * d_l / 8) resident wire — and the
    ``row_offset`` key rebasing makes the result **bit-identical** to
    :func:`aggregate_pytree` for every count-streaming scheme (PRoBit+ /
    signSGD-MV / RSA): integer count addition is associative and the
    draws depend only on absolute cohort position. EF residuals advance
    chunk by chunk (rows are independent, so streamed EF equals dense EF
    exactly). Top-k sparse wires do not count-stream; use
    :func:`aggregate_pytree`.
    """
    comp, server = pipeline.compressor, pipeline.server
    if server.stream_kind != "counts":
        raise ValueError(
            f"{type(server).__name__} (stream_kind={server.stream_kind!r}) "
            "cannot client-stream; use aggregate_pytree"
        )
    if comp.topk_frac < 1.0:
        raise ValueError("top-k sparse wires cannot count-stream")
    if getattr(comp, "client_bits", None) is not None:
        raise ValueError(
            "per-client bit-widths emit a per-group HeteroWire and cannot "
            "fold through the flat count accumulator; use aggregate_pytree"
        )
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    res_leaves = jax.tree.leaves(state.residuals)
    m = leaves[0].shape[0]
    if m % client_chunk:
        raise ValueError(
            f"cohort size {m} not divisible by client_chunk {client_chunk}"
        )
    thetas, new_res = [], []
    for i, (dl, rl) in enumerate(zip(leaves, res_leaves)):
        d = int(dl[0].size)
        d2 = dl.reshape(m, d).astype(jnp.float32)
        r2 = rl.reshape(m, d).astype(jnp.float32)
        lk = leaf_key(key, i)
        p_bytes = comp.wire_bytes(d)
        b_vec = comp.b_vector(d, b_scalar)

        def chunk_step(carry, g, d2=d2, lk=lk):
            counts, res_buf = carry
            g0 = g * client_chunk
            dch = jax.lax.dynamic_slice_in_dim(d2, g0, client_chunk, axis=0)
            rch = jax.lax.dynamic_slice_in_dim(
                res_buf, g0, client_chunk, axis=0
            )
            wire, r_new = comp.compress(lk, dch, b_scalar, rch, row_offset=g0)
            counts = server.accumulate_counts(counts, wire.packed)
            res_buf = jax.lax.dynamic_update_slice_in_dim(
                res_buf, r_new, g0, axis=0
            )
            return (counts, res_buf), jnp.zeros(())

        (counts, r_fin), _ = jax.lax.scan(
            chunk_step,
            (server.init_counts(p_bytes), r2),
            jnp.arange(m // client_chunk),
        )
        thetas.append(jnp.reshape(server.finalize(counts, m, b_vec), dl.shape[1:]))
        new_res.append(jnp.reshape(r_fin, rl.shape))
    return (
        jax.tree_util.tree_unflatten(treedef, thetas),
        PytreeWireState(residuals=jax.tree_util.tree_unflatten(treedef, new_res)),
    )
