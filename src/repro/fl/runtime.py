"""FL simulation runtime — paper Algorithm 1 end-to-end.

One round (jit-compiled, clients vmapped):
  1. every client trains its *personal* model from its previous local
     parameters, prox-regularized toward the current global model (Eq. 4);
  2. model differences ``delta^m = w_local^m - w_global`` are formed;
  3. Byzantine clients replace their delta per the configured attack
     (delta-level attacks from :data:`repro.core.ATTACKS`; the ``bit_flip``
     wire adversary instead inverts post-quantization codes inside the
     pipeline);
  4. the configured :class:`repro.core.AggregatorPipeline` (resolved once
     from the registry — no aggregator branching here) compresses the
     updates onto the packed one-bit wire and estimates theta_hat —
     PRoBit+ quantizes with the dynamic/fixed/oracle ``b`` (+ DP margin)
     and ML-estimates (Eq. 13); baselines: FedAvg / Fed-GM / signSGD-MV /
     RSA ride the same registry;
  5. the global model steps by ``theta_hat``; the dynamic-b controller
     majority-votes the clients' one-bit loss signals (§VI-B).

The round itself lives in :mod:`repro.fl.rounds` as a pure
``RoundState -> RoundState`` function; :class:`FLSimulation` is the thin
stateful driver (host loop + periodic eval) kept for the original
experiment API. Whole scenario *grids* — many (aggregator, attack,
byz_frac, M, seed) cells at once — run through the vmapped campaign
engine in :mod:`repro.sim` instead.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import numpy as np

from ..core import (
    ACCOUNTANTS,
    BControlConfig,
    DPConfig,
    PrivacyLedger,
    available_aggregators,
    build_pipeline,
    is_timing_attack,
    parse_attack,
)
from . import rounds as _rounds

_B_MODES = ("dynamic", "fixed", "oracle")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 20
    byz_frac: float = 0.0
    attack: str = "none"
    aggregator: str = "probit_plus"  # | fedavg | fed_gm | signsgd_mv | rsa
    rounds: int = 30
    local_epochs: int = 5
    batch_size: int = 10
    lr: float = 0.01
    momentum: float = 0.5
    lam: float = 0.2
    dp_epsilon: float = 0.0  # 0 disables DP
    l1_sensitivity: float = 2e-4  # paper: 0.02 * lr
    b_mode: str = "dynamic"  # dynamic | fixed | oracle
    b_init: float = 0.01
    # BEYOND-PAPER: error feedback — each client carries the quantization
    # residual e_m into the next round (delta_eff = delta + e_m;
    # e_m' = delta_eff - b*c_m). Classical EF for 1-bit compressors;
    # the paper does not use it. DP note: EF reuses the residual across
    # rounds, so the per-round (eps,0) guarantee composes differently —
    # we therefore disable EF when dp_epsilon > 0.
    error_feedback: bool = False
    # BEYOND-PAPER: top-k sparse PRoBit+ (the paper's stated future work).
    # Fraction of coordinates each client uploads (1.0 = dense Eq. 5/13).
    # Refused under DP: the data-dependent index set breaks (eps,0)-DP
    # (see core/sparse.py).
    topk_frac: float = 1.0
    # Partial participation: fraction of clients sampled per round
    # (cross-device FL standard; M in Eq. 13 becomes the sampled count).
    # The mechanism keeps Theorem 3's per-round eps; what *tightens* under
    # participation < 1 is the reported budget, via the ledger's
    # amplification-by-subsampling accountant (see dp_accountant).
    participation: float = 1.0
    # DP accountant for the run's PrivacyLedger: "subsampled" (default —
    # per-round eps amplified by the sampling rate q = m/M before basic
    # composition; q = 1 is bit-identical to "basic"), "basic"
    # (conservative sum), "advanced" (DRV strong composition at
    # delta_slack = 1e-5), or "renyi" (exact randomized-response RDP
    # composed in the Rényi domain, converted at delta_slack — dominates
    # both basic and advanced on every trajectory). Host-side bookkeeping
    # only — never traced.
    dp_accountant: str = "subsampled"
    # BEYOND-PAPER: buffered-asynchronous rounds (the ROADMAP's
    # async/straggler item). 0 = the paper's synchronous protocol; B > 0
    # keeps a B-slot server buffer of the last-arrived packed uploads and
    # estimates from it with age-weighted vote counts (see
    # repro.fl.rounds.async_fl_round for the exact assumptions relaxed).
    async_buffer: int = 0
    # Mean upload latency in rounds; per-round arrival probability is
    # 1/(1 + async_latency). Traced (vmappable campaign axis).
    async_latency: float = 0.0
    # Staleness discount exponent: a buffered upload of age a is weighted
    # (1 + a)^(-staleness_decay) in the vote counts. 0 = uniform weights.
    staleness_decay: float = 0.0
    agg_step: float = 0.01  # server step for signSGD-MV / RSA
    gm_iters: int = 16
    use_kernels: bool = False
    # Streaming client axis (ROADMAP item). 0 = dense round (the whole
    # (M, d) update matrix and (M, d_pad/8) wire materialize at once);
    # C > 0 scans the cohort in chunks of C clients, accumulating packed
    # vote counts — resident memory drops from O(M * d/8) to O(C * d/8).
    # Per-client PRNG is counter-derived, so for count-streaming schemes
    # the chunked round is bit-identical to the dense one in eager mode
    # (<= 1e-6 under jit, the PR-3 reassociation precedent).
    client_chunk: int = 0
    # With client_chunk > 0: drop per-client persistent state (w_locals /
    # residuals collapse to a single broadcast row). Clients train from
    # w_global each round — the cross-device regime where M is far larger
    # than any per-client state the server could hold. Required for
    # stream_shard and for M beyond host memory.
    stateless_clients: bool = False
    # Packer d-chunk override (0 = quantizer.PACK_CHUNK). The streaming
    # benchmark shrinks it so the per-chunk scratch stays cache-sized.
    pack_chunk: int = 0
    # Shard the client axis of each chunk scan across the campaign mesh
    # (launch/mesh.make_campaign_mesh) via the weighted-count reduction.
    stream_shard: bool = False
    # Wire width k in {1, 2, 4} bits/parameter (probit_plus only). 1 is
    # the paper's one-bit wire, bit-exact with pre-k-bit history; k > 1
    # stochastically quantizes onto the uniform 2**k-level grid and, under
    # DP, mixes in L-level randomized response (core.privacy.rr_gamma) so
    # the per-round (eps, 0) guarantee — and all four accountants —
    # compose unchanged.
    wire_bits: int = 1
    # BEYOND-PAPER: HeteroSAg-style per-client bit-widths — one entry per
    # cohort row, each in {1, 2, 4}. Overrides wire_bits; the server
    # aggregates per equal-bits group and MLE-merges. Restricted to the
    # dense synchronous probit_plus wire (no kernels / top-k / streaming /
    # async).
    client_bits: tuple | None = None
    # Hierarchical count-tree aggregation (fl/hierarchy.py, ROADMAP's
    # serving-scale item). 0 = flat aggregation; E > 0 splits the cohort
    # into E contiguous edge slices, each running the chunked count scan
    # (requires client_chunk > 0 and a count-streaming aggregator) and
    # shipping one count tensor + active-mass scalar to the root. Zero
    # staleness is bit-exact with the flat streaming round.
    tree_edges: int = 0
    # Bounded per-edge async buffer at the root (PR-3 semantics one level
    # up): 0 = synchronous tree; B > 0 buffers edge deliveries (edge e ->
    # slot e mod B) with Bernoulli(1/(1+async_latency)) arrivals and
    # (1+age)^(-staleness_decay) root merge weights.
    edge_buffer: int = 0
    # Map edge reductions onto make_campaign_mesh devices (one device per
    # E/n_dev edge group, psum-free root merge over the gathered edge
    # tensors). Mirrors stream_shard's requirements: stateless clients,
    # full participation, and E must divide n_active.
    tree_shard: bool = False
    # Byzantine *edge aggregators* (Egger & Bitar, arxiv 2506.09870): the
    # first byz_edges edges ship count tensors corrupted per edge_attack
    # (core.attacks.EDGE_ATTACK_IDS: edge_sign_flip / edge_inflate /
    # edge_replay).
    byz_edges: int = 0
    edge_attack: str = "none"
    # Root merge rule over the stacked edge count tensors: "sum" (exact
    # additive protocol), "median" / "trimmed" (robust per-coordinate
    # rate-space merges surviving a minority of Byzantine edges;
    # edge_trim edges are cut from each end of the order statistics).
    edge_merge: str = "sum"
    edge_trim: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.aggregator not in available_aggregators():
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; "
                f"available: {available_aggregators()}"
            )
        parse_attack(self.attack)  # raises ValueError on unknown names
        if self.dp_accountant not in ACCOUNTANTS:
            raise ValueError(
                f"unknown dp_accountant {self.dp_accountant!r}; "
                f"available: {ACCOUNTANTS}"
            )
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}"
            )
        if self.b_mode not in _B_MODES:
            raise ValueError(
                f"unknown b_mode {self.b_mode!r}; available: {_B_MODES}"
            )
        if self.topk_frac < 1.0 and self.dp_epsilon > 0:
            raise ValueError(
                "topk_frac < 1 releases a data-dependent index set and "
                "breaks the (eps,0)-DP guarantee; use dense PRoBit+ with DP."
            )
        if self.async_buffer < 0:
            raise ValueError(f"async_buffer must be >= 0, got {self.async_buffer}")
        if self.async_latency < 0:
            raise ValueError(f"async_latency must be >= 0, got {self.async_latency}")
        if self.staleness_decay < 0:
            raise ValueError(
                f"staleness_decay must be >= 0 (weights must be monotone "
                f"non-increasing in age), got {self.staleness_decay}"
            )
        if not self.async_buffer:
            if (self.async_latency > 0 or self.staleness_decay > 0) and (
                not self.edge_buffer
            ):
                raise ValueError(
                    "async_latency/staleness_decay require buffered-async "
                    "rounds (set async_buffer > 0 for client rounds or "
                    "edge_buffer > 0 for a buffered-async tree root)"
                )
            if is_timing_attack(self.attack):
                raise ValueError(
                    f"timing attack {self.attack!r} needs asynchronous rounds "
                    "(set async_buffer > 0); synchronous rounds have no "
                    "arrival schedule to attack"
                )
        else:
            if self.participation < 1.0:
                raise ValueError(
                    "async rounds require participation == 1.0: buffer "
                    "slots, staleness ages, and the straggler gate are keyed "
                    "to client identity, which a per-round resampled cohort "
                    "breaks. Model partial availability with async_latency "
                    "instead (a client arriving with probability "
                    "1/(1+latency) subsumes sampling)."
                )
            if self.topk_frac < 1.0:
                raise ValueError(
                    "async rounds buffer dense packed wires; topk_frac < 1 "
                    "(SparseWire) cannot be staleness-buffered"
                )
            if self.async_buffer > self.n_active:
                raise ValueError(
                    f"async_buffer={self.async_buffer} exceeds the cohort "
                    f"({self.n_active} clients); slots beyond one per client "
                    "would never be written"
                )
        if self.client_chunk < 0:
            raise ValueError(f"client_chunk must be >= 0, got {self.client_chunk}")
        if self.pack_chunk < 0 or self.pack_chunk % 8:
            raise ValueError(
                f"pack_chunk must be a non-negative multiple of 8, "
                f"got {self.pack_chunk}"
            )
        if self.client_chunk:
            if self.async_buffer:
                raise ValueError(
                    "client_chunk streams the synchronous round; the "
                    "buffered-async server holds a persistent wire buffer "
                    "and cannot stream (set async_buffer=0)"
                )
            if self.topk_frac < 1.0:
                raise ValueError(
                    "client_chunk requires the dense packed wire; "
                    "topk_frac < 1 (SparseWire) has no count accumulator"
                )
            if self.b_mode == "oracle":
                raise ValueError(
                    "b_mode='oracle' maxes |delta| over the full cohort and "
                    "cannot stream; use 'dynamic' or 'fixed' with client_chunk"
                )
            if self.byz_frac > 0:
                from ..core.attacks import STREAM_ATTACKS

                payload, _ = parse_attack(self.attack)
                if payload not in STREAM_ATTACKS:
                    raise ValueError(
                        f"attack {self.attack!r} colludes across the cohort "
                        f"and cannot run under a client-chunk scan; "
                        f"streamable attacks: {tuple(sorted(STREAM_ATTACKS))}"
                    )
        if self.stateless_clients:
            if not self.client_chunk:
                raise ValueError("stateless_clients requires client_chunk > 0")
            if self.error_feedback:
                raise ValueError(
                    "error feedback carries a per-client residual across "
                    "rounds and contradicts stateless_clients"
                )
        from ..core.quantizer import WIRE_BITS

        if self.wire_bits not in WIRE_BITS:
            raise ValueError(
                f"wire_bits must be one of {WIRE_BITS}, got {self.wire_bits}"
            )
        if self.wire_bits != 1:
            if self.aggregator != "probit_plus":
                raise ValueError(
                    f"wire_bits={self.wire_bits} is only supported by the "
                    f"probit_plus wire, not {self.aggregator!r} (the k-bit "
                    "level protocol is PRoBit+'s count/MLE machinery)"
                )
            if self.topk_frac < 1.0:
                raise ValueError(
                    "wire_bits > 1 is not supported on the top-k wire "
                    "(SparseWire packs one bit per surviving coordinate); "
                    "set topk_frac=1.0"
                )
        if self.client_bits is not None:
            object.__setattr__(
                self, "client_bits", tuple(int(k) for k in self.client_bits)
            )
            for k in self.client_bits:
                if k not in WIRE_BITS:
                    raise ValueError(
                        f"client_bits entries must be in {WIRE_BITS}, got {k}"
                    )
            if self.aggregator != "probit_plus":
                raise ValueError(
                    "per-client bit-widths (client_bits) are only supported "
                    f"by probit_plus, not {self.aggregator!r}"
                )
            if len(self.client_bits) != self.n_active:
                raise ValueError(
                    f"client_bits needs one entry per cohort row: got "
                    f"{len(self.client_bits)} for a {self.n_active}-client "
                    "cohort"
                )
            if self.use_kernels:
                raise ValueError(
                    "client_bits is not supported on the kernel wire yet; "
                    "unset use_kernels (homogeneous wire_bits works with "
                    "kernels)"
                )
            if self.topk_frac < 1.0:
                raise ValueError(
                    "client_bits is not supported on the top-k wire; "
                    "set topk_frac=1.0"
                )
            if self.client_chunk or self.stream_shard:
                raise ValueError(
                    "client_bits emits a per-group HeteroWire and cannot "
                    "stream through the flat count accumulator; unset "
                    "client_chunk/stream_shard"
                )
            if self.async_buffer:
                raise ValueError(
                    "client_bits rows have heterogeneous wire widths and "
                    "cannot share the fixed-width async buffer; set "
                    "async_buffer=0"
                )
            if self.byz_frac > 0:
                from ..core import is_wire_attack

                if is_wire_attack(self.attack):
                    raise ValueError(
                        f"wire attack {self.attack!r} is not supported on "
                        "the heterogeneous wire yet; use a delta-level "
                        "attack or homogeneous wire_bits"
                    )
        if self.stream_shard:
            if not self.client_chunk:
                raise ValueError("stream_shard requires client_chunk > 0")
            if not self.stateless_clients:
                raise ValueError(
                    "stream_shard requires stateless_clients: scattering "
                    "per-client state back from device-local chunk rows "
                    "is not supported"
                )
            if self.participation < 1.0:
                raise ValueError(
                    "stream_shard requires participation == 1.0 (the static "
                    "client-data shard layout cannot follow a resampled "
                    "cohort)"
                )
            if self.aggregator == "fed_gm":
                raise ValueError(
                    "fed_gm buffers all rows (stream_kind='buffer') and "
                    "cannot reduce across shards; pick a count- or "
                    "sum-streaming aggregator"
                )
        if self.tree_edges < 0:
            raise ValueError(f"tree_edges must be >= 0, got {self.tree_edges}")
        if self.edge_buffer < 0:
            raise ValueError(f"edge_buffer must be >= 0, got {self.edge_buffer}")
        if not self.tree_edges:
            tree_only = {
                "edge_buffer": (self.edge_buffer, 0),
                "tree_shard": (self.tree_shard, False),
                "byz_edges": (self.byz_edges, 0),
                "edge_attack": (self.edge_attack, "none"),
                "edge_merge": (self.edge_merge, "sum"),
                "edge_trim": (self.edge_trim, 0),
            }
            for name, (val, default) in tree_only.items():
                if val != default:
                    raise ValueError(
                        f"{name}={val!r} requires a hierarchical tree round "
                        "(set tree_edges > 0)"
                    )
        else:
            from ..core.attacks import EDGE_ATTACK_IDS

            _COUNT_STREAM_AGGREGATORS = ("probit_plus", "signsgd_mv", "rsa")
            if self.aggregator not in _COUNT_STREAM_AGGREGATORS:
                raise ValueError(
                    f"tree_edges requires a count-streaming aggregator "
                    f"(edges ship additive count tensors); "
                    f"{self.aggregator!r} is not in "
                    f"{_COUNT_STREAM_AGGREGATORS}"
                )
            if not self.client_chunk:
                raise ValueError(
                    "tree_edges requires client_chunk > 0: each edge runs "
                    "the chunked count-accumulation scan over its slice"
                )
            if self.tree_edges > self.n_active:
                raise ValueError(
                    f"tree_edges={self.tree_edges} exceeds the cohort "
                    f"({self.n_active} clients); an edge needs at least "
                    "one client"
                )
            if self.async_buffer:
                raise ValueError(
                    "tree_edges and async_buffer are exclusive: the tree "
                    "buffers *edge count tensors* at the root "
                    "(edge_buffer), not client wire rows"
                )
            if self.stream_shard:
                raise ValueError(
                    "tree_edges shards by edge (tree_shard), not by the "
                    "flat client axis; unset stream_shard"
                )
            if self.edge_buffer > self.tree_edges:
                raise ValueError(
                    f"edge_buffer={self.edge_buffer} exceeds tree_edges="
                    f"{self.tree_edges}; slots beyond one per edge would "
                    "never be written"
                )
            if self.edge_attack not in EDGE_ATTACK_IDS:
                raise ValueError(
                    f"unknown edge_attack {self.edge_attack!r}; "
                    f"available: {EDGE_ATTACK_IDS}"
                )
            if not 0 <= self.byz_edges <= self.tree_edges:
                raise ValueError(
                    f"byz_edges must be in [0, tree_edges], got "
                    f"{self.byz_edges} with tree_edges={self.tree_edges}"
                )
            if self.byz_edges and self.edge_attack == "none":
                raise ValueError(
                    "byz_edges > 0 needs an edge_attack from "
                    f"{EDGE_ATTACK_IDS[1:]}"
                )
            if self.edge_attack == "edge_replay" and not self.edge_buffer:
                raise ValueError(
                    "edge_replay re-ships the root's buffered slot content "
                    "and needs a buffered tree (set edge_buffer > 0)"
                )
            from .hierarchy import EDGE_MERGES

            if self.edge_merge not in EDGE_MERGES:
                raise ValueError(
                    f"unknown edge_merge {self.edge_merge!r}; "
                    f"available: {EDGE_MERGES}"
                )
            if self.edge_merge != "sum" and self.edge_buffer:
                raise ValueError(
                    "robust edge merges (median/trimmed) operate on fresh "
                    "edge tensors; staleness-weighted robust merging is "
                    "not supported (set edge_buffer=0)"
                )
            if self.edge_trim and self.edge_merge != "trimmed":
                raise ValueError(
                    "edge_trim only applies to edge_merge='trimmed'"
                )
            if self.edge_merge == "trimmed" and (
                2 * self.edge_trim >= self.tree_edges
            ):
                raise ValueError(
                    f"edge_trim={self.edge_trim} trims away all "
                    f"{self.tree_edges} edges (need 2*edge_trim < tree_edges)"
                )
            if self.tree_shard:
                if not self.stateless_clients:
                    raise ValueError(
                        "tree_shard requires stateless_clients: scattering "
                        "per-client state back from device-local edge "
                        "slices is not supported"
                    )
                if self.participation < 1.0:
                    raise ValueError(
                        "tree_shard requires participation == 1.0 (the "
                        "static client-data shard layout cannot follow a "
                        "resampled cohort)"
                    )
                if self.n_active % self.tree_edges:
                    raise ValueError(
                        f"tree_shard needs equal edge slices: tree_edges="
                        f"{self.tree_edges} does not divide the "
                        f"{self.n_active}-client cohort"
                    )

    @property
    def n_active(self) -> int:
        return max(int(self.n_clients * self.participation), 1)

    @property
    def n_byz(self) -> int:
        return int(self.n_clients * self.byz_frac)

    @property
    def dp(self) -> DPConfig:
        return DPConfig(self.dp_epsilon, self.l1_sensitivity)

    @property
    def sampling_rate(self) -> float:
        """Effective per-round client sampling rate ``q = m_sampled / M``.

        Derived from the *actual* cohort size (``n_active``, which floors
        and clamps), not the raw ``participation`` fraction — the
        amplification bound needs the realized inclusion probability.
        Full participation is exactly 1.0.
        """
        if self.participation >= 1.0:
            return 1.0
        return self.n_active / self.n_clients

    def ledger(self) -> PrivacyLedger:
        """A fresh :class:`~repro.core.PrivacyLedger` for one run of this
        config: per-round eps from Theorem 3's ``dp_epsilon``, sampling
        rate from the realized cohort, accountant per ``dp_accountant``."""
        return PrivacyLedger(
            eps_per_round=self.dp_epsilon,
            q=self.sampling_rate,
            accountant=self.dp_accountant,
        )

    @property
    def bctrl(self) -> BControlConfig:
        return BControlConfig(self.b_mode, self.b_init)

    def pipeline(self):
        """The shared :class:`repro.core.AggregatorPipeline` for this run."""
        from ..core.quantizer import PACK_CHUNK

        return build_pipeline(
            self.aggregator,
            dp=self.dp,
            b_mode=self.b_mode,
            error_feedback=self.error_feedback,
            topk_frac=self.topk_frac,
            agg_step=self.agg_step,
            gm_iters=self.gm_iters,
            use_kernels=self.use_kernels,
            chunk=self.pack_chunk or PACK_CHUNK,
            wire_bits=self.wire_bits,
            client_bits=self.client_bits,
        )


class FLSimulation:
    """Simulation-mode FL (CPU): the paper-faithful experiment harness.

    A thin stateful wrapper over the pure round core in
    :mod:`repro.fl.rounds` — it owns a :class:`~repro.fl.rounds.RoundState`
    and drives one jitted round per loop iteration, evaluating on the host
    every ``eval_every`` rounds. The per-round math, RNG schedule, and
    therefore the trajectories are identical to the campaign engine's
    scanned execution of the same config.
    """

    def __init__(
        self,
        cfg: FLConfig,
        init_params,
        loss_fn: Callable,  # loss_fn(params_pytree, {"x","y"}) -> scalar
        acc_fn: Callable,
        client_x: np.ndarray,  # (M, per_client, ...)
        client_y: np.ndarray,  # (M, per_client)
        test: dict,
    ):
        self.cfg = cfg
        self.ctx = _rounds.make_context(
            cfg, init_params, loss_fn, acc_fn, client_x, client_y, test
        )
        self.state = _rounds.init_run_state(self.ctx)
        self._params = _rounds.cell_params(cfg)
        # The carried round state is donated: each round's count/buffer
        # planes reuse the previous round's buffers instead of
        # reallocating (the driver below never re-reads the old state).
        # Callers must snapshot arrays (np.asarray) before run(), not hold
        # live references across it.
        self._round = jax.jit(
            functools.partial(_rounds.round_fn(self.ctx), self.ctx, self._params),
            donate_argnums=(1,),
        )
        self.history: list[dict] = []
        # One DP event is recorded per executed round; eps_spent in the
        # history is the cumulative budget under cfg.dp_accountant.
        self.ledger = cfg.ledger()

    # State views (the arrays live in self.state; these keep the original
    # attribute API used by tests and examples).
    @property
    def w_global(self):
        return self.state.w_global

    @property
    def w_locals(self):
        return self.state.w_locals

    @property
    def b_state(self):
        return self.state.b

    @property
    def residuals(self):
        return self.state.residuals

    @property
    def unravel(self):
        return self.ctx.unravel

    @property
    def loss_fn(self):
        return self.ctx.loss_fn

    @property
    def acc_fn(self):
        return self.ctx.acc_fn

    @property
    def client_x(self):
        return self.ctx.client_x

    @property
    def client_y(self):
        return self.ctx.client_y

    @property
    def test(self):
        return self.ctx.test

    @property
    def pipeline(self):
        return self.ctx.pipeline

    @property
    def d(self) -> int:
        return self.ctx.d

    # -- data --------------------------------------------------------------

    def _round_batches(self, key):
        return _rounds.round_batches(self.ctx, key)

    # -- driver --------------------------------------------------------------

    @property
    def eps_trajectory(self):
        """Cumulative DP budget after each executed round (ledger view)."""
        return self.ledger.trajectory()

    def evaluate(self) -> float:
        params = self.unravel(self.w_global)
        return float(self.acc_fn(params, self.test))

    def run(self, rounds: int | None = None, eval_every: int = 5, verbose: bool = False):
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        key = jax.random.PRNGKey(cfg.seed)
        for t in range(rounds):
            key, kb, kr = jax.random.split(key, 3)
            batches = self._round_batches(kb)
            self.state, metrics = self._round(kr, self.state, batches)
            self.ledger.record_round()
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = self.evaluate()
                rec = {
                    "round": t + 1,
                    "acc": acc,
                    "loss": float(metrics["loss"]),
                    "b": float(self.state.b.b),
                    "eps_spent": self.ledger.eps_spent,
                }
                self.history.append(rec)
                if verbose:
                    print(
                        f"[{cfg.aggregator}|{cfg.attack}|byz={cfg.byz_frac:.0%}] "
                        f"round {t+1}: acc={acc:.4f} loss={rec['loss']:.4f} "
                        f"b={rec['b']:.5f} eps={rec['eps_spent']:.4g}"
                    )
        return self.history
