"""FL simulation runtime — paper Algorithm 1 end-to-end.

One round (jit-compiled, clients vmapped):
  1. every client trains its *personal* model from its previous local
     parameters, prox-regularized toward the current global model (Eq. 4);
  2. model differences ``delta^m = w_local^m - w_global`` are formed;
  3. Byzantine clients replace their delta per the configured attack;
  4. the configured :class:`repro.core.AggregatorPipeline` (resolved once
     from the registry — no aggregator branching here) compresses the
     updates onto the packed one-bit wire and estimates theta_hat —
     PRoBit+ quantizes with the dynamic/fixed/oracle ``b`` (+ DP margin)
     and ML-estimates (Eq. 13); baselines: FedAvg / Fed-GM / signSGD-MV /
     RSA ride the same registry;
  5. the global model steps by ``theta_hat``; the dynamic-b controller
     majority-votes the clients' one-bit loss signals (§VI-B).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from ..core import (
    ATTACKS,
    BControlConfig,
    DPConfig,
    available_aggregators,
    build_pipeline,
    get_attack,
    init_b_state,
    loss_bit,
    update_b,
)
from ..optim import local_prox_train

_B_MODES = ("dynamic", "fixed", "oracle")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 20
    byz_frac: float = 0.0
    attack: str = "none"
    aggregator: str = "probit_plus"  # | fedavg | fed_gm | signsgd_mv | rsa
    rounds: int = 30
    local_epochs: int = 5
    batch_size: int = 10
    lr: float = 0.01
    momentum: float = 0.5
    lam: float = 0.2
    dp_epsilon: float = 0.0  # 0 disables DP
    l1_sensitivity: float = 2e-4  # paper: 0.02 * lr
    b_mode: str = "dynamic"  # dynamic | fixed | oracle
    b_init: float = 0.01
    # BEYOND-PAPER: error feedback — each client carries the quantization
    # residual e_m into the next round (delta_eff = delta + e_m;
    # e_m' = delta_eff - b*c_m). Classical EF for 1-bit compressors;
    # the paper does not use it. DP note: EF reuses the residual across
    # rounds, so the per-round (eps,0) guarantee composes differently —
    # we therefore disable EF when dp_epsilon > 0.
    error_feedback: bool = False
    # BEYOND-PAPER: top-k sparse PRoBit+ (the paper's stated future work).
    # Fraction of coordinates each client uploads (1.0 = dense Eq. 5/13).
    # Refused under DP: the data-dependent index set breaks (eps,0)-DP
    # (see core/sparse.py).
    topk_frac: float = 1.0
    # Partial participation: fraction of clients sampled per round
    # (cross-device FL standard; M in Eq. 13 becomes the sampled count).
    # Amplification-by-subsampling would further tighten the DP budget —
    # we keep the per-round eps unchanged (conservative).
    participation: float = 1.0
    agg_step: float = 0.01  # server step for signSGD-MV / RSA
    gm_iters: int = 16
    use_kernels: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.aggregator not in available_aggregators():
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; "
                f"available: {available_aggregators()}"
            )
        if self.attack not in ATTACKS:
            raise ValueError(
                f"unknown attack {self.attack!r}; "
                f"available: {tuple(sorted(ATTACKS))}"
            )
        if self.b_mode not in _B_MODES:
            raise ValueError(
                f"unknown b_mode {self.b_mode!r}; available: {_B_MODES}"
            )
        if self.topk_frac < 1.0 and self.dp_epsilon > 0:
            raise ValueError(
                "topk_frac < 1 releases a data-dependent index set and "
                "breaks the (eps,0)-DP guarantee; use dense PRoBit+ with DP."
            )

    @property
    def n_active(self) -> int:
        return max(int(self.n_clients * self.participation), 1)

    @property
    def n_byz(self) -> int:
        return int(self.n_clients * self.byz_frac)

    @property
    def dp(self) -> DPConfig:
        return DPConfig(self.dp_epsilon, self.l1_sensitivity)

    @property
    def bctrl(self) -> BControlConfig:
        return BControlConfig(self.b_mode, self.b_init)

    def pipeline(self):
        """The shared :class:`repro.core.AggregatorPipeline` for this run."""
        return build_pipeline(
            self.aggregator,
            dp=self.dp,
            b_mode=self.b_mode,
            error_feedback=self.error_feedback,
            topk_frac=self.topk_frac,
            agg_step=self.agg_step,
            gm_iters=self.gm_iters,
            use_kernels=self.use_kernels,
        )


class FLSimulation:
    """Simulation-mode FL (CPU): the paper-faithful experiment harness."""

    def __init__(
        self,
        cfg: FLConfig,
        init_params,
        loss_fn: Callable,  # loss_fn(params_pytree, {"x","y"}) -> scalar
        acc_fn: Callable,
        client_x: np.ndarray,  # (M, per_client, ...)
        client_y: np.ndarray,  # (M, per_client)
        test: dict,
    ):
        self.cfg = cfg
        w0, self.unravel = ravel_pytree(init_params)
        self.w_global = w0
        self.w_locals = jnp.tile(w0[None], (cfg.n_clients, 1))
        self.residuals = jnp.zeros((cfg.n_clients, w0.shape[0]), jnp.float32)
        self.b_state = init_b_state(cfg.bctrl)
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.client_x = jnp.asarray(client_x)
        self.client_y = jnp.asarray(client_y)
        self.test = {k: jnp.asarray(v) for k, v in test.items()}
        self.d = w0.shape[0]
        # All aggregator-specific behavior lives in this pipeline object —
        # the runtime only orchestrates local training and state updates.
        self.pipeline = cfg.pipeline()
        self._round = jax.jit(self._round_impl)
        self.history: list[dict] = []

    # -- data --------------------------------------------------------------

    def _round_batches(self, key):
        cfg = self.cfg
        per_client = self.client_x.shape[1]
        steps = max(cfg.local_epochs * per_client // cfg.batch_size, 1)
        idx = jax.random.randint(
            key, (cfg.n_clients, steps, cfg.batch_size), 0, per_client
        )
        bx = jax.vmap(lambda x, i: x[i])(self.client_x, idx)
        by = jax.vmap(lambda y, i: y[i])(self.client_y, idx)
        return {"x": bx, "y": by}

    # -- one round ----------------------------------------------------------

    def _round_impl(self, key, w_global, w_locals, b, batches, residuals):
        cfg = self.cfg
        if cfg.participation < 1.0:
            sel = jax.random.choice(
                jax.random.fold_in(key, 99), cfg.n_clients,
                (cfg.n_active,), replace=False,
            )
        else:
            sel = jnp.arange(cfg.n_clients)
        w_sel = w_locals[sel]
        res_sel = residuals[sel]
        batches = jax.tree.map(lambda a: a[sel], batches)

        def client(w_local, cb, ck):
            return local_prox_train(
                self.loss_fn,
                w_global,
                w_local,
                self.unravel,
                cb,
                lr=cfg.lr,
                mu=cfg.momentum,
                lam=cfg.lam,
                use_kernel=cfg.use_kernels,
            )

        ckeys = jax.random.split(key, cfg.n_active)
        w_new, loss_before, loss_after = jax.vmap(client)(w_sel, batches, ckeys)
        deltas = w_new - w_global[None]

        k_att, k_q = jax.random.split(jax.random.fold_in(key, 1))
        n_byz = int(cfg.n_active * cfg.byz_frac)
        deltas_att = get_attack(cfg.attack)(k_att, deltas, n_byz)

        theta, res_new = self.pipeline(k_q, deltas_att, b.b, res_sel)
        w_global_new = w_global + theta

        bits = jax.vmap(loss_bit)(loss_before, loss_after)
        b_new = update_b(b, bits, cfg.bctrl)
        w_locals_new = w_locals.at[sel].set(w_new)
        residuals_new = residuals.at[sel].set(res_new)
        return w_global_new, w_locals_new, b_new, jnp.mean(loss_after), residuals_new

    # -- driver --------------------------------------------------------------

    def evaluate(self) -> float:
        params = self.unravel(self.w_global)
        return float(self.acc_fn(params, self.test))

    def run(self, rounds: int | None = None, eval_every: int = 5, verbose: bool = False):
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        key = jax.random.PRNGKey(cfg.seed)
        for t in range(rounds):
            key, kb, kr = jax.random.split(key, 3)
            batches = self._round_batches(kb)
            (
                self.w_global,
                self.w_locals,
                self.b_state,
                loss,
                self.residuals,
            ) = self._round(
                kr, self.w_global, self.w_locals, self.b_state, batches,
                self.residuals,
            )
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = self.evaluate()
                rec = {
                    "round": t + 1,
                    "acc": acc,
                    "loss": float(loss),
                    "b": float(self.b_state.b),
                }
                self.history.append(rec)
                if verbose:
                    print(
                        f"[{cfg.aggregator}|{cfg.attack}|byz={cfg.byz_frac:.0%}] "
                        f"round {t+1}: acc={acc:.4f} loss={rec['loss']:.4f} b={rec['b']:.5f}"
                    )
        return self.history
