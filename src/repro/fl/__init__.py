"""Federated-learning runtime: the paper's training protocol (Algorithm 1)
with pluggable aggregators, Byzantine attacks, and DP.

The round math lives in :mod:`repro.fl.rounds` as a pure functional core;
:class:`FLSimulation` drives it statefully, and :mod:`repro.sim` runs
whole scenario grids over it."""

from .rounds import (
    AsyncRoundState,
    CellParams,
    RoundContext,
    RoundState,
    async_fl_round,
    cell_params,
    fl_round,
    init_async_state,
    init_run_state,
    init_state,
    make_context,
    run_rounds,
)
from .hierarchy import (
    EDGE_MERGES,
    TreeRoundState,
    edge_slices,
    init_tree_state,
    tree_fl_round,
)
from .pytree_wire import (
    PytreeWireState,
    aggregate_pytree,
    compress_pytree,
    init_wire_state,
    leaf_key,
    pytree_wire_bytes,
    stream_aggregate_pytree,
)
from .runtime import FLConfig, FLSimulation

__all__ = [
    "FLConfig",
    "FLSimulation",
    "PytreeWireState",
    "leaf_key",
    "init_wire_state",
    "pytree_wire_bytes",
    "compress_pytree",
    "aggregate_pytree",
    "stream_aggregate_pytree",
    "RoundState",
    "AsyncRoundState",
    "RoundContext",
    "CellParams",
    "make_context",
    "init_state",
    "init_async_state",
    "init_run_state",
    "cell_params",
    "fl_round",
    "async_fl_round",
    "run_rounds",
    "EDGE_MERGES",
    "TreeRoundState",
    "edge_slices",
    "init_tree_state",
    "tree_fl_round",
]
