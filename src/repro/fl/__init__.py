"""Federated-learning runtime: the paper's training protocol (Algorithm 1)
with pluggable aggregators, Byzantine attacks, and DP.

The round math lives in :mod:`repro.fl.rounds` as a pure functional core;
:class:`FLSimulation` drives it statefully, and :mod:`repro.sim` runs
whole scenario grids over it."""

from .rounds import (
    CellParams,
    RoundContext,
    RoundState,
    cell_params,
    fl_round,
    init_state,
    make_context,
    run_rounds,
)
from .runtime import FLConfig, FLSimulation

__all__ = [
    "FLConfig",
    "FLSimulation",
    "RoundState",
    "RoundContext",
    "CellParams",
    "make_context",
    "init_state",
    "cell_params",
    "fl_round",
    "run_rounds",
]
