"""Federated-learning runtime: the paper's training protocol (Algorithm 1)
with pluggable aggregators, Byzantine attacks, and DP."""

from .runtime import FLConfig, FLSimulation

__all__ = ["FLConfig", "FLSimulation"]
