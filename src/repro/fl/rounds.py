"""Pure functional FL round core — paper Algorithm 1 as state -> state.

This module is the engine under both execution harnesses:

* :class:`repro.fl.FLSimulation` — the stateful, host-driven wrapper that
  keeps the original experiment API (one jitted round per Python-loop
  iteration, host-side eval every ``eval_every`` rounds);
* :mod:`repro.sim` — the campaign engine, which runs *whole scenario
  grids* as one computation: :func:`run_rounds` multi-rounds via
  ``lax.scan`` and is vmapped over (cell, seed) batches.

The split between static and traced scenario state is what makes the
vmapping possible:

* :class:`RoundContext` — everything that shapes the trace: the
  :class:`~repro.fl.runtime.FLConfig`, task functions, client data, the
  resolved :class:`~repro.core.AggregatorPipeline`, and the static
  ``flip_n`` of the ``bit_flip`` wire adversary. One context == one XLA
  program; cells sharing a context can be batched.
* :class:`CellParams` — per-cell *traced* scenario knobs (lr, momentum,
  prox weight, delta-attack id, wire-flip gate). Cells that differ only
  here ride one vmapped trace (the attack id dispatches via
  ``lax.switch``, see :func:`repro.core.attacks.apply_attack`).
* :class:`RoundState` — the evolving per-run state (global/local weights,
  dynamic-b controller, error-feedback residuals).

:func:`fl_round` reproduces the pre-refactor ``FLSimulation._round_impl``
operation-for-operation (same RNG schedule: client batches from one key,
attack/quantizer keys from ``fold_in(key, 1)``, participation sampling
from ``fold_in(key, 99)``), so a campaign cell at a fixed seed matches the
sequential simulation to float tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..core import (
    BState,
    apply_attack,
    attack_id as _attack_id,
    init_b_state,
    is_wire_attack,
    loss_bit,
    update_b,
)
from ..optim import local_prox_train

__all__ = [
    "RoundState",
    "CellParams",
    "RoundContext",
    "make_context",
    "init_state",
    "cell_params",
    "round_batches",
    "fl_round",
    "evaluate",
    "run_rounds",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Evolving state of one FL run (all leaves are device arrays)."""

    w_global: jax.Array  # (d,)
    w_locals: jax.Array  # (n_clients, d) personal models
    b: BState  # dynamic-b controller state
    residuals: jax.Array  # (n_clients, d) error-feedback residuals


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CellParams:
    """Traced per-cell scenario knobs — the vmappable campaign axes.

    Leaves may be Python scalars (the simulation path closes over them, so
    they fold into the trace as constants, reproducing the pre-refactor
    program exactly) or batched arrays (the campaign path maps over them).
    """

    lr: Any
    momentum: Any
    lam: Any
    attack_id: Any  # int index into repro.core.ATTACK_IDS (delta stage)
    flip_gate: Any  # bool: arm the bit_flip wire adversary (needs flip_n>0)


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Static context closed over by the round functions (not a pytree).

    Two cells can share a context — and therefore a compiled program —
    iff every field here compares equal (the campaign engine groups by the
    FLConfig fields this depends on; see ``repro.sim.campaign``).
    """

    cfg: Any  # FLConfig (static hyperparameters & shapes)
    loss_fn: Callable  # loss_fn(params_pytree, {"x","y"}) -> scalar
    acc_fn: Callable
    unravel: Callable
    pipeline: Any  # repro.core.AggregatorPipeline
    w0: jax.Array  # (d,) flat initial parameters
    client_x: jax.Array  # (n_clients, per_client, ...)
    client_y: jax.Array  # (n_clients, per_client)
    test: dict
    flip_n: int  # rows bit-flipped on the wire when a cell's flip_gate is on

    @property
    def d(self) -> int:
        return self.w0.shape[0]


def make_context(
    cfg,
    init_params,
    loss_fn: Callable,
    acc_fn: Callable,
    client_x,
    client_y,
    test: dict,
    *,
    wire_flip: bool | None = None,
) -> RoundContext:
    """Resolve a config + task into a RoundContext.

    ``wire_flip`` arms the static wire-flip slot even when ``cfg.attack``
    itself is not ``bit_flip`` — the campaign engine sets it when *any*
    cell in a vmapped group is a bit_flip cell (per-cell ``flip_gate``
    then selects).
    """
    w0, unravel = ravel_pytree(init_params)
    if wire_flip is None:
        wire_flip = is_wire_attack(cfg.attack)
    n_byz = int(cfg.n_active * cfg.byz_frac)
    return RoundContext(
        cfg=cfg,
        loss_fn=loss_fn,
        acc_fn=acc_fn,
        unravel=unravel,
        pipeline=cfg.pipeline(),
        w0=w0,
        client_x=jnp.asarray(client_x),
        client_y=jnp.asarray(client_y),
        test={k: jnp.asarray(v) for k, v in test.items()},
        flip_n=n_byz if wire_flip else 0,
    )


def init_state(ctx: RoundContext, b_init=None) -> RoundState:
    """Fresh run state; ``b_init`` overrides the config's (may be traced)."""
    cfg = ctx.cfg
    if b_init is None:
        b = init_b_state(cfg.bctrl)
    else:
        b = BState(b=jnp.asarray(b_init, jnp.float32), prev_vote=jnp.float32(0.0))
    return RoundState(
        w_global=ctx.w0,
        w_locals=jnp.tile(ctx.w0[None], (cfg.n_clients, 1)),
        b=b,
        residuals=jnp.zeros((cfg.n_clients, ctx.w0.shape[0]), jnp.float32),
    )


def cell_params(cfg) -> CellParams:
    """The CellParams a single FLConfig describes (scalar leaves)."""
    return CellParams(
        lr=cfg.lr,
        momentum=cfg.momentum,
        lam=cfg.lam,
        attack_id=_attack_id(cfg.attack),
        flip_gate=is_wire_attack(cfg.attack),
    )


def round_batches(ctx: RoundContext, key: jax.Array) -> dict:
    """Sample one round's local-training batches for every client."""
    cfg = ctx.cfg
    per_client = ctx.client_x.shape[1]
    steps = max(cfg.local_epochs * per_client // cfg.batch_size, 1)
    idx = jax.random.randint(
        key, (cfg.n_clients, steps, cfg.batch_size), 0, per_client
    )
    bx = jax.vmap(lambda x, i: x[i])(ctx.client_x, idx)
    by = jax.vmap(lambda y, i: y[i])(ctx.client_y, idx)
    return {"x": bx, "y": by}


def fl_round(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: RoundState,
    batches: dict,
) -> tuple[RoundState, dict]:
    """One FL round: local prox-training, attack, aggregate, b-control.

    Returns the next state and per-round metrics: ``loss`` (mean post-
    training local loss), ``b`` (controller value after the vote), and
    ``theta_mse`` — the mean squared error of the aggregated ``theta_hat``
    against the true mean of the (post-attack) uploaded updates, i.e. the
    pure aggregation error the paper's Theorem 1 bounds at O(1/M).
    """
    cfg = ctx.cfg
    w_global, w_locals, b, residuals = (
        state.w_global,
        state.w_locals,
        state.b,
        state.residuals,
    )
    if cfg.participation < 1.0:
        sel = jax.random.choice(
            jax.random.fold_in(key, 99), cfg.n_clients,
            (cfg.n_active,), replace=False,
        )
    else:
        sel = jnp.arange(cfg.n_clients)
    w_sel = w_locals[sel]
    res_sel = residuals[sel]
    batches = jax.tree.map(lambda a: a[sel], batches)

    def client(w_local, cb, ck):
        return local_prox_train(
            ctx.loss_fn,
            w_global,
            w_local,
            ctx.unravel,
            cb,
            lr=params.lr,
            mu=params.momentum,
            lam=params.lam,
            use_kernel=cfg.use_kernels,
        )

    ckeys = jax.random.split(key, cfg.n_active)
    w_new, loss_before, loss_after = jax.vmap(client)(w_sel, batches, ckeys)
    deltas = w_new - w_global[None]

    k_att, k_q = jax.random.split(jax.random.fold_in(key, 1))
    n_byz = int(cfg.n_active * cfg.byz_frac)
    deltas_att = apply_attack(params.attack_id, k_att, deltas, n_byz)

    theta, res_new = ctx.pipeline(
        k_q, deltas_att, b.b, res_sel,
        flip_n=ctx.flip_n, flip_gate=params.flip_gate,
    )
    w_global_new = w_global + theta

    bits = jax.vmap(loss_bit)(loss_before, loss_after)
    b_new = update_b(b, bits, cfg.bctrl)
    new_state = RoundState(
        w_global=w_global_new,
        w_locals=w_locals.at[sel].set(w_new),
        b=b_new,
        residuals=residuals.at[sel].set(res_new),
    )
    metrics = {
        "loss": jnp.mean(loss_after),
        "b": b_new.b,
        "theta_mse": jnp.mean((theta - jnp.mean(deltas_att, axis=0)) ** 2),
    }
    return new_state, metrics


def evaluate(ctx: RoundContext, w_global: jax.Array) -> jax.Array:
    """Test accuracy of the flat global model (jittable)."""
    return ctx.acc_fn(ctx.unravel(w_global), ctx.test)


def run_rounds(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: RoundState,
    rounds: int | None = None,
    *,
    with_acc: bool = True,
) -> tuple[RoundState, dict]:
    """Run ``rounds`` FL rounds under ``lax.scan``.

    Follows the exact per-round key schedule of ``FLSimulation.run``
    (``key, kb, kr = split(key, 3)``; batches from ``kb``, round from
    ``kr``), so at a fixed seed this reproduces the sequential driver.
    Returns the final state and the metrics trajectory (each metric is a
    ``(rounds,)`` array; ``acc`` included when ``with_acc``).
    """
    rounds = rounds or ctx.cfg.rounds

    def body(carry, _):
        key, state = carry
        key, kb, kr = jax.random.split(key, 3)
        batches = round_batches(ctx, kb)
        state, m = fl_round(ctx, params, kr, state, batches)
        if with_acc:
            m = dict(m, acc=evaluate(ctx, state.w_global))
        return (key, state), m

    (_, final_state), traj = jax.lax.scan(body, (key, state), None, length=rounds)
    return final_state, traj
