"""Pure functional FL round core — paper Algorithm 1 as state -> state.

Three round variants share one client-side recipe (participation
sampling, local prox-training, delta attack, Eq.-5 compression):

* :func:`fl_round` — the paper's synchronous protocol, *dense* execution:
  all M sampled clients train under one ``vmap`` and the full
  ``(M, d_pad/8)`` wire materializes before the estimate
  (:func:`_client_uploads`);
* :func:`stream_fl_round` — the same synchronous protocol under a
  **chunked execution model**: the cohort is scanned in chunks of
  ``FLConfig.client_chunk`` clients (``lax.scan``), and each chunk's
  train -> attack -> compress -> count-accumulate pipeline folds into
  additive carries (packed vote counts, the b-controller's loss-bit vote,
  metric sums). Resident memory is **O(client_chunk * d/8)** for the wire
  plus O(d) for the accumulators — independent of M — which is what lets
  a single CPU host run million-client PRoBit+ rounds. Per-client PRNG is
  counter-derived (batches keyed ``fold_in(kb, client_id)``, quantizer
  rows keyed ``fold_in(k_q, cohort_position)``), so under
  ``jax_threefry_partitionable`` any chunking of the cohort draws exactly
  the dense round's bits: count-streaming schemes (PRoBit+ / signSGD-MV /
  RSA) are *bit-identical* to :func:`fl_round` in eager mode and agree to
  1e-6 under jit (reassociation only). Byzantine membership, active-client
  masks, and staleness-style weights all enter as per-chunk row weights
  folded into the same accumulation. With ``FLConfig.stream_shard`` the
  chunk scan itself is sharded across the campaign mesh
  (:func:`repro.launch.mesh.make_campaign_mesh`): each device scans its
  slice of the client axis and the additive carries ``psum`` — the
  weighted-count reduction is the cross-device collective.
* :func:`async_fl_round` — buffered-asynchronous rounds (beyond paper):
  uploads arrive per a latency model, the server estimates from a bounded
  staleness buffer with age-weighted vote counts, and the ``straggler``
  timing adversary can withhold Byzantine uploads. See
  :class:`AsyncRoundState` / :func:`async_fl_round` for exactly which
  paper assumptions are relaxed.

This module is the engine under both execution harnesses:

* :class:`repro.fl.FLSimulation` — the stateful, host-driven wrapper that
  keeps the original experiment API (one jitted round per Python-loop
  iteration, host-side eval every ``eval_every`` rounds);
* :mod:`repro.sim` — the campaign engine, which runs *whole scenario
  grids* as one computation: :func:`run_rounds` multi-rounds via
  ``lax.scan`` and is vmapped over (cell, seed) batches.

The split between static and traced scenario state is what makes the
vmapping possible:

* :class:`RoundContext` — everything that shapes the trace: the
  :class:`~repro.fl.runtime.FLConfig`, task functions, client data, the
  resolved :class:`~repro.core.AggregatorPipeline`, and the static
  ``flip_n`` of the ``bit_flip`` wire adversary. One context == one XLA
  program; cells sharing a context can be batched.
* :class:`CellParams` — per-cell *traced* scenario knobs (lr, momentum,
  prox weight, delta-attack id, wire-flip gate). Cells that differ only
  here ride one vmapped trace (the attack id dispatches via
  ``lax.switch``, see :func:`repro.core.attacks.apply_attack`).
* :class:`RoundState` — the evolving per-run state (global/local weights,
  dynamic-b controller, error-feedback residuals).

:func:`fl_round` reproduces the pre-refactor ``FLSimulation._round_impl``
operation-for-operation (same RNG schedule: client batches from one key,
attack/quantizer keys from ``fold_in(key, 1)``, participation sampling
from ``fold_in(key, 99)``), so a campaign cell at a fixed seed matches the
sequential simulation to float tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..core import (
    BState,
    DenseWire,
    apply_attack,
    attack_id as _attack_id,
    init_b_state,
    is_timing_attack,
    is_wire_attack,
    loss_bit,
    staleness_weights,
    update_b,
)
from ..core.attacks import apply_attack_stream
from ..core.bcontrol import update_b_from_vote
from ..optim import local_prox_train

__all__ = [
    "RoundState",
    "AsyncRoundState",
    "CellParams",
    "RoundContext",
    "make_context",
    "init_state",
    "init_async_state",
    "init_run_state",
    "cell_params",
    "client_mask",
    "round_batches",
    "fl_round",
    "stream_fl_round",
    "async_fl_round",
    "round_fn",
    "evaluate",
    "run_rounds",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Evolving state of one FL run (all leaves are device arrays)."""

    w_global: jax.Array  # (d,)
    w_locals: jax.Array  # (n_clients, d) personal models
    b: BState  # dynamic-b controller state
    residuals: jax.Array  # (n_clients, d) error-feedback residuals


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AsyncRoundState:
    """State of one *buffered-asynchronous* FL run (paper assumption relaxed).

    The paper's Theorems 2-4 analyze synchronous rounds: all M sampled
    clients upload in lockstep and the server estimates from exactly this
    round's codes. ``AsyncRoundState`` relaxes that arrival assumption —
    the server keeps a bounded buffer of the last-arrived packed one-bit
    uploads (one wire row per slot) tagged with staleness ages, and each
    round estimates from the *buffer*, not the fresh cohort. Everything
    else (Eq. 5 compression, the packed uint8 wire, the Eq. 13 estimate
    shape, the dynamic-b controller) is unchanged; staleness enters only
    as a per-row weight folded into the vote counts.

    The first four fields mirror :class:`RoundState` (the sync state
    embeds structurally, so drivers can read ``w_global`` etc. off either).
    """

    w_global: jax.Array  # (d,)
    w_locals: jax.Array  # (n_clients, d) personal models
    b: BState  # dynamic-b controller state
    residuals: jax.Array  # (n_clients, d) error-feedback residuals
    buf_rows: jax.Array  # (B, P) uint8 packed wire rows | (B, d) f32 dense
    buf_age: jax.Array  # (B,) int32 rounds since the slot's upload arrived
    buf_valid: jax.Array  # (B,) bool slot holds an upload
    buf_owner: jax.Array  # (B,) int32 client index that wrote the slot (-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CellParams:
    """Traced per-cell scenario knobs — the vmappable campaign axes.

    Leaves may be Python scalars (the simulation path closes over them, so
    they fold into the trace as constants, reproducing the pre-refactor
    program exactly) or batched arrays (the campaign path maps over them).
    """

    lr: Any
    momentum: Any
    lam: Any
    attack_id: Any  # int index into repro.core.ATTACK_IDS (delta stage)
    flip_gate: Any  # bool: arm the bit_flip wire adversary (needs flip_n>0)
    latency: Any  # f32 mean upload latency in rounds; P(arrive) = 1/(1+lat)
    staleness_decay: Any  # f32 age-weight exponent: w(age) = (1+age)^(-decay)
    straggler_gate: Any  # bool: arm the straggler timing adversary
    # Number of *real* clients in this cell. Only read when the context is
    # ``masked`` (a fused heterogeneous-M campaign group): the client axis
    # is padded to the group max and rows >= m_active are masked out of
    # the estimate, the b-vote, and the metrics — M moves from a static
    # shape to a traced value. Unmasked contexts ignore it entirely, so
    # the single-config path compiles the exact pre-refactor program.
    m_active: Any = None


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Static context closed over by the round functions (not a pytree).

    Two cells can share a context — and therefore a compiled program —
    iff every field here compares equal (the campaign engine groups by the
    FLConfig fields this depends on; see ``repro.sim.campaign``).
    """

    cfg: Any  # FLConfig (static hyperparameters & shapes)
    loss_fn: Callable  # loss_fn(params_pytree, {"x","y"}) -> scalar
    acc_fn: Callable
    unravel: Callable
    pipeline: Any  # repro.core.AggregatorPipeline
    w0: jax.Array  # (d,) flat initial parameters
    client_x: jax.Array  # (n_clients, per_client, ...)
    client_y: jax.Array  # (n_clients, per_client)
    test: dict
    flip_n: int  # rows bit-flipped on the wire when a cell's flip_gate is on
    # True for fused heterogeneous-M campaign groups: the client axis is
    # padded to the group max and every round threads the 0/1 active-client
    # mask (rows < CellParams.m_active) through the estimate, the b-vote,
    # and the metrics. False compiles the exact unmasked program.
    masked: bool = False

    @property
    def d(self) -> int:
        return self.w0.shape[0]


def make_context(
    cfg,
    init_params,
    loss_fn: Callable,
    acc_fn: Callable,
    client_x,
    client_y,
    test: dict,
    *,
    wire_flip: bool | None = None,
    masked: bool = False,
) -> RoundContext:
    """Resolve a config + task into a RoundContext.

    ``wire_flip`` arms the static wire-flip slot even when ``cfg.attack``
    itself is not ``bit_flip`` — the campaign engine sets it when *any*
    cell in a vmapped group is a bit_flip cell (per-cell ``flip_gate``
    then selects). ``masked`` marks a fused heterogeneous-M context whose
    client axis is padded (``cfg.n_clients`` is the group max; the real
    per-cell M arrives as the traced ``CellParams.m_active``).
    """
    w0, unravel = ravel_pytree(init_params)
    if wire_flip is None:
        wire_flip = is_wire_attack(cfg.attack)
    if cfg.stream_shard:
        import warnings

        n_dev = len(jax.devices())
        if n_dev <= 1:
            warnings.warn(
                "stream_shard is a no-op: only one local device is visible. "
                "For CPU scaling runs set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
                "importing jax.",
                RuntimeWarning,
            )
        elif cfg.n_active % n_dev:
            warnings.warn(
                f"stream_shard falling back to a single-device scan: "
                f"cohort size {cfg.n_active} does not divide across "
                f"{n_dev} devices.",
                RuntimeWarning,
            )
    if cfg.tree_shard:
        import warnings

        n_dev = len(jax.devices())
        if n_dev <= 1:
            warnings.warn(
                "tree_shard is a no-op: only one local device is visible. "
                "For CPU scaling runs set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
                "importing jax.",
                RuntimeWarning,
            )
        elif cfg.tree_edges % n_dev:
            warnings.warn(
                f"tree_shard falling back to a host-loop edge sweep: "
                f"{cfg.tree_edges} edges do not divide across "
                f"{n_dev} devices.",
                RuntimeWarning,
            )
    if masked and (cfg.async_buffer or cfg.participation < 1.0):
        raise ValueError(
            "masked (fused heterogeneous-M) contexts require synchronous "
            "rounds at full participation; see repro.sim.plan.fusable"
        )
    n_byz = int(cfg.n_active * cfg.byz_frac)
    return RoundContext(
        cfg=cfg,
        loss_fn=loss_fn,
        acc_fn=acc_fn,
        unravel=unravel,
        pipeline=cfg.pipeline(),
        w0=w0,
        client_x=jnp.asarray(client_x),
        client_y=jnp.asarray(client_y),
        test={k: jnp.asarray(v) for k, v in test.items()},
        flip_n=n_byz if wire_flip else 0,
        masked=masked,
    )


def init_state(ctx: RoundContext, b_init=None) -> RoundState:
    """Fresh run state; ``b_init`` overrides the config's (may be traced).

    ``stateless_clients`` collapses the per-client state planes to one
    broadcast row — clients train from ``w_global`` each round and carry
    nothing, so the server holds O(d) state however large M grows.
    """
    cfg = ctx.cfg
    if b_init is None:
        b = init_b_state(cfg.bctrl)
    else:
        b = BState(b=jnp.asarray(b_init, jnp.float32), prev_vote=jnp.float32(0.0))
    n_rows = 1 if cfg.stateless_clients else cfg.n_clients
    return RoundState(
        w_global=ctx.w0,
        w_locals=jnp.tile(ctx.w0[None], (n_rows, 1)),
        b=b,
        residuals=jnp.zeros((n_rows, ctx.w0.shape[0]), jnp.float32),
    )


def init_async_state(ctx: RoundContext, b_init=None) -> AsyncRoundState:
    """Fresh async run state: empty staleness buffer, sync fields as usual.

    Buffer row shape follows the pipeline's wire format (packed uint8 for
    bit schemes, dense f32 for FedAvg / Fed-GM); all slots start invalid,
    so an estimate before any arrival is zero.
    """
    cfg = ctx.cfg
    base = init_state(ctx, b_init)
    n_bytes = ctx.pipeline.compressor.wire_bytes(ctx.d)
    if n_bytes is None:
        rows = jnp.zeros((cfg.async_buffer, ctx.d), jnp.float32)
    else:
        rows = jnp.zeros((cfg.async_buffer, n_bytes), jnp.uint8)
    return AsyncRoundState(
        w_global=base.w_global,
        w_locals=base.w_locals,
        b=base.b,
        residuals=base.residuals,
        buf_rows=rows,
        buf_age=jnp.zeros((cfg.async_buffer,), jnp.int32),
        buf_valid=jnp.zeros((cfg.async_buffer,), bool),
        buf_owner=jnp.full((cfg.async_buffer,), -1, jnp.int32),
    )


def init_run_state(ctx: RoundContext, b_init=None):
    """The state the context's config calls for (sync, async, or tree)."""
    if ctx.cfg.async_buffer:
        return init_async_state(ctx, b_init)
    if ctx.cfg.tree_edges and ctx.cfg.edge_buffer:
        from .hierarchy import init_tree_state

        return init_tree_state(ctx, b_init)
    return init_state(ctx, b_init)


def round_fn(ctx: RoundContext):
    """The round function matching the context (sync, streamed, async, tree)."""
    if ctx.cfg.async_buffer:
        return async_fl_round
    if ctx.cfg.tree_edges:
        from .hierarchy import tree_fl_round

        return tree_fl_round
    if ctx.cfg.client_chunk:
        return stream_fl_round
    return fl_round


def cell_params(cfg) -> CellParams:
    """The CellParams a single FLConfig describes (scalar leaves)."""
    return CellParams(
        lr=cfg.lr,
        momentum=cfg.momentum,
        lam=cfg.lam,
        attack_id=_attack_id(cfg.attack),
        flip_gate=is_wire_attack(cfg.attack),
        latency=cfg.async_latency,
        staleness_decay=cfg.staleness_decay,
        straggler_gate=is_timing_attack(cfg.attack),
        m_active=cfg.n_active,
    )


def client_mask(ctx: RoundContext, params: CellParams) -> jax.Array | None:
    """The 0/1 active-client row mask of a masked (fused) context.

    ``None`` for unmasked contexts — every weighted path downstream
    (estimate, b-vote, metric means) treats ``None`` as "use the exact
    unweighted ops", preserving bit-exactness of single-M execution.
    """
    if not ctx.masked:
        return None
    return (
        jnp.arange(ctx.cfg.n_active) < jnp.asarray(params.m_active)
    ).astype(jnp.float32)


def _batch_steps(ctx: RoundContext) -> int:
    cfg = ctx.cfg
    per_client = ctx.client_x.shape[1]
    return max(cfg.local_epochs * per_client // cfg.batch_size, 1)


def _client_batch_idx(ctx: RoundContext, key: jax.Array, client_id) -> jax.Array:
    """Client ``client_id``'s batch indices for the round keyed by ``key``.

    Keyed by *global client id* via ``fold_in``, not a position in one
    blocked ``(n_clients, ...)`` draw — so the streaming round can draw
    any client's batches inside its chunk scan and get exactly the indices
    the dense round drew for that client (``jax_threefry_partitionable``
    makes the fold_in schedule stable across chunkings).
    """
    cfg = ctx.cfg
    per_client = ctx.client_x.shape[1]
    return jax.random.randint(
        jax.random.fold_in(key, client_id),
        (_batch_steps(ctx), cfg.batch_size),
        0,
        per_client,
    )


def round_batches(ctx: RoundContext, key: jax.Array) -> dict:
    """Sample one round's local-training batches for every client.

    Streaming contexts (``client_chunk > 0``) defer the draw: the chunk
    scan materializes only its own C clients' batches, so the full
    ``(n_clients, steps, batch)`` gather never exists — the round key is
    passed through instead.
    """
    cfg = ctx.cfg
    if cfg.client_chunk:
        return {"key": key}
    idx = jax.vmap(lambda m: _client_batch_idx(ctx, key, m))(
        jnp.arange(cfg.n_clients)
    )
    bx = jax.vmap(lambda x, i: x[i])(ctx.client_x, idx)
    by = jax.vmap(lambda y, i: y[i])(ctx.client_y, idx)
    return {"x": bx, "y": by}


def _client_uploads(ctx, params, key, state, batches):
    """The client side of a round, shared by the sync and async variants:
    participation sampling, local prox-training, delta attack, and
    compression onto the wire. Returns everything the two server variants
    need; the RNG schedule is byte-identical between them, which is half
    of the zero-latency bit-exactness guarantee (the other half is the
    unit-weight count path, see ``packed_weighted_counts``)."""
    cfg = ctx.cfg
    w_global = state.w_global
    if cfg.participation < 1.0:
        sel = jax.random.choice(
            jax.random.fold_in(key, 99), cfg.n_clients,
            (cfg.n_active,), replace=False,
        )
    else:
        sel = jnp.arange(cfg.n_clients)
    w_sel = state.w_locals[sel]
    res_sel = state.residuals[sel]
    batches = jax.tree.map(lambda a: a[sel], batches)

    def client(w_local, cb, ck):
        return local_prox_train(
            ctx.loss_fn,
            w_global,
            w_local,
            ctx.unravel,
            cb,
            lr=params.lr,
            mu=params.momentum,
            lam=params.lam,
            use_kernel=cfg.use_kernels,
        )

    ckeys = jax.random.split(key, cfg.n_active)
    w_new, loss_before, loss_after = jax.vmap(client)(w_sel, batches, ckeys)
    deltas = w_new - w_global[None]

    k_att, k_q = jax.random.split(jax.random.fold_in(key, 1))
    n_byz = int(cfg.n_active * cfg.byz_frac)
    deltas_att = apply_attack(params.attack_id, k_att, deltas, n_byz)

    wire, res_new = ctx.pipeline.compress_wire(
        k_q, deltas_att, state.b.b, res_sel,
        flip_n=ctx.flip_n, flip_gate=params.flip_gate,
    )
    return sel, w_new, loss_before, loss_after, deltas_att, wire, res_new


def _finish_round(ctx, state, sel, w_new, loss_before, loss_after, res_new, theta, deltas_att, state_cls, mask=None, **extra):
    """Server epilogue shared by both variants: global step, b-control,
    state write-back, metrics.

    ``mask`` (fused heterogeneous-M groups only) is the 0/1 active-client
    row mask: padded clients cast no b-vote and drop out of the loss /
    theta_mse means. ``None`` keeps the exact unmasked ops.
    """
    cfg = ctx.cfg
    bits = jax.vmap(loss_bit)(loss_before, loss_after)
    b_new = update_b(state.b, bits, cfg.bctrl, weights=mask)
    new_state = state_cls(
        w_global=state.w_global + theta,
        w_locals=state.w_locals.at[sel].set(w_new),
        b=b_new,
        residuals=state.residuals.at[sel].set(res_new),
        **extra,
    )
    if mask is None:
        loss = jnp.mean(loss_after)
        delta_mean = jnp.mean(deltas_att, axis=0)
    else:
        m_eff = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(loss_after * mask) / m_eff
        delta_mean = jnp.sum(deltas_att * mask[:, None], axis=0) / m_eff
    metrics = {
        "loss": loss,
        "b": b_new.b,
        "theta_mse": jnp.mean((theta - delta_mean) ** 2),
    }
    return new_state, metrics


def fl_round(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: RoundState,
    batches: dict,
) -> tuple[RoundState, dict]:
    """One FL round: local prox-training, attack, aggregate, b-control.

    Returns the next state and per-round metrics: ``loss`` (mean post-
    training local loss), ``b`` (controller value after the vote), and
    ``theta_mse`` — the mean squared error of the aggregated ``theta_hat``
    against the true mean of the (post-attack) uploaded updates, i.e. the
    pure aggregation error the paper's Theorem 1 bounds at O(1/M).

    Under a ``masked`` context (fused heterogeneous-M campaign group) the
    active-client mask rides the *weighted* count path of PR 3 into the
    Eq. 13 vote counts: ``N_i^w`` sums only real clients and the effective
    cohort ``M^w = m_active`` is traced, so one compiled program serves
    every M in the group while the wire format is unchanged.
    """
    sel, w_new, loss_before, loss_after, deltas_att, wire, res_new = (
        _client_uploads(ctx, params, key, state, batches)
    )
    mask = client_mask(ctx, params)
    theta = ctx.pipeline.estimate(wire, weights=mask)
    return _finish_round(
        ctx, state, sel, w_new, loss_before, loss_after, res_new,
        theta, deltas_att, RoundState, mask=mask,
    )


def _scan_chunks(
    ctx: RoundContext,
    params: CellParams,
    kb: jax.Array,
    k_att: jax.Array,
    k_q: jax.Array,
    w_global: jax.Array,
    b_scalar: jax.Array,
    w_locals: jax.Array | None,
    residuals: jax.Array | None,
    sel_rows: jax.Array,
    client_x: jax.Array,
    client_y: jax.Array,
    data_offset,
    row0,
    limit,
    n_byz: int,
    weighted: bool,
) -> dict:
    """Scan one shard of the client axis in chunks of ``cfg.client_chunk``.

    ``sel_rows`` are the shard's selected client ids in cohort order;
    ``row0`` is the global cohort position of its first row (device
    ``k`` of a sharded scan passes ``k * n_local``), which keys the
    per-row quantizer streams, Byzantine membership, and wire flips;
    ``data_offset`` maps client ids to rows of the (possibly device-local)
    ``client_x`` block. Rows at cohort position >= ``limit`` carry weight
    zero (fused heterogeneous-M masks and chunk padding alike).

    Returns the additive carries: the stream accumulator ``acc`` (packed
    vote counts / weighted dense sum / row buffer, per the server's
    ``stream_kind``), the b-controller vote, the loss and delta sums, the
    effective cohort weight ``wsum``, and — stateful mode only — the
    written-back per-client planes. Every carry except the fed_gm row
    buffer is O(d), which is the streaming memory bound.
    """
    cfg = ctx.cfg
    C = cfg.client_chunk
    d = ctx.d
    server = ctx.pipeline.server
    kind = server.stream_kind
    n_loc = sel_rows.shape[0]
    n_chunks = -(-n_loc // C)
    n_pad = n_chunks * C
    # Padded tail rows wrap onto earlier clients; their weight is zero and
    # their state write-back is dropped, so the duplicates are inert.
    sel_p = sel_rows[jnp.arange(n_pad) % n_loc]
    stateless = cfg.stateless_clients
    steps = _batch_steps(ctx)

    if kind == "counts":
        p_bytes = ctx.pipeline.compressor.wire_bytes(d)
        acc0 = server.init_counts(p_bytes, weighted=weighted)
    elif kind == "sum":
        acc0 = server.init_stream_sum(d)
    else:  # "buffer" — fed_gm touches every row per Weiszfeld iteration
        acc0 = jnp.zeros((n_pad, d), jnp.float32)

    carry0 = dict(
        acc=acc0,
        vote=jnp.float32(0.0),
        loss=jnp.float32(0.0),
        dsum=jnp.zeros((d,), jnp.float32),
        wsum=jnp.float32(0.0),
    )
    if not stateless:
        carry0["w_locals"] = w_locals
        carry0["residuals"] = residuals

    def body(carry, g0):
        local = g0 + jnp.arange(C)  # shard-local row positions
        gidx = row0 + local  # global cohort positions
        sel_c = jax.lax.dynamic_slice(sel_p, (g0,), (C,))
        # Padded tail rows must mask on the *local* axis: a sharded scan's
        # pad rows carry global positions that run into the next shard's
        # range, where `gidx < limit` alone would leave them weighted.
        w_c = ((gidx < limit) & (local < n_loc)).astype(jnp.float32)

        idx = jax.vmap(lambda m: _client_batch_idx(ctx, kb, m))(sel_c)
        rows = sel_c - data_offset
        bx = jax.vmap(lambda r, i: client_x[r][i])(rows, idx)
        by = jax.vmap(lambda r, i: client_y[r][i])(rows, idx)

        if stateless:
            w_start = jnp.broadcast_to(w_global, (C, d))
            res_c = jnp.zeros((C, d), jnp.float32)
        else:
            w_start = carry["w_locals"][sel_c]
            res_c = carry["residuals"][sel_c]

        def client(w_local, cb):
            return local_prox_train(
                ctx.loss_fn,
                w_global,
                w_local,
                ctx.unravel,
                cb,
                lr=params.lr,
                mu=params.momentum,
                lam=params.lam,
                use_kernel=cfg.use_kernels,
            )

        w_new, loss_before, loss_after = jax.vmap(client)(
            w_start, {"x": bx, "y": by}
        )
        deltas = w_new - w_global[None]
        deltas_att = apply_attack_stream(
            params.attack_id, k_att, deltas, gidx < n_byz, gidx
        )
        wire, res_new = ctx.pipeline.compress_wire(
            k_q,
            deltas_att,
            b_scalar,
            res_c,
            flip_n=ctx.flip_n,
            flip_gate=params.flip_gate,
            row_offset=row0 + g0,
        )

        if kind == "counts":
            acc = server.accumulate_counts(
                carry["acc"], wire.packed, w_c if weighted else None
            )
        elif kind == "sum":
            acc = server.accumulate_sum(carry["acc"], wire.updates, w_c)
        else:
            acc = jax.lax.dynamic_update_slice(
                carry["acc"], wire.updates, (g0, 0)
            )

        bits = jax.vmap(loss_bit)(loss_before, loss_after).astype(jnp.float32)
        new = dict(
            acc=acc,
            vote=carry["vote"] + jnp.sum(bits * w_c),
            loss=carry["loss"] + jnp.sum(loss_after * w_c),
            dsum=carry["dsum"] + jnp.sum(deltas_att * w_c[:, None], axis=0),
            wsum=carry["wsum"] + jnp.sum(w_c),
        )
        if not stateless:
            # mode="drop": padded wrap rows target index n_clients (out of
            # bounds) so they cannot clobber a real client's row.
            tgt = jnp.where(local < n_loc, sel_c, cfg.n_clients)
            new["w_locals"] = carry["w_locals"].at[tgt].set(w_new, mode="drop")
            new["residuals"] = (
                carry["residuals"].at[tgt].set(res_new, mode="drop")
            )
        return new, None

    carry, _ = jax.lax.scan(body, carry0, jnp.arange(n_chunks) * C)
    return carry


def _stream_shard_devices(ctx: RoundContext) -> int:
    """How many devices the streaming scan shards over (1 = unsharded)."""
    cfg = ctx.cfg
    if not cfg.stream_shard:
        return 1
    n_dev = len(jax.devices())
    if n_dev <= 1 or cfg.n_active % n_dev:
        return 1
    return n_dev


def _sharded_scan(
    ctx: RoundContext,
    params: CellParams,
    kb: jax.Array,
    k_att: jax.Array,
    k_q: jax.Array,
    w_global: jax.Array,
    b_scalar: jax.Array,
    limit,
    n_byz: int,
    weighted: bool,
    n_dev: int,
) -> dict:
    """:func:`_scan_chunks` sharded over the campaign mesh's client slices.

    Each device scans its contiguous ``n_active / n_dev`` client rows
    (``stream_shard`` validation pins participation to 1.0, so cohort
    position == client id and the client data shards as plain blocks) and
    the additive carries ``psum`` — the weighted-count reduction is the
    only cross-device collective. Stateful planes are excluded by the
    ``stateless_clients`` requirement.
    """
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import make_campaign_mesh

    cfg = ctx.cfg
    n_loc = cfg.n_active // n_dev
    mesh = make_campaign_mesh(n_dev)

    def body(cx, cy, kb_, ka_, kq_, wg, bs, lim, prm):
        k = jax.lax.axis_index("data")
        row0 = k * n_loc
        sel_rows = row0 + jnp.arange(n_loc)
        carry = _scan_chunks(
            ctx, prm, kb_, ka_, kq_, wg, bs, None, None,
            sel_rows, cx, cy, row0, row0, lim, n_byz, weighted,
        )
        return jax.tree.map(lambda x: jax.lax.psum(x, "data"), carry)

    in_specs = (P("data"), P("data")) + (P(),) * 7
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=P())
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, check_vma=False, **kwargs)
    else:
        from jax.experimental.shard_map import shard_map

        fn = shard_map(body, check_rep=False, **kwargs)
    return fn(
        ctx.client_x, ctx.client_y, kb, k_att, k_q,
        w_global, b_scalar, jnp.asarray(limit, jnp.int32), params,
    )


def stream_fl_round(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: RoundState,
    batches: dict,
) -> tuple[RoundState, dict]:
    """One synchronous FL round under the chunked (streaming) client axis.

    Protocol-identical to :func:`fl_round` — same participation sampling,
    RNG schedule, attack semantics, estimate, b-vote, and metrics — but
    executed as a ``lax.scan`` over ``cfg.client_chunk``-client chunks:
    the wire and update matrices exist only chunk-sized, and the server
    carries additive accumulators (see :func:`_scan_chunks`). Count-
    streaming schemes are bit-identical to the dense round in eager mode;
    jit agreement is 1e-6 (reassociation of f32 partial sums only —
    integer vote counts are exact under any chunking).
    """
    cfg = ctx.cfg
    n = cfg.n_active
    C = cfg.client_chunk
    d = ctx.d
    server = ctx.pipeline.server
    kind = server.stream_kind
    kb = batches["key"]

    if cfg.participation < 1.0:
        sel = jax.random.choice(
            jax.random.fold_in(key, 99), cfg.n_clients,
            (n,), replace=False,
        )
    else:
        sel = jnp.arange(cfg.n_clients)
    k_att, k_q = jax.random.split(jax.random.fold_in(key, 1))
    n_byz = int(n * cfg.byz_frac)
    limit = jnp.asarray(params.m_active) if ctx.masked else n

    n_dev = _stream_shard_devices(ctx)
    n_loc = n // n_dev
    weighted = ctx.masked or (-(-n_loc // C)) * C != n_loc
    if n_dev > 1:
        carry = _sharded_scan(
            ctx, params, kb, k_att, k_q, state.w_global, state.b.b,
            limit, n_byz, weighted, n_dev,
        )
    else:
        carry = _scan_chunks(
            ctx, params, kb, k_att, k_q, state.w_global, state.b.b,
            None if cfg.stateless_clients else state.w_locals,
            None if cfg.stateless_clients else state.residuals,
            sel, ctx.client_x, ctx.client_y, 0, 0, limit, n_byz, weighted,
        )

    acc, vote, wsum = carry["acc"], carry["vote"], carry["wsum"]
    if kind == "counts":
        b_vec = ctx.pipeline.compressor.b_vector(d, state.b.b)
        if weighted:
            est = server.finalize(acc, jnp.maximum(wsum, 1e-12), b_vec)
            theta = jnp.where(wsum > 0, est, 0.0)
        else:
            theta = server.finalize(acc, n, b_vec)
    elif kind == "sum":
        theta = server.finalize_sum(acc)
    else:
        w_all = (jnp.arange(acc.shape[0]) < limit).astype(jnp.float32)
        theta = server.from_dense(acc, w_all if weighted else None)

    b_new = update_b_from_vote(state.b, vote, cfg.bctrl)
    new_state = RoundState(
        w_global=state.w_global + theta,
        w_locals=(
            state.w_locals if cfg.stateless_clients else carry["w_locals"]
        ),
        b=b_new,
        residuals=(
            state.residuals if cfg.stateless_clients else carry["residuals"]
        ),
    )
    m_eff = jnp.maximum(wsum, 1.0)
    delta_mean = carry["dsum"] / m_eff
    metrics = {
        "loss": carry["loss"] / m_eff,
        "b": b_new.b,
        "theta_mse": jnp.mean((theta - delta_mean) ** 2),
    }
    return new_state, metrics


def async_fl_round(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: AsyncRoundState,
    batches: dict,
) -> tuple[AsyncRoundState, dict]:
    """One buffered-asynchronous FL round (relaxes the paper's synchrony).

    Assumptions of the paper this variant relaxes, and what replaces them:

    * **Lockstep arrival** (Theorems 2-4 assume all M sampled clients
      upload every round): each client's upload instead *arrives* with
      probability ``1/(1 + latency)`` (``CellParams.latency``, traced, so
      a latency axis vmaps). A non-arriving client leaves its buffer slot
      holding its last delivered upload, one round staler.
    * **Fresh-cohort estimation** (Eq. 13 averages this round's codes):
      the server estimates from its bounded buffer (``async_buffer``
      slots; client m writes slot ``m mod B``, so ``B = M`` is one slot
      per client and ``B < M`` models slot contention under server memory
      pressure). Each buffered row is weighted ``(1+age)^(-staleness_decay)``
      — the weight folds into the vote counts *before* the Eq. 13 MLE
      (``packed_weighted_counts``), so the packed uint8 wire format and
      the estimate shape are unchanged.
    * **Range consistency**: a stale row's bits were drawn against the
      ``b`` of its production round but are estimated under the current
      ``b`` — one-bit codes are range-free votes, and the resulting scale
      error is bounded by the controller's per-round step (``1.01/0.98``)
      to the power of the age.

    The one-bit loss vote for the b-controller and the EF residual
    write-back stay synchronous: both are client-side state or O(1-bit)
    signals that piggyback on the round heartbeat, not model uploads.

    Degenerate parity: with ``async_buffer == n_active``, zero latency,
    and ``staleness_decay == 0`` every slot refreshes every round with
    weight exactly 1.0, and the trajectory is bit-exact with
    :func:`fl_round` (asserted in ``tests/test_async_rounds.py``).

    Extra metrics: ``buf_fill`` (fraction of valid slots) and ``mean_age``
    (mean staleness over valid slots).
    """
    cfg = ctx.cfg
    m_act, n_buf = cfg.n_active, cfg.async_buffer
    sel, w_new, loss_before, loss_after, deltas_att, wire, res_new = (
        _client_uploads(ctx, params, key, state, batches)
    )
    rows = wire.updates if isinstance(wire, DenseWire) else wire.packed

    # Arrival model: Bernoulli(1/(1+latency)) per (round, client). The
    # straggler timing adversary overrides its Byzantine rows' arrivals:
    # a (colluding) Byzantine client delivers only while its slot holds no
    # Byzantine upload, then the cohort withholds — the poisoned upload
    # sits in the buffer at ever-growing staleness, and if a slot-sharing
    # honest client evicts it (B < M), a Byzantine sharer re-delivers to
    # re-poison the slot. Gating on "any Byzantine resident" rather than
    # "my upload resident" keeps colluders from evicting each other
    # (which would reset the slot's age every round).
    p_arrive = 1.0 / (1.0 + params.latency)
    u = jax.random.uniform(jax.random.fold_in(key, 7), (m_act,))
    delivered = u < p_arrive
    slot = jnp.arange(m_act) % n_buf
    n_byz = int(m_act * cfg.byz_frac)
    byz = jnp.arange(m_act) < n_byz
    slot_owner = state.buf_owner[slot]
    byz_resident = (slot_owner >= 0) & (slot_owner < n_byz)
    delivered = jnp.where(params.straggler_gate & byz, ~byz_resident, delivered)

    # Fold the M fresh rows into the B slots, later clients winning shared
    # slots (static unrolled generations keep shapes vmappable).
    n_gen = -(-m_act // n_buf)
    pad = n_gen * n_buf - m_act
    rows_p = jnp.pad(rows, ((0, pad),) + ((0, 0),) * (rows.ndim - 1))
    del_p = jnp.pad(delivered, (0, pad))
    buf, hit = state.buf_rows, jnp.zeros((n_buf,), bool)
    owner = state.buf_owner
    for g in range(n_gen):
        d_g = del_p[g * n_buf : (g + 1) * n_buf]
        r_g = rows_p[g * n_buf : (g + 1) * n_buf]
        buf = jnp.where(d_g.reshape((-1,) + (1,) * (rows.ndim - 1)), r_g, buf)
        owner = jnp.where(d_g, g * n_buf + jnp.arange(n_buf), owner)
        hit = hit | d_g
    age = jnp.where(hit, 0, state.buf_age + 1)
    valid = state.buf_valid | hit

    # Age-weighted estimate from the buffered wire (current public b).
    weights = staleness_weights(age, params.staleness_decay, valid)
    if isinstance(wire, DenseWire):
        buf_wire = DenseWire(updates=buf)
    else:
        buf_wire = dataclasses.replace(wire, packed=buf)
    theta = ctx.pipeline.estimate(buf_wire, weights=weights)

    new_state, metrics = _finish_round(
        ctx, state, sel, w_new, loss_before, loss_after, res_new,
        theta, deltas_att, AsyncRoundState,
        buf_rows=buf, buf_age=age, buf_valid=valid, buf_owner=owner,
    )
    n_valid = jnp.sum(valid.astype(jnp.float32))
    metrics["buf_fill"] = n_valid / n_buf
    metrics["mean_age"] = jnp.sum(
        age.astype(jnp.float32) * valid
    ) / jnp.maximum(n_valid, 1.0)
    return new_state, metrics


def evaluate(ctx: RoundContext, w_global: jax.Array) -> jax.Array:
    """Test accuracy of the flat global model (jittable)."""
    return ctx.acc_fn(ctx.unravel(w_global), ctx.test)


def run_rounds(
    ctx: RoundContext,
    params: CellParams,
    key: jax.Array,
    state: RoundState,
    rounds: int | None = None,
    *,
    with_acc: bool = True,
) -> tuple[RoundState, dict]:
    """Run ``rounds`` FL rounds under ``lax.scan``.

    Follows the exact per-round key schedule of ``FLSimulation.run``
    (``key, kb, kr = split(key, 3)``; batches from ``kb``, round from
    ``kr``), so at a fixed seed this reproduces the sequential driver.
    Returns the final state and the metrics trajectory (each metric is a
    ``(rounds,)`` array; ``acc`` included when ``with_acc``). The round
    variant follows the carried state: an :class:`AsyncRoundState` scans
    :func:`async_fl_round`, a :class:`RoundState` the synchronous round.
    """
    rounds = rounds or ctx.cfg.rounds
    if isinstance(state, AsyncRoundState):
        step = async_fl_round
    elif ctx.cfg.tree_edges:
        from .hierarchy import tree_fl_round

        step = tree_fl_round
    else:
        step = stream_fl_round if ctx.cfg.client_chunk else fl_round

    def body(carry, _):
        key, state = carry
        key, kb, kr = jax.random.split(key, 3)
        batches = round_batches(ctx, kb)
        state, m = step(ctx, params, kr, state, batches)
        if with_acc:
            m = dict(m, acc=evaluate(ctx, state.w_global))
        return (key, state), m

    (_, final_state), traj = jax.lax.scan(body, (key, state), None, length=rounds)
    return final_state, traj
